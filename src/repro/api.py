"""Unified serving entry point: ``repro.api.serve(ServeSpec(...))``.

One facade for every front-end (launchers, benchmarks, examples): builds
the model, resolves the scheduling policy by name from
``repro.scheduling.registry`` — so live engines can run the baseline
policies (vllm / splitwise / sarathi) as well as AcceLLM — and drives a
:class:`repro.workloads.WorkloadSpec` traffic stream through
:class:`repro.scheduling.live.LiveCluster`.  The lifecycle is open-loop:
requests arrive over time on the iteration clock (or closed-loop for
``ClosedLoop`` specs); latency metrics are reported in scheduling
iterations, alongside SLO attainment and goodput when the spec carries
an :class:`repro.workloads.SLO`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.configs import get_config
from repro.fleet import FleetController, FleetSchedule
from repro.models import init_params
from repro.scheduling.live import LiveCluster
from repro.scheduling.registry import get_policy, policy_accepts
from repro.serving.request import Request
from repro.workloads import (SLO, Batch, SLOSummary, TableLengths,
                             WorkloadSpec, queue_depth_stats, slo_summary,
                             utilization)


@dataclass
class ServeSpec:
    """Everything needed to stand up a live serving cluster."""
    arch: str = "phi3-medium-14b"
    policy: str = "accellm"
    policy_kwargs: Dict = field(default_factory=dict)
    n_instances: int = 4
    num_slots: int = 8
    kv_capacity: int = 256
    #: KV lines per block in the paged store's ledger (None: largest
    #: divisor of kv_capacity <= 16)
    block_lines: Optional[int] = None
    #: fused decode ceiling: idle open-loop stretches run up to N decode
    #: iterations as one jitted scan (1 = per-step decode)
    fuse_decode_steps: int = 1
    #: refcounted radix prefix cache on every engine (repro.prefixcache):
    #: shared prompt heads prefill once and dedup in HBM
    prefix_cache: bool = False
    #: cache retention cap in pool blocks (None: half of each engine's
    #: block pool)
    prefix_cache_blocks: Optional[int] = None
    redundancy: bool = True            # forwarded to redundancy-aware policies
    #: straggler hedging (forwarded to hedging-aware policies): decode
    #: routes to synced mirrors when an instance's health EWMA crosses
    #: the kernel's threshold
    hedging: bool = True
    #: bounded admission queue: arrivals are shed at the door once the
    #: backlog holds this many requests (None = unbounded)
    max_queue: Optional[int] = None
    #: deadline-aware shedding: queued requests waiting longer than this
    #: many iterations are refused (None = never); pair with ``slo.ttft``
    shed_deadline: Optional[float] = None
    reduced: bool = True               # CPU-sized variant of the architecture
    temperature: float = 0.0
    eos_token: Optional[int] = None
    seed: int = 0
    max_steps: int = 2000
    #: first-class traffic description; when None, a legacy batch-at-t=0
    #: spec is built from (workload, n_requests, request_scale) below
    traffic: Optional[WorkloadSpec] = None
    #: latency targets in iterations; enables attainment/goodput reporting
    slo: Optional[SLO] = None
    #: fleet fault-injection schedule (repro.fleet): kills / joins /
    #: drains applied between scheduler iterations on the iteration
    #: clock; the same schedule drives the simulator in modeled seconds
    fleet: Optional[FleetSchedule] = None
    #: tensor-parallel width per instance: carve the host's devices into
    #: n_instances disjoint ``model``-axis mesh slices (repro.meshserve)
    #: and shard each engine's params + KV pool across its slice; None
    #: keeps every engine on the default device
    mesh_tp: Optional[int] = None
    #: heterogeneous pod: one InstanceSpec per instance (slice widths
    #: follow ``spec.n_devices``); overrides mesh_tp's uniform carving
    mesh_specs: Optional[Sequence] = None
    #: sample the observability timeline every N scheduling iterations
    #: (1 = every iteration); long replays keep O(n/stride) memory
    timeline_stride: int = 1
    # legacy request sampling (used when `traffic` is not given)
    workload: str = "mixed"
    n_requests: int = 16
    request_scale: float = 0.05

    def resolve_traffic(self) -> WorkloadSpec:
        if self.traffic is not None:
            return self.traffic
        return WorkloadSpec(arrival=Batch(self.n_requests),
                            lengths=TableLengths(self.workload,
                                                 scale=self.request_scale),
                            name=self.workload)


@dataclass
class ServeReport:
    """Outcome of a serve() run; latencies are in scheduling iterations."""
    spec: ServeSpec
    cluster: LiveCluster
    finished: List[Request]
    n_submitted: int

    @property
    def stats(self) -> Dict[str, int]:
        return self.cluster.stats

    @property
    def fleet_stats(self) -> Optional[Dict[str, int]]:
        """Failover/scale counters from the run's FleetController (None
        when no fleet event fired)."""
        return self.cluster.fleet.stats if self.cluster.fleet else None

    @property
    def all_finished(self) -> bool:
        """Every submitted request reached a terminal state and the
        source was fully delivered.  Shed/aborted requests are terminal
        — a degraded run *completes*; whether it was healthy is the SLO
        summary's question (sheds count as misses there)."""
        return (len(self.finished) + self.n_shed + self.n_aborted
                == self.n_submitted and self.n_undelivered == 0)

    @property
    def n_unfinished(self) -> int:
        return (self.n_submitted - len(self.finished)
                - self.n_shed - self.n_aborted)

    @property
    def n_shed(self) -> int:
        """Requests refused by admission control (queue bound or
        deadline) — deliberate, counted SLO misses."""
        return len(self.cluster.shed)

    @property
    def n_aborted(self) -> int:
        """Requests torn down mid-flight (client aborts + KV-pressure
        aborts)."""
        return len(self.cluster.aborted)

    @property
    def n_undelivered(self) -> int:
        """Source requests never admitted because max_steps elapsed."""
        return self.cluster.undelivered

    @property
    def duration(self) -> float:
        return self.cluster.now

    @property
    def timeline(self):
        return self.cluster.timeline

    @property
    def sched_us_per_iter(self) -> float:
        """Mean wall-clock scheduler overhead per iteration (µs) —
        policy + planner decisions, excluding engine execution."""
        return self.cluster.sched_us_per_iter

    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft() for r in self.finished])

    def jcts(self) -> np.ndarray:
        return np.array([r.jct() for r in self.finished])

    def tbts(self) -> np.ndarray:
        # no sentinel: an empty array must stay empty or it drags down
        # mean/worst TBT for single-token runs
        return np.array([t for r in self.finished for t in r.tbts()])

    def slo(self, slo: Optional[SLO] = None) -> SLOSummary:
        """Score the run against ``slo`` (default: the spec's)."""
        slo = slo or self.spec.slo or SLO()
        return slo_summary(self.cluster._submitted, slo,
                           duration=self.duration,
                           unit=self.cluster.clock.unit)

    def goodput(self, slo: Optional[SLO] = None) -> float:
        return self.slo(slo).goodput

    def utilization(self) -> Dict[str, float]:
        return utilization(self.timeline, len(self.cluster.engines))

    def queue_depth(self) -> Dict[str, float]:
        return queue_depth_stats(self.timeline)

    def describe(self) -> str:
        lines = [f"finished {len(self.finished)}/{self.n_submitted}"
                 + (f" ({self.n_unfinished} unfinished)"
                    if self.n_unfinished else "")
                 + (f" ({self.n_shed} shed)" if self.n_shed else "")
                 + (f" ({self.n_aborted} aborted)"
                    if self.n_aborted else "")
                 + (f" [{self.n_undelivered} never delivered — raise "
                    f"max_steps]" if self.n_undelivered else "")]
        if self.finished:
            ttfts, jcts, tbts = self.ttfts(), self.jcts(), self.tbts()
            lines.append(f"TTFT (iters): p50={np.percentile(ttfts, 50):.1f} "
                         f"p99={np.percentile(ttfts, 99):.1f}")
            if tbts.size:
                lines.append(f"TBT  (iters): mean={tbts.mean():.2f} "
                             f"worst={tbts.max():.1f}")
            lines.append(f"JCT  (iters): p50={np.percentile(jcts, 50):.1f} "
                         f"p99={np.percentile(jcts, 99):.1f}")
        if self.spec.slo is not None:
            lines.append(self.slo().describe())
        util = self.utilization()
        qd = self.queue_depth()
        if self.timeline:
            lines.append(
                f"util: prefill={util['prefill']:.1%} "
                f"decode={util['decode']:.1%} idle={util['idle']:.1%}; "
                f"queue depth mean={qd['mean']:.1f} peak={qd['peak']:.0f}")
        lines.append(f"stats: {self.stats}")
        if self.fleet_stats is not None:
            fs = {k: v for k, v in self.fleet_stats.items() if v}
            lines.append(f"fleet: {fs or 'no events fired'}")
        return "\n".join(lines)


def build_cluster(spec: ServeSpec, cfg=None, params=None) -> LiveCluster:
    """Resolve config, params and policy, and return a ready cluster."""
    if cfg is None:
        cfg = get_config(spec.arch)
        if spec.reduced:
            cfg = cfg.reduced()
    if params is None:
        params = init_params(jax.random.PRNGKey(spec.seed), cfg)
    kwargs = dict(spec.policy_kwargs)
    if policy_accepts(spec.policy, "redundancy"):
        kwargs.setdefault("redundancy", spec.redundancy)
    if policy_accepts(spec.policy, "hedging"):
        kwargs.setdefault("hedging", spec.hedging)
    policy = get_policy(spec.policy, **kwargs)
    fleet = (FleetController(spec.fleet, seed=spec.seed)
             if spec.fleet is not None else None)
    mesh = None
    if spec.mesh_specs is not None:
        from repro.meshserve import MeshPlacement
        mesh = MeshPlacement.carve(spec.n_instances,
                                   specs=spec.mesh_specs)
    elif spec.mesh_tp is not None:
        from repro.meshserve import MeshPlacement
        mesh = MeshPlacement.carve(spec.n_instances, tp=spec.mesh_tp)
    return LiveCluster(cfg, params, spec.n_instances, spec.num_slots,
                       spec.kv_capacity, policy,
                       temperature=spec.temperature,
                       eos_token=spec.eos_token,
                       block_lines=spec.block_lines,
                       fuse_decode_steps=spec.fuse_decode_steps,
                       prefix_cache=spec.prefix_cache,
                       prefix_cache_blocks=spec.prefix_cache_blocks,
                       fleet=fleet, mesh=mesh,
                       timeline_stride=spec.timeline_stride,
                       max_queue=spec.max_queue,
                       shed_deadline=spec.shed_deadline)


def serve(spec: ServeSpec,
          requests: Optional[Sequence[Union[Request,
                                            Tuple[Request, Optional[dict]]]]]
          = None, cfg=None, params=None) -> ServeReport:
    """Build the cluster, run the traffic to completion, and report.

    With explicit ``requests`` they are submitted up front (closed batch,
    the legacy contract).  Otherwise the spec's
    :class:`~repro.workloads.WorkloadSpec` drives the cluster open-loop:
    the request stream is pulled against the iteration clock as arrivals
    come due."""
    cluster = build_cluster(spec, cfg=cfg, params=params)
    if requests is not None:
        for item in requests:
            req, extra = item if isinstance(item, tuple) else (item, None)
            cluster.submit(req, extra)
        finished = cluster.run(max_steps=spec.max_steps)
    else:
        source = spec.resolve_traffic().source(seed=spec.seed,
                                               cfg=cluster.cfg)
        finished = cluster.run(max_steps=spec.max_steps, source=source)
    return ServeReport(spec=spec, cluster=cluster, finished=finished,
                       n_submitted=len(cluster._submitted))
