"""Unified serving entry point: ``repro.api.serve(ServeSpec(...))``.

One facade for every front-end (launchers, benchmarks, examples): builds
the model, resolves the scheduling policy by name from
``repro.scheduling.registry`` — so live engines can run the baseline
policies (vllm / splitwise / sarathi) as well as AcceLLM — drives the
request set through :class:`repro.scheduling.live.LiveCluster`, and
returns latency metrics in scheduling iterations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.scheduling.live import LiveCluster
from repro.scheduling.registry import get_policy, policy_accepts
from repro.serving.request import Request
from repro.sim.workload import WORKLOADS


@dataclass
class ServeSpec:
    """Everything needed to stand up a live serving cluster."""
    arch: str = "phi3-medium-14b"
    policy: str = "accellm"
    policy_kwargs: Dict = field(default_factory=dict)
    n_instances: int = 4
    num_slots: int = 8
    kv_capacity: int = 256
    redundancy: bool = True            # forwarded to redundancy-aware policies
    reduced: bool = True               # CPU-sized variant of the architecture
    temperature: float = 0.0
    eos_token: Optional[int] = None
    seed: int = 0
    max_steps: int = 2000
    # request sampling (used when serve() is not given explicit requests)
    workload: str = "mixed"
    n_requests: int = 16
    request_scale: float = 0.05


@dataclass
class ServeReport:
    """Outcome of a serve() run; latencies are in scheduling iterations."""
    spec: ServeSpec
    cluster: LiveCluster
    finished: List[Request]
    n_submitted: int

    @property
    def stats(self) -> Dict[str, int]:
        return self.cluster.stats

    @property
    def all_finished(self) -> bool:
        return len(self.finished) == self.n_submitted

    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft() for r in self.finished])

    def jcts(self) -> np.ndarray:
        return np.array([r.jct() for r in self.finished])

    def tbts(self) -> np.ndarray:
        flat = [t for r in self.finished for t in r.tbts()]
        return np.array(flat or [0.0])

    def describe(self) -> str:
        lines = [f"finished {len(self.finished)}/{self.n_submitted}"]
        if self.finished:
            ttfts, jcts, tbts = self.ttfts(), self.jcts(), self.tbts()
            lines += [
                f"TTFT (iters): p50={np.percentile(ttfts, 50):.1f} "
                f"p99={np.percentile(ttfts, 99):.1f}",
                f"TBT  (iters): mean={tbts.mean():.2f} "
                f"worst={tbts.max():.1f}",
                f"JCT  (iters): p50={np.percentile(jcts, 50):.1f} "
                f"p99={np.percentile(jcts, 99):.1f}",
            ]
        lines.append(f"stats: {self.stats}")
        return "\n".join(lines)


def build_cluster(spec: ServeSpec, cfg=None, params=None) -> LiveCluster:
    """Resolve config, params and policy, and return a ready cluster."""
    if cfg is None:
        cfg = get_config(spec.arch)
        if spec.reduced:
            cfg = cfg.reduced()
    if params is None:
        params = init_params(jax.random.PRNGKey(spec.seed), cfg)
    kwargs = dict(spec.policy_kwargs)
    if policy_accepts(spec.policy, "redundancy"):
        kwargs.setdefault("redundancy", spec.redundancy)
    policy = get_policy(spec.policy, **kwargs)
    return LiveCluster(cfg, params, spec.n_instances, spec.num_slots,
                       spec.kv_capacity, policy,
                       temperature=spec.temperature,
                       eos_token=spec.eos_token)


def sample_requests(cfg, n: int, workload: str, seed: int = 0,
                    scale: float = 0.05
                    ) -> List[Tuple[Request, Optional[dict]]]:
    """Sample prompt/decode lengths from the paper's workload tables
    (Table 2), scaled down for CPU-sized engines; attaches the modality
    extras (vision patches / audio frames) the architecture needs."""
    (plo, phi), (dlo, dhi) = WORKLOADS[workload]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = max(4, int(rng.integers(plo, phi + 1) * scale))
        dlen = max(2, int(rng.integers(dlo, dhi + 1) * scale))
        extra = None
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            extra = {"patch_embeds": jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (1, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))}
        elif cfg.is_encoder_decoder:
            # frames length must equal the encoder memory capacity so the
            # engine can merge the per-request state into its slot
            extra = {"frames": jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (1, cfg.encoder.max_source_positions,
                 cfg.frontend.embed_dim))}
        reqs.append((Request(
            prompt_len=plen, max_new_tokens=dlen,
            prompt_tokens=jax.random.randint(
                jax.random.fold_in(key, i), (1, plen), 0, cfg.vocab_size)),
            extra))
    return reqs


def serve(spec: ServeSpec,
          requests: Optional[Sequence[Union[Request,
                                            Tuple[Request, Optional[dict]]]]]
          = None, cfg=None, params=None) -> ServeReport:
    """Build the cluster, run the request set to completion, and report."""
    cluster = build_cluster(spec, cfg=cfg, params=params)
    if requests is None:
        requests = sample_requests(cluster.cfg, spec.n_requests,
                                   spec.workload, seed=spec.seed,
                                   scale=spec.request_scale)
    n = 0
    for item in requests:
        req, extra = item if isinstance(item, tuple) else (item, None)
        cluster.submit(req, extra)
        n += 1
    finished = cluster.run(max_steps=spec.max_steps)
    return ServeReport(spec=spec, cluster=cluster, finished=finished,
                       n_submitted=n)
