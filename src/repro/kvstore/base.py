"""The shared block-table ledger: line/byte accounting both backends use.

A *line* is one token's worth of attention KV across all attention layers
(``repro.core.kvbytes.bytes_per_token``).  Recurrent blocks (SSM / xLSTM)
contribute a constant-size state that lives in a dedicated single block
per request; enc-dec static caches (encoder output, cross K/V) are priced
with it but written only once.

Line counts follow the serving convention both executors already used for
memory accounting: a resident request is charged ``total_len = prompt_len
+ generated`` lines — the prompt's KV plus one line per sampled token
(the line for the newest token is *reserved* at sampling time and
physically written by the next decode step; see
``PagedStore.copy_lines``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.kvbytes import (bytes_per_token, recurrent_state_bytes,
                                static_state_bytes)


class KVStoreError(RuntimeError):
    """Raised on ledger misuse (double alloc, unknown rid, pool
    exhaustion)."""


@dataclass(frozen=True)
class LineCosts:
    """Byte costs of one request's serving state, per architecture.

    The single source of truth consumed by the balancer weights, the
    scheduler views and both stores — derived from
    :mod:`repro.core.kvbytes` so live engines and the simulator price a
    line identically.
    """
    line_bytes: float      # KV bytes appended per token (attention layers)
    recurrent_bytes: int   # constant-size state re-mirrored every step
    static_bytes: int      # written once at prefill (enc-dec caches)

    @property
    def fixed_bytes(self) -> int:
        return self.recurrent_bytes + self.static_bytes

    @classmethod
    def from_config(cls, cfg: ModelConfig, dtype_bytes: int = 2
                    ) -> "LineCosts":
        return cls(line_bytes=bytes_per_token(cfg, dtype_bytes),
                   recurrent_bytes=recurrent_state_bytes(cfg, dtype_bytes),
                   static_bytes=static_state_bytes(cfg, dtype_bytes))

    def bytes_at(self, lines: int) -> float:
        """Total state bytes for a request holding ``lines`` KV lines
        (== ``repro.core.kvbytes.state_bytes_at(cfg, lines)``)."""
        return self.line_bytes * lines + self.fixed_bytes

    def mirror_bytes(self, delta_lines: int) -> float:
        """Per-sync replica-update traffic: only the new KV lines plus
        the constant-size recurrent state (§4.1.2)."""
        return self.line_bytes * delta_lines + self.recurrent_bytes


@dataclass
class BlockLedger:
    """Fixed-size block pool + per-request block tables.

    Blocks hold ``block_lines`` KV lines each; a request additionally
    pins one *fixed block* for its length-independent state when the
    architecture has any.  ``max_blocks_per_seq`` caps a single request's
    line blocks (the live engine's ring-buffer window: lines beyond the
    window reuse the same physical blocks).

    ``strict=False`` (the simulator's accounting overlay) lets the pool
    *overcommit*: an alloc past the last free block mints overflow ids
    instead of raising, ``free_blocks()`` bottoms out at 0, and overflow
    ids are discarded on free.  The live store stays strict — a real
    engine cannot mint HBM.

    Blocks are *refcounted*: ``alloc(..., shared=[...])`` adopts blocks
    already referenced elsewhere (a resident prefix) into the head of the
    new table without consuming pool headroom, and ``retain``/``release``
    let an external holder (the prefix cache) keep blocks alive after
    their last table drops them.  A block returns to the free list only
    when its last referent releases it; ``free``/``release`` report the
    count of blocks *actually* released.  Appending into a shared,
    partially-filled tail block triggers copy-on-write (the writer gets a
    private replacement; ``last_cow`` records the swap for stores that
    also move bytes).
    """
    costs: LineCosts
    num_blocks: int
    block_lines: int
    max_blocks_per_seq: Optional[int] = None
    strict: bool = True
    tables: Dict[int, List[int]] = field(default_factory=dict)
    fixed_block: Dict[int, Optional[int]] = field(default_factory=dict)
    _lines: Dict[int, int] = field(default_factory=dict)
    _synced: Dict[int, int] = field(default_factory=dict)
    _free: List[int] = field(default_factory=list)
    _next_overflow: int = 0
    #: per-block reference counts; a block is either free or in _refs
    _refs: Dict[int, int] = field(default_factory=dict)
    #: per-rid head lines backed by blocks adopted via ``shared=``
    _shared_head: Dict[int, int] = field(default_factory=dict)
    #: last copy-on-write swap: (rid, old_block, new_block)
    last_cow: Optional[Tuple[int, int, int]] = None
    #: running Σ of ``_lines`` values, so ``used_bytes`` is O(1) — the
    #: balancer reads it per scheduling decision over every instance
    _tot_lines: int = 0

    def __post_init__(self):
        if self.block_lines <= 0:
            raise KVStoreError(f"block_lines must be > 0 "
                               f"(got {self.block_lines})")
        if not self._free:
            self._free = list(range(self.num_blocks - 1, -1, -1))
        self._next_overflow = self.num_blocks

    def _take(self, need: int) -> List[int]:
        """Pop ``need`` blocks off the free list; in non-strict mode any
        shortfall is covered by minted overflow ids."""
        if need <= len(self._free):
            take = self._free[-need:][::-1] if need else []
            del self._free[len(self._free) - need:]
        else:
            if self.strict:
                raise KVStoreError(
                    f"pool exhausted: {need} blocks needed, "
                    f"{len(self._free)} free")
            take = self._free[::-1]
            self._free.clear()
            while len(take) < need:
                take.append(self._next_overflow)
                self._next_overflow += 1
        for b in take:
            self._refs[b] = 1
        return take

    def _take_hinted(self, need: int, block_ids: List[int],
                     exact: bool) -> List[int]:
        """Take ``need`` specific free blocks from a placement hint.
        ``exact`` demands the first ``need`` hint entries be free (alloc
        contract); otherwise free hint entries are filtered (append)."""
        if exact:
            if len(block_ids) < need:
                raise KVStoreError(
                    f"{need} blocks needed, hint has {len(block_ids)}")
            take = list(block_ids[:need])
            missing = [b for b in take if b not in self._free]
            if missing:
                raise KVStoreError(f"blocks {missing} are not free")
        else:
            take = [b for b in block_ids if b in self._free][:need]
            if len(take) < need:
                raise KVStoreError(
                    f"pool exhausted: {need} blocks needed, hint has "
                    f"{len(take)} free")
        for b in take:
            self._free.remove(b)
            self._refs[b] = 1
        return take

    # -- derived sizes -------------------------------------------------------
    @property
    def block_bytes(self) -> float:
        return self.block_lines * self.costs.line_bytes

    def line_blocks_for(self, lines: int) -> int:
        n = -(-lines // self.block_lines) if lines > 0 else 0
        if self.max_blocks_per_seq is not None:
            n = min(n, self.max_blocks_per_seq)
        return n

    def blocks_for(self, lines: int) -> int:
        return self.line_blocks_for(lines) + (
            1 if self.costs.fixed_bytes > 0 else 0)

    # -- queries -------------------------------------------------------------
    def resident(self) -> List[int]:
        return sorted(self.tables)

    def lines(self, rid: int) -> int:
        if rid not in self.tables:
            raise KVStoreError(f"rid {rid} not resident in ledger")
        return self._lines[rid]

    def synced_line(self, rid: int) -> int:
        """Line up to which this store's copy of ``rid`` has been
        mirrored (== ``lines`` when current)."""
        if rid not in self.tables:
            raise KVStoreError(f"rid {rid} not resident in ledger")
        return self._synced[rid]

    def delta_since(self, rid: int, line: int) -> Tuple[int, int]:
        """The ``(from_line, to_line)`` half-open range of lines a mirror
        holding ``line`` lines is missing."""
        to = self.lines(rid)
        return (min(line, to), to)

    def free_blocks(self) -> int:
        return len(self._free)

    def used_blocks(self) -> int:
        # counted from the refcounts (not num_blocks - free): a shared
        # block is one block however many tables reference it, and a
        # non-strict ledger can overcommit past the nominal pool size
        return len(self._refs)

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    def shared_head_lines(self, rid: int) -> int:
        """Head lines of ``rid`` backed by blocks adopted from a resident
        prefix (0 for an unshared request)."""
        return self._shared_head.get(rid, 0) if rid in self.tables else 0

    def shared_blocks_count(self) -> int:
        """Distinct blocks currently referenced by more than one holder."""
        return sum(1 for c in self._refs.values() if c > 1)

    def shared_saved_blocks(self) -> int:
        """Pool blocks *not* consumed thanks to sharing: Σ (refs − 1).
        Each extra reference is a block a share-blind allocator would
        have paid for."""
        return sum(c - 1 for c in self._refs.values() if c > 1)

    def used_bytes_of(self, rid: int) -> float:
        return self.costs.bytes_at(self.lines(rid))

    def used_bytes(self) -> float:
        """Line-exact resident state bytes (Σ ``state_bytes_at``), the
        quantity the balancer and admission compare.  Computed from the
        running line total: line counts are exact integers in float64
        (far below 2**53), so one multiply equals the per-request sum
        bit for bit — and the call is O(1), not O(resident)."""
        return (self.costs.line_bytes * self._tot_lines
                + self.costs.fixed_bytes * len(self._lines))

    def can_alloc(self, lines: int) -> bool:
        return self.blocks_for(lines) <= len(self._free)

    # -- mutations -----------------------------------------------------------
    def alloc(self, rid: int, lines: int = 0, *,
              block_ids: Optional[List[int]] = None,
              synced: Optional[int] = None,
              shared: Optional[List[int]] = None) -> List[int]:
        """Admit ``rid`` at ``lines`` KV lines; returns the block ids
        backing it (fixed block first, if any).  ``block_ids`` lets a
        placement-aware caller (the live store's slot-affine layout) pick
        specific blocks from the free pool.  ``shared`` adopts
        already-referenced blocks (a resident prefix) as the head of the
        table: their refcounts go up, no pool headroom is consumed."""
        if rid in self.tables:
            raise KVStoreError(f"rid {rid} already resident")
        shared = list(shared or [])
        n_line = self.line_blocks_for(lines)
        if len(shared) > n_line:
            raise KVStoreError(
                f"rid {rid}: {len(shared)} shared blocks exceed the "
                f"{n_line} line blocks for {lines} lines")
        bad = [b for b in shared if b not in self._refs]
        if bad:
            raise KVStoreError(f"shared blocks {bad} are not referenced")
        need = (n_line - len(shared)) + (
            1 if self.costs.fixed_bytes > 0 else 0)
        if block_ids is not None:
            try:
                take = self._take_hinted(need, block_ids, exact=True)
            except KVStoreError as e:
                raise KVStoreError(f"rid {rid}: {e}") from None
        else:
            take = self._take(need)
        for b in shared:
            self._refs[b] += 1
        fixed = take[0] if self.costs.fixed_bytes > 0 else None
        self.fixed_block[rid] = fixed
        self.tables[rid] = shared + (take[1:] if fixed is not None
                                     else take)
        self._lines[rid] = lines
        self._tot_lines += lines
        self._synced[rid] = lines if synced is None else synced
        if shared:
            self._shared_head[rid] = min(lines,
                                         len(shared) * self.block_lines)
        return take

    def append_line(self, rid: int, n: int = 1,
                    *, block_ids: Optional[List[int]] = None) -> int:
        """Grow ``rid`` by ``n`` lines, pulling new blocks from the pool
        on boundary crossings; returns the new line count.

        Copy-on-write: if the append starts inside a *shared* tail block
        (refcount > 1), the writer first swaps in a private replacement
        block — recorded in ``last_cow`` — so the other referents keep
        the original bytes."""
        old = self.lines(rid)
        new = old + n
        table = self.tables[rid]
        self.last_cow = None
        if (old % self.block_lines != 0 and table
                and self._refs[table[-1]] > 1):
            old_b = table[-1]
            if block_ids is not None:
                repl = self._take_hinted(1, block_ids, exact=False)[0]
            else:
                repl = self._take(1)[0]
            table[-1] = repl
            self._decref(old_b)
            self.last_cow = (rid, old_b, repl)
            if self._shared_head.get(rid, 0) > (len(table) - 1) \
                    * self.block_lines:
                self._shared_head[rid] = (len(table) - 1) \
                    * self.block_lines
        need = self.line_blocks_for(new) - len(table)
        if need > 0:
            if block_ids is not None:
                try:
                    grab = self._take_hinted(need, block_ids, exact=False)
                except KVStoreError:
                    raise KVStoreError(
                        f"pool exhausted growing rid {rid} to {new} "
                        f"lines") from None
            else:
                grab = self._take(need)
            table.extend(grab)
        self._lines[rid] = new
        self._tot_lines += n
        return new

    def set_lines(self, rid: int, lines: int,
                  *, block_ids: Optional[List[int]] = None) -> int:
        """Reconcile ``rid`` to an absolute line count (simulator resync
        path); grows like :meth:`append_line`, never shrinks blocks."""
        cur = self.lines(rid)
        if lines > cur:
            return self.append_line(rid, lines - cur, block_ids=block_ids)
        self._lines[rid] = lines
        self._tot_lines += lines - cur
        return lines

    def mark_synced(self, rid: int, line: Optional[int] = None):
        self._synced[rid] = self.lines(rid) if line is None else line

    def _decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block actually left
        the pool's used set (last referent)."""
        c = self._refs.get(block)
        if c is None:
            raise KVStoreError(f"block {block} is not referenced")
        if c > 1:
            self._refs[block] = c - 1
            return False
        del self._refs[block]
        # overflow ids (non-strict overcommit) evaporate; real ids return
        if block < self.num_blocks:
            self._free.append(block)
        return True

    def retain(self, blocks: List[int]):
        """External holder (the prefix cache) takes a reference on each
        block, keeping it alive past its last table."""
        bad = [b for b in blocks if b not in self._refs]
        if bad:
            raise KVStoreError(f"cannot retain free blocks {bad}")
        for b in blocks:
            self._refs[b] += 1

    def release(self, blocks: List[int]) -> int:
        """Drop one external reference per block; returns how many blocks
        actually returned to the pool."""
        return sum(1 for b in blocks if self._decref(b))

    def free(self, rid: int) -> int:
        """Release ``rid``'s references; returns the number of blocks
        *actually* freed back to the pool (shared blocks with surviving
        referents don't count — eviction of a shared-prefix replica only
        reclaims its unique suffix)."""
        if rid not in self.tables:
            raise KVStoreError(f"rid {rid} not resident in ledger")
        blocks = self.tables.pop(rid)
        fixed = self.fixed_block.pop(rid)
        if fixed is not None:
            blocks = [fixed] + blocks
        self._tot_lines -= self._lines.pop(rid)
        self._synced.pop(rid)
        self._shared_head.pop(rid, None)
        return sum(1 for b in blocks if self._decref(b))
