"""PagedStore: the live engine's block-table KV/state store.

Owns the serving-state arrays for one instance (the pytree
``repro.models.init_state`` builds) *and* the block ledger over them.
Physical layout is **slot-affine**: each request slot owns a contiguous
region of the pool — one fixed block for its recurrent/static state
(when the architecture has any) followed by ``kv_capacity /
block_lines`` line blocks backing rows of the dense cache window — so
the model's layer-scan state layout is untouched while allocation,
headroom and eviction are block-granular.  The block tables this yields
are real: :meth:`line_block_table` feeds the paged decode-attention
kernel (``repro.kernels.decode_attention.paged_decode_attention_pallas``)
which gathers K/V through them on the TPU path.

The store executes the two redundancy data movements in *line* units:

* :meth:`copy_lines` — the per-step mirror: only the KV rows in
  ``[from_line-1, to_line-1)`` move (accounting lines count the reserved
  next-token line, hence the -1 shift to written rows; see
  ``kvstore.base``), plus the constant-size recurrent states.  O(delta)
  per step, not O(kv_capacity).
* :meth:`stream_slot` / :meth:`import_chunk` — whole-state transfers as
  per-layer chunks, the unit the mesh overlaps with prefill compute
  (AcceLLM §4.2.4).

When the two stores live on different mesh slices (``repro.meshserve``:
each instance's pool is committed to its own device set), both movements
switch from the slice-local copy jits to the collective pulls in
``repro.meshserve.collectives`` — gather on the source slice, one
device-to-device hop, scatter on the destination — so redundancy traffic
never bounces through the host.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kvstore.base import BlockLedger, KVStoreError, LineCosts
from repro.models import init_state
from repro.models.blocks import layer_specs, plan_segments

#: attention-state keys indexed by KV line (axis 2 of the stacked leaf)
LINE_KEYS = ("k", "v", "c_kv", "k_rope")
#: attention-state keys written once at prefill (enc-dec cross caches)
STATIC_KEYS = ("xk", "xv")


def pick_block_lines(kv_capacity: int, requested: int = 16) -> int:
    """Largest divisor of the cache window that is <= ``requested``."""
    b = max(1, min(requested, kv_capacity))
    while kv_capacity % b:
        b -= 1
    return b


# jitted copy primitives for the mirror hot path: slot indices and row
# positions are traced (one compile per (shape, n_rows), reused every
# step); the destination buffer is donated so the update is in place.


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_rows(dst, src, dst_slot, src_slot, pos):
    return dst.at[:, dst_slot, pos].set(src[:, src_slot, pos])


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_entry(dst, src, dst_slot, src_slot):
    return dst.at[:, dst_slot].set(src[:, src_slot])


# intra-store gather for prefix adoption: source rows live in *other*
# slots' windows (one per shared block), so the row vector carries its
# own per-row slot index; not donated — src and dst are the same buffer
@jax.jit
def _gather_rows(arr, dst_slot, src_slots, src_pos, dst_pos):
    return arr.at[:, dst_slot, dst_pos].set(arr[:, src_slots, src_pos])


def _colocated(a, b) -> bool:
    """Whether two leaves share a device set (the slice-local fast
    path); differing sets route through the meshserve collectives."""
    sa = getattr(a, "sharding", None)
    sb = getattr(b, "sharding", None)
    if sa is None or sb is None:
        return True
    return sa.device_set == sb.device_set


class PagedStore:
    def __init__(self, cfg: ModelConfig, num_slots: int, kv_capacity: int,
                 block_lines: Optional[int] = None,
                 dtype_name: Optional[str] = None):
        self.cfg = cfg
        self.num_slots = num_slots
        self.kv_capacity = kv_capacity
        if block_lines is not None and kv_capacity % block_lines:
            # an explicit geometry request must not be silently rounded
            raise KVStoreError(
                f"block_lines {block_lines} does not divide "
                f"kv_capacity {kv_capacity}")
        self.block_lines = pick_block_lines(kv_capacity, block_lines or 16)
        self.costs = LineCosts.from_config(cfg)
        self.line_blocks_per_slot = kv_capacity // self.block_lines
        self._has_fixed = self.costs.fixed_bytes > 0
        self.blocks_per_slot = self.line_blocks_per_slot + (
            1 if self._has_fixed else 0)
        self.ledger = BlockLedger(
            self.costs, num_blocks=num_slots * self.blocks_per_slot,
            block_lines=self.block_lines,
            max_blocks_per_seq=self.line_blocks_per_slot)
        self.state = init_state(cfg, num_slots, kv_capacity,
                                dtype_name=dtype_name)
        self.slot_rid: Dict[int, int] = {}
        self.rid_slot: Dict[int, int] = {}
        # leaf classification: (segment index, part key, leaf key, kind)
        self._paths: List[Tuple[int, str, str, str]] = []
        for i, seg in enumerate(plan_segments(layer_specs(cfg))):
            for j, spec in enumerate(seg.specs):
                for key in self.state["layers"][i][f"p{j}"]:
                    if spec.block == "attn":
                        kind = "line" if key in LINE_KEYS else "static"
                    else:
                        kind = "recurrent"
                    self._paths.append((i, f"p{j}", key, kind))

    # -- capacity ------------------------------------------------------------
    @property
    def capacity_bytes(self) -> float:
        """Accounting capacity: every slot filled to the cache window."""
        return self.num_slots * self.costs.bytes_at(self.kv_capacity)

    def used_bytes(self) -> float:
        return self.ledger.used_bytes()

    def used_bytes_of(self, rid: int) -> float:
        return self.ledger.used_bytes_of(rid)

    def free_bytes(self) -> float:
        return self.capacity_bytes - self.ledger.used_bytes()

    def free_blocks(self) -> int:
        return self.ledger.free_blocks()

    # -- block tables ----------------------------------------------------------
    def slot_block_ids(self, slot: int) -> List[int]:
        lo = slot * self.blocks_per_slot
        return list(range(lo, lo + self.blocks_per_slot))

    def line_block_table(self, rid: int) -> List[int]:
        """Physical *line-block* ids of ``rid`` in pool numbering (the
        dense caches reshaped to ``(num_slots * kv_capacity/block_lines,
        block_lines, ...)``), the table the paged decode kernel gathers
        through."""
        off = 1 if self._has_fixed else 0
        out = []
        for b in self.ledger.tables[rid]:
            slot, k = divmod(b, self.blocks_per_slot)
            out.append(slot * self.line_blocks_per_slot + (k - off))
        return out

    def decode_block_tables(self, rids: List[int], blocks: int):
        """Padded ``(len(rids), blocks)`` int32 block tables for the
        paged decode kernel.  Slot-affine placement makes each row the
        identity run over its slot's pool region — the blocks the ring
        window will hand the request as it grows — so one table covers
        a whole fused multi-step scan without re-planning mid-scan
        (``line_block_table`` returns exactly the allocated prefix of
        this run).  Entries past a request's live lines are masked by
        the kernel's ``lengths`` scalar, never read as valid KV."""
        import numpy as np
        blocks = min(blocks, self.line_blocks_per_slot)
        out = np.empty((len(rids), blocks), np.int32)
        for i, rid in enumerate(rids):
            base = self.rid_slot[rid] * self.line_blocks_per_slot
            out[i] = np.arange(base, base + blocks, dtype=np.int32)
        return out

    def pool_view(self, arr: jnp.ndarray) -> jnp.ndarray:
        """Reshape one request-batched cache leaf ``(B, W, ...)`` into the
        block pool ``(B * W/block_lines, block_lines, ...)`` addressed by
        :meth:`line_block_table`."""
        B, W = arr.shape[:2]
        return arr.reshape((B * (W // self.block_lines), self.block_lines)
                           + arr.shape[2:])

    # -- ledger ops (slot-affine) ----------------------------------------------
    def alloc(self, rid: int, slot: int, lines: int,
              synced: Optional[int] = None,
              shared: Optional[List[int]] = None) -> None:
        """Admit ``rid`` into ``slot``.  ``shared`` adopts a resident
        prefix's blocks (anywhere in the pool) as the table head; the
        slot's *own* region then backs only the suffix — but note the
        physical contract: the dense window stays self-contained, so the
        caller must also :meth:`copy_prefix` the shared rows into the
        slot's window.  The slot's own blocks shadowed by the shared
        head (logical positions ``[0, len(shared))``) hold those copied
        rows and stay OFF the ledger — the ledger is the accounting
        truth, and sharing is exactly the HBM it saves."""
        if slot in self.slot_rid:
            raise KVStoreError(f"slot {slot} already backs "
                               f"rid {self.slot_rid[slot]}")
        ids = self.slot_block_ids(slot)
        off = 1 if self._has_fixed else 0
        n_shared = len(shared) if shared else 0
        if n_shared:
            if n_shared * self.block_lines > min(lines, self.kv_capacity):
                raise KVStoreError(
                    f"rid {rid}: shared head {n_shared} blocks exceeds "
                    f"{lines} lines (hits must be block-aligned)")
            hint = ids[:off] + ids[off + n_shared:]
        else:
            hint = ids
        self.ledger.alloc(rid, lines, block_ids=hint, synced=synced,
                          shared=shared)
        self.slot_rid[slot] = rid
        self.rid_slot[rid] = slot

    def _grow_hint(self, rid: int) -> List[int]:
        """Free own-region blocks for the *next* logical positions of
        ``rid`` — skipping the positions shadowed by a shared head, whose
        own blocks hold the copied prefix rows and must never be handed
        out as growth."""
        slot = self.rid_slot[rid]
        ids = self.slot_block_ids(slot)
        off = 1 if self._has_fixed else 0
        return ids[off + len(self.ledger.tables[rid]):]

    def append_line(self, rid: int, n: int = 1) -> int:
        out = self.ledger.append_line(rid, n,
                                      block_ids=self._grow_hint(rid))
        if self.ledger.last_cow is not None:
            raise KVStoreError(
                f"rid {rid}: copy-on-write inside the slot-affine store "
                f"(shared heads must be block-aligned)")
        return out

    def set_lines(self, rid: int, lines: int) -> int:
        cur = self.ledger.lines(rid)
        if lines > cur:
            return self.append_line(rid, lines - cur)
        return self.ledger.set_lines(rid, lines)

    def free_slot(self, slot: int) -> int:
        """Release the slot's request; returns blocks *actually* freed
        (shared blocks survive under their other referents)."""
        rid = self.slot_rid.pop(slot, None)
        if rid is None:
            return 0
        self.rid_slot.pop(rid)
        return self.ledger.free(rid)

    def slot_used_blocks(self, slot: int) -> List[int]:
        """Own-region blocks still referenced (by a table or the prefix
        cache) — a slot is reusable for fresh prefill only once this is
        empty."""
        return [b for b in self.slot_block_ids(slot)
                if self.ledger.refcount(b) > 0]

    def shared_head_lines(self, rid: int) -> int:
        return self.ledger.shared_head_lines(rid)

    def shared_saved_bytes(self) -> float:
        """HBM the refcounted prefix sharing avoids allocating:
        Σ (refs − 1) blocks at block granularity."""
        return self.ledger.shared_saved_blocks() * self.ledger.block_bytes

    def lines(self, rid: int) -> int:
        return self.ledger.lines(rid)

    def synced_line(self, rid: int) -> int:
        return self.ledger.synced_line(rid)

    def delta_since(self, rid: int, line: int) -> Tuple[int, int]:
        return self.ledger.delta_since(rid, line)

    def mark_synced(self, rid: int, line: Optional[int] = None):
        self.ledger.mark_synced(rid, line)

    # -- whole-slot state movement ---------------------------------------------
    def extract_slot(self, slot: int):
        """Per-request state (batch dim kept, size 1)."""

        def ex(a):
            return a[:, slot: slot + 1]

        out = {"layers": jax.tree_util.tree_map(ex, self.state["layers"])}
        if "enc_out" in self.state:
            out["enc_out"] = self.state["enc_out"][slot: slot + 1]
        return out

    def merge_slot(self, slot: int, sub_state, src_slot: int = 0):
        """Install ``sub_state`` (batch dim 1 at ``src_slot``) into
        ``slot``, whole-window (row bounds clamp to the smaller of the
        two cache windows).  Batch is dim 1 for layer states (dim 0 is
        the segment repeat dim) and dim 0 for ``enc_out``."""
        self.merge_slot_rows(slot, sub_state, 0, self.kv_capacity,
                             src_slot=src_slot)

    def merge_slot_rows(self, slot: int, sub_state, lo: int, hi: int,
                        src_slot: int = 0):
        """Install ``sub_state``'s batch row ``src_slot`` into ``slot``,
        copying only KV rows ``[lo, hi)`` of the line-indexed leaves —
        the merge for bucket-sized prefill scratch (whose cache window
        may be smaller than the store's) and for resumed chunk writes.
        Recurrent and static leaves copy whole; row bounds clamp to
        whichever window is smaller."""
        for i, pj, key, kind in self._paths:
            dst = self.state["layers"][i][pj][key]
            src = sub_state["layers"][i][pj][key]
            if not _colocated(dst, src):
                from repro.meshserve import collectives
                src = collectives.device_transfer(src, dst)
            if kind == "line":
                h = min(hi, src.shape[2], dst.shape[2])
                l = min(lo, h)
                if h <= l:
                    continue
                self.state["layers"][i][pj][key] = dst.at[
                    :, slot, l:h].set(src[:, src_slot, l:h])
            else:
                self.state["layers"][i][pj][key] = dst.at[:, slot].set(
                    src[:, src_slot])
        if "enc_out" in self.state:
            enc = sub_state["enc_out"]
            if not _colocated(self.state["enc_out"], enc):
                from repro.meshserve import collectives
                enc = collectives.device_transfer(enc, self.state["enc_out"])
            self.state["enc_out"] = self.state["enc_out"].at[slot].set(
                enc[src_slot])

    # -- per-layer streamed transfer (§4.2.4) ----------------------------------
    def stream_slot(self, slot: int) -> Iterator[Tuple[tuple, jnp.ndarray]]:
        """Yield ``slot``'s state one layer-part leaf at a time — the
        chunk granularity a real mesh overlaps with prefill compute."""
        for i, pj, key, _ in self._paths:
            yield ((i, pj, key), self.state["layers"][i][pj][key]
                   [:, slot: slot + 1])
        if "enc_out" in self.state:
            yield (("enc_out",), self.state["enc_out"][slot: slot + 1])

    def import_chunk(self, slot: int, path: tuple, chunk: jnp.ndarray):
        if path[0] == "enc_out":
            target = self.state["enc_out"]
            if not _colocated(target, chunk):
                from repro.meshserve import collectives
                chunk = collectives.device_transfer(chunk, target)
            self.state["enc_out"] = target.at[slot].set(chunk[0])
            return
        i, pj, key = path
        arr = self.state["layers"][i][pj][key]
        if not _colocated(arr, chunk):
            # per-layer chunk arriving from another mesh slice: one
            # device-to-device hop, then the write is slice-local
            from repro.meshserve import collectives
            chunk = collectives.device_transfer(chunk, arr)
        self.state["layers"][i][pj][key] = arr.at[:, slot].set(chunk[:, 0])

    # -- delta line copy (the §4.1.2 mirror) -----------------------------------
    def copy_lines(self, src: "PagedStore", src_slot: int, dst_slot: int,
                   from_line: int, to_line: int) -> float:
        """Copy only the KV rows of accounting lines ``[from_line,
        to_line)`` from ``src``'s slot into ours, plus the constant-size
        recurrent states; returns the bytes moved.  Accounting line ``L``
        reserves physical row ``L-1`` (the newest sampled token's KV is
        written by the *next* decode step), so rows ``[from_line-1,
        to_line-1)`` move, modulo the ring-buffer window."""
        lo, hi = max(0, from_line - 1), max(0, to_line - 1)
        n_rows = hi - lo
        d_slot = jnp.int32(dst_slot)
        s_slot = jnp.int32(src_slot)
        for i, pj, key, kind in self._paths:
            if kind == "static":
                continue
            dst_arr = self.state["layers"][i][pj][key]
            src_arr = src.state["layers"][i][pj][key]
            local = _colocated(dst_arr, src_arr)
            if not local:
                from repro.meshserve import collectives
            if kind == "recurrent":
                self.state["layers"][i][pj][key] = (
                    _copy_entry(dst_arr, src_arr, d_slot, s_slot) if local
                    else collectives.pull_entry(dst_arr, src_arr,
                                                dst_slot, src_slot))
                continue
            if n_rows <= 0:
                continue
            cap = dst_arr.shape[2]
            pos = jnp.asarray([p % cap for p in range(lo, hi)], jnp.int32)
            self.state["layers"][i][pj][key] = (
                _copy_rows(dst_arr, src_arr, d_slot, s_slot, pos) if local
                else collectives.pull_rows(dst_arr, src_arr,
                                           dst_slot, src_slot, pos))
        return self.costs.mirror_bytes(max(0, to_line - from_line))

    # -- prefix adoption (one-time window fill) --------------------------------
    def copy_prefix(self, blocks: List[int], dst_slot: int,
                    n_lines: int) -> float:
        """Materialise a shared prefix run into ``dst_slot``'s dense
        window rows ``[0, n_lines)``.

        The slot-affine layout keeps each window self-contained (the
        layer scan reads its slot's rows directly), so adopting blocks
        from other slots' regions is a one-time intra-HBM row gather —
        data movement instead of prefill *compute*.  The ledger, not the
        window, is the accounting truth: the adopted blocks stay shared
        there and the shadowed own rows stay off-ledger.  Returns the
        bytes gathered (reported separately from mirror/stream traffic;
        never charged as prefill)."""
        import numpy as np
        n_lines = min(n_lines, self.kv_capacity,
                      len(blocks) * self.block_lines)
        if n_lines <= 0:
            return 0.0
        off = 1 if self._has_fixed else 0
        src_slots = np.empty((n_lines,), np.int32)
        src_pos = np.empty((n_lines,), np.int32)
        for i in range(n_lines):
            b = blocks[i // self.block_lines]
            slot, k = divmod(b, self.blocks_per_slot)
            src_slots[i] = slot
            src_pos[i] = (k - off) * self.block_lines \
                + i % self.block_lines
        dst_pos = np.arange(n_lines, dtype=np.int32)
        d_slot = jnp.int32(dst_slot)
        for i, pj, key, kind in self._paths:
            if kind != "line":
                continue
            arr = self.state["layers"][i][pj][key]
            self.state["layers"][i][pj][key] = _gather_rows(
                arr, d_slot, jnp.asarray(src_slots),
                jnp.asarray(src_pos), jnp.asarray(dst_pos))
        return self.costs.line_bytes * n_lines
