"""SimStore: pure block accounting for the discrete-event simulator.

One store per :class:`repro.sim.cluster.SimInstance`, holding the ledger
for every request resident there (decode primaries *and* replicas — the
replica-memory undercounting of the old ad-hoc accounting is impossible
by construction).  The simulator mutates its ``decode_batch`` /
``replicas`` dicts at event granularity (and some consistency tests
drive those dicts directly, bypassing the event loop), so the store
reconciles ledger membership and line counts from them lazily on read:
the *costs* and the *ledger arithmetic* are shared with the live
``PagedStore``, the event mechanics stay the simulator's own.
"""
from __future__ import annotations

from typing import List, Mapping, Optional

from repro.kvstore.base import BlockLedger, LineCosts


class SimStore:
    def __init__(self, costs: LineCosts, capacity_bytes: float,
                 block_lines: int = 16,
                 max_blocks: int = 1 << 18):
        self.costs = costs
        self.capacity_bytes = float(capacity_bytes)
        block_bytes = block_lines * costs.line_bytes
        if block_bytes <= 0:
            # pure-recurrent architecture: blocks hold fixed states only
            block_bytes = max(costs.fixed_bytes, 1)
        # strict=False: the simulator admits on BYTE headroom (its decode
        # batch is elastic; §4.2.5 pressure is handled by eviction), so
        # block rounding + fixed blocks may overcommit the nominal pool —
        # the ledger then mints overflow ids and free_blocks() reads 0
        # instead of crashing an accounting query mid-run.
        self.ledger = BlockLedger(
            costs, num_blocks=min(max_blocks,
                                  int(self.capacity_bytes // block_bytes)),
            block_lines=block_lines, strict=False)

    # -- reconciliation ------------------------------------------------------
    def reconcile(self, resident: Mapping[int, int],
                  synced: Optional[Mapping[int, int]] = None,
                  shared: Optional[Mapping[int, List[int]]] = None):
        """Make ledger membership and line counts match ``resident``
        (rid -> current KV lines).  ``synced`` optionally pins mirror
        marks; by default every entry is considered current (the
        simulator executes the mirror implicitly inside the decode-step
        cost, so a replica is never more than in-flight-one-step
        behind).  ``shared`` maps rids to prefix-cache block runs adopted
        as their table heads — alloc/free stay symmetric on the
        refcounts, so a prefix-hit request prices exactly its unique
        suffix here just as it does on the live store."""
        led = self.ledger
        for rid in list(led.tables):
            if rid not in resident:
                led.free(rid)
        for rid, lines in resident.items():
            if rid in led.tables:
                led.set_lines(rid, lines)
            else:
                led.alloc(rid, lines,
                          shared=(shared or {}).get(rid))
            led.mark_synced(rid, None if synced is None
                            else synced.get(rid))
        return self

    # -- queries (post-reconcile ledger pass-throughs) -----------------------
    def used_bytes(self) -> float:
        return self.ledger.used_bytes()

    def used_bytes_of(self, rid: int) -> float:
        return self.ledger.used_bytes_of(rid)

    def free_bytes(self) -> float:
        return self.capacity_bytes - self.ledger.used_bytes()

    def free_blocks(self) -> int:
        return self.ledger.free_blocks()

    def lines(self, rid: int) -> int:
        return self.ledger.lines(rid)

    def delta_since(self, rid: int, line: int):
        return self.ledger.delta_since(rid, line)

    def mirror_bytes_per_step(self, n_mirrored: int) -> float:
        """Per-decode-step replica-update traffic: one new KV line (plus
        the constant recurrent state) per mirrored request — the ledger
        quantity the live executor also charges."""
        return n_mirrored * self.costs.mirror_bytes(1)
