"""Backend-agnostic paged KV/state store (the redundancy ledger).

AcceLLM prices its whole redundancy mechanism in *KV lines* (§4.1.2 —
"newly computed KV cache lines are transferred back"): per-decode-step
mirror traffic is one new line, post-prefill streaming is per-layer
overlapped, eviction frees replica bytes.  This package is the single
home of that accounting:

* :class:`LineCosts` — bytes-per-line / fixed-state costs derived from
  :mod:`repro.core.kvbytes` (one formula, both backends).
* :class:`BlockLedger` — a fixed-size block pool with per-request block
  tables: ``alloc / append_line / free / delta_since`` plus used-byte and
  free-block headroom queries.
* :class:`PagedStore` — the live implementation: owns the engine's
  serving-state arrays, executes delta line copies and per-layer
  streamed transfers on them, slot-affine block placement.
* :class:`SimStore` — pure block accounting for the discrete-event
  simulator, charged from the identical ledger.

The live engine (:class:`repro.serving.InstanceEngine`) and the
simulator (:class:`repro.sim.cluster.SimInstance`) both expose these
numbers through :mod:`repro.scheduling.views`, so the AcceLLM kernel's
admission, replica-budgeting and eviction decisions read the same ledger
on either backend.
"""
from repro.kvstore.base import BlockLedger, KVStoreError, LineCosts
from repro.kvstore.paged import PagedStore
from repro.kvstore.sim import SimStore

__all__ = ["BlockLedger", "KVStoreError", "LineCosts", "PagedStore",
           "SimStore"]
