"""AcceLLM cluster facade over the shared scheduling kernel.

The paper's §4 policy — pair routing, dynamic prefill/decode roles,
redundant-KV placement, count+state-bytes rebalancing, replica eviction —
lives in :class:`repro.scheduling.accellm.AcceLLMScheduler`; the mechanics
of driving real JAX engines live in
:class:`repro.scheduling.live.LiveCluster`.  This module keeps the
historical ``AcceLLMCluster`` entry point as a thin facade over the two,
plus the ``Pair``/``Placement`` structures older callers and tests use.

New code should go through :func:`repro.api.serve`, which can also run the
baseline policies (vllm / splitwise / sarathi) on live engines.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.live import LiveCluster, Placement
from repro.serving.engine import InstanceEngine

__all__ = ["AcceLLMCluster", "Pair", "Placement"]


class Pair:
    """Pair-local view of an instance pair (paper §4.2.1): exposes the
    two engines and the pair's placements with within-pair indices."""

    def __init__(self, a: InstanceEngine, b: InstanceEngine,
                 cluster: LiveCluster):
        self.a = a
        self.b = b
        self._cluster = cluster

    def engines(self):
        return (self.a, self.b)

    def free_capacity(self) -> int:
        return len(self.a.free_slots()) + len(self.b.free_slots())

    @property
    def placements(self) -> Dict[int, Placement]:
        local = {self.a.instance_id: 0, self.b.instance_id: 1}
        out: Dict[int, Placement] = {}
        for rid, pl in self._cluster.placements.items():
            inst, slot = pl.primary
            if inst not in local:
                continue
            replica = None
            if pl.replica is not None:
                replica = (local[pl.replica[0]], pl.replica[1])
            out[rid] = Placement(primary=(local[inst], slot), replica=replica)
        return out


class AcceLLMCluster(LiveCluster):
    """Deprecated construction shim: ``AcceLLMCluster(cfg, params, ...)``
    still works but is now sugar for ``LiveCluster(...,
    policy=AcceLLMScheduler(...))``; prefer ``repro.api.serve``."""

    def __init__(self, cfg: ModelConfig, params, n_instances: int,
                 num_slots: int, kv_capacity: int, *, redundancy: bool = True,
                 temperature: float = 0.0, eos_token: Optional[int] = None):
        warnings.warn(
            "AcceLLMCluster(...) is a compatibility facade; use "
            "repro.api.serve(ServeSpec(...)) for new code",
            DeprecationWarning, stacklevel=2)
        super().__init__(cfg, params, n_instances, num_slots, kv_capacity,
                         policy=AcceLLMScheduler(redundancy=redundancy),
                         temperature=temperature, eos_token=eos_token)
        self.redundancy = redundancy

    @property
    def pairs(self) -> List[Pair]:
        return [Pair(self.engines[i], self.engines[i + 1], self)
                for i in range(0, len(self.engines), 2)]
