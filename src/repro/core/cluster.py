"""AcceLLM cluster orchestrator over real InstanceEngines.

Implements the paper's §4 mechanism end-to-end on live JAX engines:
  * instances organized in pairs (§4.2.1),
  * the scheduling manager (§4.2.2): new requests go to the pair with the
    most free memory; inside a pair the less-loaded instance flips to
    prefill while its partner keeps decoding — never both phases on one
    instance in one iteration,
  * redundant KV caches (§4.1.2): after prefill the state streams to the
    partner (which becomes the primary decoder) while the prefilling
    instance *retains* its copy as the replica; during decode the newly
    generated KV lines are mirrored back into the replica,
  * load balancing (§4.1.3): when both instances decode, the pair's batch
    is re-split by count and state-bytes using zero-cost replica promotion,
  * graceful degradation (§4.2.5): replicas are evicted first under memory
    pressure.

The clock is the scheduling iteration (one decode step per active instance
per iteration); latency metrics are reported in iterations. The discrete-
event simulator in ``repro.sim`` maps the same policy onto wall-clock
device models.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.balancer import Item, partition, should_rebalance
from repro.core.kvbytes import decode_read_bytes
from repro.serving.engine import InstanceEngine
from repro.serving.request import Phase, Request


@dataclass
class Placement:
    primary: Tuple[int, int]                 # (instance idx, slot)
    replica: Optional[Tuple[int, int]] = None


@dataclass
class Pair:
    a: InstanceEngine
    b: InstanceEngine
    placements: Dict[int, Placement] = field(default_factory=dict)  # rid ->

    def engines(self):
        return (self.a, self.b)

    def free_capacity(self) -> int:
        return len(self.a.free_slots()) + len(self.b.free_slots())

    def decode_items(self, cfg: ModelConfig) -> List[Item]:
        items = []
        for rid, pl in self.placements.items():
            inst, slot = pl.primary
            eng = self.engines()[inst]
            req = eng.slot_req.get(slot)
            if req is None or req.phase is not Phase.DECODE:
                continue
            items.append(Item(
                rid=rid,
                weight=decode_read_bytes(cfg, req.total_len),
                home=inst,
                movable=pl.replica is not None))
        return items


class AcceLLMCluster:
    def __init__(self, cfg: ModelConfig, params, n_instances: int,
                 num_slots: int, kv_capacity: int, *, redundancy: bool = True,
                 temperature: float = 0.0, eos_token: Optional[int] = None):
        assert n_instances % 2 == 0, "AcceLLM organizes instances in pairs"
        self.cfg = cfg
        self.redundancy = redundancy
        self.engines = [
            InstanceEngine(cfg, params, num_slots, kv_capacity,
                           instance_id=i, temperature=temperature,
                           eos_token=eos_token)
            for i in range(n_instances)
        ]
        self.pairs = [Pair(self.engines[i], self.engines[i + 1])
                      for i in range(0, n_instances, 2)]
        self.queue: List[Tuple[Request, Optional[dict]]] = []
        self.now = 0.0
        self.finished: List[Request] = []
        self._submitted = []
        self.stats = {"prefills": 0, "decode_steps": 0, "rebalances": 0,
                      "replica_promotions": 0, "replica_evictions": 0,
                      "mirror_syncs": 0}

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request, extra: Optional[dict] = None):
        req.arrival = self.now
        self.queue.append((req, extra))
        self._submitted.append(req)

    _submitted: List[Request]

    # -- scheduling manager -----------------------------------------------------
    def _route_pair(self) -> Optional[Pair]:
        """Pair with the most free memory (paper §4.2.2)."""
        candidates = [p for p in self.pairs if self._pair_can_accept(p)]
        if not candidates:
            return None
        return max(candidates, key=lambda p: p.free_capacity())

    def _pair_can_accept(self, pair: Pair) -> bool:
        if any(e.free_slots() for e in pair.engines()):
            return True
        # memory pressure: a replica can be evicted to make room (§4.2.5)
        return any(pl.replica is not None for pl in pair.placements.values())

    def _make_room(self, pair: Pair) -> Optional[int]:
        """Return engine index with a free slot, evicting a replica if needed."""
        for i, e in enumerate(pair.engines()):
            if e.free_slots():
                return i
        # evict the replica of the longest request (most bytes freed)
        best = None
        for rid, pl in pair.placements.items():
            if pl.replica is not None:
                if best is None or rid < best:
                    best = rid
        if best is None:
            return None
        pl = pair.placements[best]
        inst, slot = pl.replica
        pair.engines()[inst].release(slot)
        pl.replica = None
        self.stats["replica_evictions"] += 1
        return inst

    # -- one scheduling iteration -------------------------------------------------
    def step(self):
        self.now += 1.0
        prefilling: Dict[int, bool] = {}

        # 1. prefill routing: one request per pair per iteration
        if self.queue:
            pair = self._route_pair()
            if pair is not None:
                req, extra = self.queue.pop(0)
                self._do_prefill(pair, req, extra, prefilling)

        # 2. decode on every instance not prefilling this iteration
        for pair in self.pairs:
            for eng in pair.engines():
                if prefilling.get(eng.instance_id):
                    continue
                # stamp token timing for requests decoded this iteration
                live = [eng.slot_req[s] for s in eng.active_slots()]
                if eng.decode():
                    self.stats["decode_steps"] += 1
                for req in live:
                    req.token_times.append(self.now)
            self._post_decode(pair)

        # 4. mirror newly generated lines into replicas (§4.1.2)
        if self.redundancy:
            for pair in self.pairs:
                self._mirror(pair)

        # 5. pair-level load balancing via replica promotion (§4.1.3)
        for pair in self.pairs:
            self._rebalance(pair)

    def _do_prefill(self, pair: Pair, req: Request, extra, prefilling):
        side = self._make_room(pair)
        if side is None:
            self.queue.insert(0, (req, extra))
            return
        # dynamic role: the chosen side prefills, partner keeps decoding
        pre_eng = pair.engines()[side]
        partner_idx = 1 - side
        partner = pair.engines()[partner_idx]
        slot = pre_eng.prefill_request(req, extra)
        req.phase = Phase.DECODE
        req.first_token_time = self.now
        req.token_times.append(self.now)
        self.stats["prefills"] += 1
        prefilling[pre_eng.instance_id] = True
        placement = Placement(primary=(side, slot))
        # stream state to the partner: partner becomes the primary decoder,
        # the prefilling instance retains its copy as the replica (§4.1.2)
        if self.redundancy and partner.free_slots():
            psl = partner.free_slots()[0]
            partner.import_slot(psl, pre_eng.export_slot(slot), req)
            pre_eng.demote_to_replica(slot, of=(partner.instance_id, psl))
            placement = Placement(primary=(partner_idx, psl),
                                  replica=(side, slot))
        pair.placements[req.rid] = placement

    def _post_decode(self, pair: Pair):
        """Release placements of finished requests (primary slot already
        freed by the engine; drop the replica too)."""
        for rid, pl in list(pair.placements.items()):
            inst, slot = pl.primary
            eng = pair.engines()[inst]
            req = eng.slot_req.get(slot)
            if req is None or req.rid != rid:        # finished & released
                if pl.replica is not None:
                    r_inst, r_slot = pl.replica
                    pair.engines()[r_inst].release(r_slot)
                del pair.placements[rid]

    def _mirror(self, pair: Pair):
        for rid, pl in pair.placements.items():
            if pl.replica is None:
                continue
            p_inst, p_slot = pl.primary
            r_inst, r_slot = pl.replica
            src = pair.engines()[p_inst]
            dst = pair.engines()[r_inst]
            if p_slot in src.slot_req:
                dst.sync_replica_from(src, p_slot, r_slot)
                self.stats["mirror_syncs"] += 1

    def _rebalance(self, pair: Pair):
        items = pair.decode_items(self.cfg)
        if not should_rebalance(items):
            return
        _, _, moves = partition(items)
        for rid, src_i, dst_i in moves:
            pl = pair.placements[rid]
            if pl.replica is None:
                continue
            src = pair.engines()[src_i]
            dst = pair.engines()[dst_i]
            p_slot = pl.primary[1]
            r_slot = pl.replica[1]
            req = src.slot_req[p_slot]
            # zero-cost migration: promote replica, demote primary
            dst.promote_replica(r_slot, req)
            src.demote_to_replica(p_slot, of=(dst.instance_id, r_slot))
            pair.placements[rid] = Placement(primary=(dst_i, r_slot),
                                             replica=(src_i, p_slot))
            self.stats["replica_promotions"] += 1
        if moves:
            self.stats["rebalances"] += 1

    # -- driver ---------------------------------------------------------------
    def pending(self) -> int:
        live = len(self.queue)
        for pair in self.pairs:
            live += len(pair.placements)
        return live

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while self.pending() and steps < max_steps:
            self.step()
            # stamp finish times for anything that completed this iteration
            # (including requests that finish in their very first step)
            for req in self._submitted:
                if req.phase is Phase.DONE and req.finish_time is None:
                    req.finish_time = self.now
                    self.finished.append(req)
            steps += 1
        return self.finished
