"""Decode load balancing across an instance pair (AcceLLM §4.1.3).

Pure policy: given the requests currently decoded by the two instances of a
pair (each with a state-bytes weight), produce a balanced re-assignment that
equalizes (a) per-instance batch size and (b) per-instance total state
bytes read per step. With full KV redundancy every move is free; without a
replica a move costs a KV transfer, so only replica-backed moves are taken.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Set, Tuple


@dataclass(frozen=True)
class Item:
    rid: int
    weight: float          # state bytes read per decode step
    home: int              # current instance (0 or 1 within the pair)
    movable: bool = True   # replica exists on the other side


def partition(items: Sequence[Item], count_tol: int = 1
              ) -> Tuple[Set[int], Set[int], List[Tuple[int, int, int]]]:
    """LPT-style greedy: heaviest first onto the lighter side, under a batch
    count constraint (|n0 - n1| <= count_tol). Immovable items stay home.

    Returns (side0 rids, side1 rids, moves [(rid, src, dst), ...]).
    """
    side: Dict[int, Set[int]] = {0: set(), 1: set()}
    load = [0.0, 0.0]
    fixed = [it for it in items if not it.movable]
    free = sorted((it for it in items if it.movable),
                  key=lambda it: -it.weight)
    for it in fixed:
        side[it.home].add(it.rid)
        load[it.home] += it.weight
    total = len(items)
    cap = max(1, (total + count_tol) // 2)
    for it in free:
        pick = 0 if load[0] <= load[1] else 1
        if len(side[pick]) >= cap and len(side[1 - pick]) < cap:
            pick = 1 - pick
        side[pick].add(it.rid)
        load[pick] += it.weight
    moves = []
    by_rid = {it.rid: it for it in items}
    for dst in (0, 1):
        for rid in side[dst]:
            if by_rid[rid].home != dst:
                moves.append((rid, by_rid[rid].home, dst))
    return side[0], side[1], moves


def imbalance(items: Sequence[Item]) -> Tuple[int, float]:
    """(batch count delta, state-bytes delta) of the current placement."""
    n = [0, 0]
    w = [0.0, 0.0]
    for it in items:
        n[it.home] += 1
        w[it.home] += it.weight
    return abs(n[0] - n[1]), abs(w[0] - w[1])


def should_rebalance(items: Sequence[Item], count_trigger: int = 2,
                     bytes_trigger_frac: float = 0.2) -> bool:
    """Trigger when counts drift by >= count_trigger or state bytes by more
    than bytes_trigger_frac of the total."""
    if not items:
        return False
    n = [0, 0]
    w = [0.0, 0.0]
    for it in items:
        n[it.home] += 1
        w[it.home] += it.weight
    return should_rebalance_agg(n[0], n[1], w[0], w[1],
                                count_trigger, bytes_trigger_frac)


def should_rebalance_agg(n0: int, n1: int, w0: float, w1: float,
                         count_trigger: int = 2,
                         bytes_trigger_frac: float = 0.2) -> bool:
    """The :func:`should_rebalance` trigger from per-side aggregates —
    for callers (the vectorized kernels) that keep counts and byte sums
    incrementally and only materialize Items once a rebalance fires.
    Weights are exact integers in float64, so aggregate sums equal the
    per-item accumulation bit for bit."""
    if n0 + n1 == 0:
        return False
    total_w = (w0 + w1) or 1.0
    return (abs(n0 - n1) >= count_trigger
            or abs(w0 - w1) / total_w > bytes_trigger_frac)
