"""Serving-state size accounting per architecture.

AcceLLM's scheduler balances decode batches by the *bytes of state read per
step* (decode is HBM-bandwidth-bound, §3.3) and its redundancy manager
budgets replica memory. Both need bytes-per-request as a function of the
current sequence length. For attention archs that is length-proportional
KV; for MLA it is the (much smaller) latent; for SSM blocks it is a
length-independent constant — which is why the balancer weights requests by
``state_bytes(cfg, length)`` rather than raw length (DESIGN.md §4).

These formulas are consumed through ``repro.kvstore.LineCosts``, the cost
card both the live ``PagedStore`` and the simulator's ``SimStore`` ledger
charge from — change them here and every backend reprices identically.

The per-config quantities are memoized: configs are frozen (hashable)
and these are pure functions of them, yet the simulator prices every
decode iteration through ``state_bytes_at`` — without the cache the
walk over ``block_pattern`` dominates million-request replays.
"""
from __future__ import annotations

from functools import lru_cache

from repro.configs.base import ModelConfig
from repro.models.state import xlstm_dims


@lru_cache(maxsize=None)
def bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    """KV-cache bytes added per token (attention layers only)."""
    n_attn = sum(1 for b in cfg.block_pattern if b == "attn")
    if cfg.attention_kind == "mla":
        per = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * dtype_bytes
    else:
        per = 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
    return n_attn * per


@lru_cache(maxsize=None)
def recurrent_state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Length-independent state that CHANGES every decode step
    (SSM/conv/xLSTM memories).  This is the constant-size per-step mirror
    payload for recurrent blocks (AcceLLM treats it as "one KV line" of
    fixed size)."""
    total = 0
    for blk in cfg.block_pattern:
        if blk == "mamba":
            mc = cfg.mamba
            d_in = mc.expand * cfg.d_model
            total += d_in * mc.d_state * 4          # ssm state f32
            total += mc.d_conv * d_in * dtype_bytes  # conv window
        elif blk == "mlstm":
            d_in, hd = xlstm_dims(cfg, "mlstm")
            h = cfg.num_heads
            total += (h * hd * hd + h * hd + h) * 4
            total += cfg.xlstm.conv1d_kernel_size * d_in * 4
        elif blk == "slstm":
            total += 4 * cfg.d_model * 4
    return total


@lru_cache(maxsize=None)
def static_state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Length-independent state written once at prefill and immutable
    thereafter (enc-dec: cached encoder output + cross K/V).  Streamed
    when a request is replicated, never re-mirrored per step."""
    total = 0
    if cfg.is_encoder_decoder:
        src = cfg.encoder.max_source_positions
        total += src * cfg.d_model * dtype_bytes
        total += (len(cfg.block_pattern) * 2 * src
                  * cfg.num_kv_heads * cfg.head_dim * dtype_bytes)
    return total


@lru_cache(maxsize=None)
def fixed_state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Length-independent state bytes (recurrent memories + enc-dec
    static caches)."""
    return (recurrent_state_bytes(cfg, dtype_bytes)
            + static_state_bytes(cfg, dtype_bytes))


def state_bytes_at(cfg: ModelConfig, length: int, dtype_bytes: int = 2) -> float:
    """Total serving-state bytes for one request at sequence length."""
    return bytes_per_token(cfg, dtype_bytes) * length + fixed_state_bytes(
        cfg, dtype_bytes)


def decode_read_bytes(cfg: ModelConfig, length: int,
                      dtype_bytes: int = 2) -> float:
    """Bytes streamed from HBM for this request in ONE decode step — the
    quantity the load balancer equalizes across a pair (weights are shared
    by the batch, so the per-request marginal cost is exactly its state)."""
    return state_bytes_at(cfg, length, dtype_bytes)
