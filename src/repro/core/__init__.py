"""AcceLLM's contribution: redundant-KV instance pairs, dynamic roles,
and state-bytes load balancing (scheduler + redundancy + balancer)."""
from repro.core.balancer import Item, imbalance, partition, should_rebalance
from repro.core.cluster import AcceLLMCluster, Pair, Placement
from repro.core.kvbytes import (bytes_per_token, decode_read_bytes,
                                fixed_state_bytes, state_bytes_at)

__all__ = [
    "AcceLLMCluster", "Pair", "Placement", "Item", "partition", "imbalance",
    "should_rebalance", "bytes_per_token", "fixed_state_bytes",
    "state_bytes_at", "decode_read_bytes",
]
