"""AcceLLM's contribution: redundant-KV instance pairs, dynamic roles,
and state-bytes load balancing (scheduler + redundancy + balancer).

``Placement`` is loaded lazily (PEP 562) because it lives in
``repro.scheduling.live``, which itself uses the pure helpers below — a
cycle if everything imported eagerly.  The historical ``AcceLLMCluster``
facade is gone: construct clusters through ``repro.api.serve`` (or
``LiveCluster`` with ``AcceLLMScheduler`` directly).
"""
from repro.core.balancer import Item, imbalance, partition, should_rebalance
from repro.core.kvbytes import (bytes_per_token, decode_read_bytes,
                                fixed_state_bytes, recurrent_state_bytes,
                                state_bytes_at, static_state_bytes)

__all__ = [
    "Placement", "Item", "partition", "imbalance",
    "should_rebalance", "bytes_per_token", "fixed_state_bytes",
    "recurrent_state_bytes", "static_state_bytes",
    "state_bytes_at", "decode_read_bytes",
]


def __getattr__(name):
    if name == "Placement":
        from repro.scheduling.live import Placement
        return Placement
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
