"""AcceLLM's contribution: redundant-KV instance pairs, dynamic roles,
and state-bytes load balancing (scheduler + redundancy + balancer).

The cluster facade is loaded lazily (PEP 562) because
``repro.core.cluster`` builds on ``repro.scheduling``, which itself uses
the pure helpers below — a cycle if everything imported eagerly.
"""
from repro.core.balancer import Item, imbalance, partition, should_rebalance
from repro.core.kvbytes import (bytes_per_token, decode_read_bytes,
                                fixed_state_bytes, recurrent_state_bytes,
                                state_bytes_at, static_state_bytes)

__all__ = [
    "AcceLLMCluster", "Pair", "Placement", "Item", "partition", "imbalance",
    "should_rebalance", "bytes_per_token", "fixed_state_bytes",
    "recurrent_state_bytes", "static_state_bytes",
    "state_bytes_at", "decode_read_bytes",
]

_LAZY = ("AcceLLMCluster", "Pair", "Placement")


def __getattr__(name):
    if name in _LAZY:
        from repro.core import cluster
        return getattr(cluster, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
