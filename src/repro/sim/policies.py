"""Simulator adapters for the shared scheduling kernels.

The serving systems of the paper's evaluation (§5.2) plus one from its
related work (§2) are *decided* by the backend-agnostic kernels in
``repro.scheduling`` — the same objects that drive live JAX engines
through ``repro.scheduling.live`` — and *executed* here against the
discrete-event simulator's analytic cost model:

  VLLMPolicy      — vLLM-style: independent instances, continuous batching
                    that co-schedules prefill with decode (prefill
                    prioritized). No KV movement. TBT spikes when prompts
                    land mid-decode (paper Fig. 5 / 16).
  SplitwisePolicy — Splitwise-style static disaggregation: n_p dedicated
                    prefill instances, rest decode-only; post-prefill KV
                    transfer to a decode instance is on the request's
                    critical path (Fig. 1 Case B).
  SarathiPolicy   — Sarathi-Serve-style chunked prefill: prompts split into
                    fixed-size chunks co-scheduled with decode, bounding
                    (not eliminating) the TBT spike — trades TTFT for TBT.
  AcceLLMPolicy   — the paper's system: instance pairs, dynamic roles,
                    per-layer-overlapped KV streaming, redundant KV copies,
                    count+state-bytes decode balancing, replica eviction
                    under memory pressure.

Each adapter owns only simulator mechanics (event pushes, busy-state
handling); routing, role selection, placement, rebalancing and eviction
decisions are delegated to its kernel, iteration *shapes* to the shared
step planner (``repro.stepplan`` — the same bucketing/chunking/no-mixing
rules the live executor compiles under), and iteration *costs* to the
single entry point ``PerfModel.plan_time``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.fleet import (DegradeInstance, Drain, FleetController,
                         JoinInstance, KillInstance, RecoverInstance,
                         reset_for_reprefill, rollback_tokens)
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.actions import (Action, Decode, EvictReplica,
                                      MirrorSync, Prefill, PromoteReplica,
                                      StreamState)
from repro.scheduling.base import MAX_PREFILL_BATCH, SchedulerPolicy
from repro.scheduling.baselines import (SarathiScheduler, SplitwiseScheduler,
                                        VLLMScheduler)
from repro.scheduling.ulb import ULBScheduler
from repro.sim.cluster import Policy, SimInstance, Simulator
from repro.sim.workload import SimRequest
from repro.stepplan import (DecodePlan, Planner, StepPlan, TransferPlan,
                            prefill_part)

__all__ = ["AcceLLMPolicy", "VLLMPolicy", "SplitwisePolicy", "SarathiPolicy",
           "ULBPolicy", "SimInstanceView", "SimClusterView",
           "MAX_PREFILL_BATCH"]


def sim_prefix_key(inst: SimInstance, req) -> list:
    """The simulator's radix key for a request's shareable prompt head.

    The sim is token-free, so the alphabet is ``(prefix_id, pos)``
    pairs: two requests collide on exactly the chunks where their
    declared shared prefix overlaps — the same hit lengths the live
    engine computes over real token ids (group tokens are identical
    across a prefix group there)."""
    if (inst.prefix_cache is None
            or getattr(req, "prefix_id", None) is None):
        return []
    from repro.prefixcache import aligned_hit_lines
    n = aligned_hit_lines(req.prefix_len, req.prompt_len,
                          inst.block_lines)
    return [(req.prefix_id, j) for j in range(n)]


# ---------------------------------------------------------------------------
# Views: the simulator's cost model behind the scheduling protocols
# ---------------------------------------------------------------------------


class SimInstanceView:
    """InstanceView over a SimInstance (see repro.scheduling.views)."""

    def __init__(self, inst: SimInstance,
                 placement: Dict[int, Tuple[int, Optional[int]]],
                 planner: Optional[Planner] = None):
        self._i = inst
        self._placement = placement
        self._planner = planner

    @property
    def index(self) -> int:
        return self._i.iid

    # -- fleet state ---------------------------------------------------------
    def alive(self) -> bool:
        return self._i.alive

    def draining(self) -> bool:
        return self._i.draining

    def health(self) -> float:
        # EWMA slowdown (1.0 = nominal), updated by the event loop with
        # the shared step_health arithmetic (see scheduling.views)
        return self._i.health

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> int:
        return max(0, self._i.max_batch - len(self._i.decode_batch))

    def mem_free(self) -> float:
        return self._i.mem_free()

    def free_blocks(self) -> int:
        return self._i.free_blocks()

    def block_lines(self) -> int:
        return self._i.block_lines

    def spec(self):
        # the hardware this SimInstance is priced on (heterogeneous
        # pods carry a different InstanceSpec per instance)
        return self._i.perf.inst

    def primary_bytes(self) -> float:
        costs = self._i.store.costs
        return sum(costs.bytes_at(r.total_len)
                   for r in self._i.decode_batch.values())

    def replica_bytes(self) -> float:
        costs = self._i.store.costs
        return sum(costs.bytes_at(r.total_len)
                   for r in self._i.replicas.values())

    def can_admit(self, req, taking: int = 0) -> bool:
        fits = self._i.mem_free() >= self._i.perf.kv_bytes(req.prompt_len)
        return fits and len(self._i.decode_batch) + taking < self._i.max_batch

    def can_hold_primary(self, req, resident: bool = False) -> bool:
        # the simulator's decode batch is elastic; memory pressure is
        # handled by eviction rather than refusing placement
        return True

    def can_hold_replica(self, req, resident: bool = False) -> bool:
        return self._i.mem_free() >= self._i.perf.kv_bytes(req.total_len)

    def can_queue(self) -> bool:
        return True

    # -- load ----------------------------------------------------------------
    def decode_load(self) -> int:
        return len(self._i.decode_batch)

    def prefill_backlog(self) -> int:
        return len(self._i.prefill_queue)

    def prefill_backlog_tokens(self) -> int:
        # planner feedback, same as the live view: prompts mid-chunk
        # count only their remaining (cursor-adjusted) tokens, and a
        # stamped prefix-cache hit starts the count past the hit
        cursor = self._planner.cursor if self._planner else (lambda rid: 0)
        return sum(r.prompt_len - max(cursor(r.rid),
                                      getattr(r, "prefix_hit", 0) or 0)
                   for r in self._i.prefill_queue)

    def decode_weights(self) -> Dict[int, float]:
        return {rid: self._i.perf.kv_bytes(r.total_len)
                for rid, r in self._i.decode_batch.items()}

    def replica_weights(self) -> Dict[int, float]:
        return {rid: self._i.perf.kv_bytes(r.total_len)
                for rid, r in self._i.replicas.items()}

    def decode_remaining(self) -> Dict[int, int]:
        return {rid: r.max_new_tokens - r.generated
                for rid, r in self._i.decode_batch.items()}

    # -- mirror ledger --------------------------------------------------------
    def request_lines(self) -> Dict[int, int]:
        return {rid: r.total_len for rid, r in self._i.decode_batch.items()}

    def replica_synced(self) -> Dict[int, int]:
        # the simulator executes the mirror inside the decode-step cost,
        # so a replica is current as of its request's last decode —
        # unless a sparse lag mark says a sync was skipped (fleet races,
        # partial-sync injection in tests)
        return {rid: self._i.synced_marks.get(rid, r.total_len)
                for rid, r in self._i.replicas.items()}

    # -- prefix cache ---------------------------------------------------------
    def shared_blocks(self) -> int:
        return self._i.synced_store().ledger.shared_blocks_count()

    def prefix_hit_tokens(self, req) -> int:
        cache = self._i.prefix_cache
        if cache is None:
            return 0
        key = sim_prefix_key(self._i, req)
        if not key:
            return 0
        return len(cache.peek_blocks(key)) * self._i.block_lines


class SimClusterView:
    """ClusterView over a Simulator (see repro.scheduling.views)."""

    def __init__(self, sim: Simulator,
                 placement: Dict[int, Tuple[int, Optional[int]]],
                 planner: Optional[Planner] = None):
        self._views = [SimInstanceView(i, placement, planner)
                       for i in sim.instances]
        self._placement = placement

    def instances(self):
        return self._views

    def pairs(self):
        return [(self._views[i], self._views[i + 1])
                for i in range(0, len(self._views) - 1, 2)]

    def placements(self) -> Dict[int, Tuple[int, Optional[int]]]:
        return self._placement


class KernelPolicy(Policy):
    """Base adapter: binds a scheduling kernel + the shared step planner
    to the simulator."""

    kernel: SchedulerPolicy
    #: rid -> (primary iid, replica iid or None); empty for policies
    #: without redundancy
    placement: Dict[int, Tuple[int, Optional[int]]]

    def __init__(self, kernel: SchedulerPolicy, fuse_decode_steps: int = 1):
        self.kernel = kernel
        self.placement = {}
        #: array-backed cluster state (repro.scale), attached at bind
        #: time when the kernel declares ``vectorized = True``
        self.arrays = None
        #: same configuration rule as the live executor: the kernel
        #: declares mixing/chunking, the planner shapes iterations
        self.planner = Planner.for_policy(kernel)
        #: fused decode ceiling (mirrors LiveCluster(fuse_decode_steps=)):
        #: idle decode instances compile up-to-N-step DecodePlans, priced
        #: with one amortized dispatch by plan_time; the planner's
        #: mirror/backlog/remaining-budget gates apply per instance,
        #: spans are capped at the next pending arrival (_fuse_horizon),
        #: and event-driven instances keep independent clocks so no
        #: cluster-wide uniformity cap is needed
        self.planner.max_fuse_steps = max(1, fuse_decode_steps)

    @property
    def name(self):  # type: ignore[override]
        return self.kernel.name

    def bind(self, sim: Simulator):
        super().bind(sim)
        if getattr(self.kernel, "vectorized", False):
            # lazy import: repro.sim must stay importable without the
            # scale layer in the loop (no cycle at module load)
            from repro.scale.state import ArrayClusterState
            self.arrays = ArrayClusterState(sim, self.placement,
                                            self.planner)
            # the adapter's ledger becomes the observed dict so every
            # placement write lands in the replica arrays
            self.placement = self.arrays.placement

    def view(self) -> SimClusterView:
        if self.arrays is not None:
            return self.arrays.cluster_view()
        return SimClusterView(self.sim, self.placement, self.planner)

    def _inst_view(self, inst: SimInstance) -> SimInstanceView:
        """A single instance's view, from the persistent array views
        when attached (pair-local admission/eviction decisions)."""
        if self.arrays is not None:
            return self.arrays.cluster_view().instances()[inst.iid]
        return SimInstanceView(inst, self.placement, self.planner)

    def note_decode_advance(self, inst, rids, steps):
        if self.arrays is not None:
            self.arrays.note_decode_advance(inst, rids, steps)

    def route(self, req: SimRequest) -> Optional[SimInstance]:
        idx = self.kernel.route(self.view(), req)
        return None if idx is None else self.sim.instances[idx]

    # -- plan helpers ---------------------------------------------------------
    def _fuse_horizon(self, inst: SimInstance) -> Optional[int]:
        """Decode iterations until the next pending arrival, in units of
        this instance's current single-step time — the sim analogue of
        the live executor's arrival-horizon cap, so a fused span never
        runs (much) past an admission point on either backend.  None
        when no arrival is scheduled."""
        nxt = self.sim.next_arrival()
        if nxt is None:
            return None
        if self.arrays is not None:
            lens, _ = self._inst_view(inst).decode_plan_stats()
            lengths = tuple(sorted(lens))
        else:
            lengths = tuple(sorted(r.total_len
                                   for r in inst.decode_batch.values()))
        t1 = inst.perf.plan_time(DecodePlan(
            inst.iid, lengths=lengths, block_lines=inst.block_lines))
        if t1 <= 0:
            return None
        return max(1, int((nxt - self.sim.now) / t1))

    def _compile(self, inst: SimInstance,
                 actions: List[Action]) -> Optional[StepPlan]:
        if self.planner.max_fuse_steps > 1:
            self.planner.fuse_horizon = self._fuse_horizon(inst)
        plans = self.planner.compile(actions, self.view())
        if not plans:
            return None
        plan = plans[0]
        # requests whose prefill completes within this plan leave the
        # queue NOW (they are executing, not waiting — backlog views and
        # queue-depth timelines must not count them, matching the live
        # executor); prompts mid-chunk stay queued with their cursor
        pf = prefill_part(plan)
        if pf is not None:
            done_rids = set(pf.completed_rids())
            if done_rids:
                inst.prefill_queue = [r for r in inst.prefill_queue
                                      if r.rid not in done_rids]
        return plan

    def _queue_split(self, inst: SimInstance):
        """Split the prefill queue into prompts mid-chunk (they resume
        unconditionally) and fresh candidates (admission-gated)."""
        in_prog = [r for r in inst.prefill_queue
                   if self.planner.cursor(r.rid) > 0]
        fresh = [r for r in inst.prefill_queue
                 if self.planner.cursor(r.rid) == 0]
        return in_prog, fresh

    def _prefill_actions(self, inst: SimInstance, reqs) -> List[Action]:
        for r in reqs:
            self._prefix_stamp(inst, r)
        return [Prefill(r.rid, inst.iid, r.prompt_len, req=r) for r in reqs]

    # -- prefix cache ---------------------------------------------------------
    def _prefix_stamp(self, inst: SimInstance, r: SimRequest):
        """Consult the instance's prefix index once, when the prefill is
        first scheduled (same stamp point as the live executor): the
        planner then prices the PrefillItem at its unique suffix, and
        the pinned run survives eviction until :meth:`_note_prefilled`
        adopts it.  Idempotent across re-planning."""
        cache = inst.prefix_cache
        if cache is None or getattr(r, "prefix_hit", None) is not None:
            return
        key = sim_prefix_key(inst, r)
        blocks = cache.lookup_pin(r.rid, key) if key else []
        if blocks:
            inst.hit_runs[r.rid] = blocks
        r.prefix_hit = len(blocks) * inst.block_lines

    def _note_prefilled(self, inst: SimInstance, r: SimRequest):
        """Prefill of ``r`` completed on ``inst``: adopt the pinned hit
        run as the resident table's shared head and index the new
        prompt's shareable head — mirror of the live engine's
        first-chunk adoption + ``_prefix_insert``.

        A request that was handed off after prefill (Splitwise-style:
        never resident here) still seeds the cache: its head blocks are
        allocated, retained by the index, and the unique suffix is
        returned to the pool at once — the live engine's
        release-after-stream, where the cache alone keeps the prompt
        head alive on the prefill instance."""
        cache = inst.prefix_cache
        if cache is None:
            return
        run = inst.hit_runs.pop(r.rid, None)
        resident = r.rid in inst.decode_batch or r.rid in inst.replicas
        if resident and (getattr(r, "prefix_hit", None) or 0) and run:
            inst.shared_runs[r.rid] = run
        cache.unpin(r.rid)
        key = sim_prefix_key(inst, r)
        if not key:
            return
        led = inst.synced_store().ledger
        k = len(key) // inst.block_lines
        if resident:
            cache.insert(key, led.tables[r.rid][:k])
        elif r.rid not in led.tables:
            led.alloc(r.rid, r.total_len, shared=run)
            cache.insert(key, led.tables[r.rid][:k])
            led.free(r.rid)

    # -- fleet mechanics (repro.fleet) ----------------------------------------
    def on_fleet_event(self, ev, ctrl: FleetController):
        if isinstance(ev, KillInstance):
            self._fleet_kill(ev.instance, ctrl)
        elif isinstance(ev, JoinInstance):
            self._fleet_join(ev.instance, ctrl)
        elif isinstance(ev, Drain):
            self._fleet_drain(ev.instance, ctrl)
        elif isinstance(ev, DegradeInstance):
            self._fleet_degrade(ev.instance, ev.factor, ev.link_factor, ctrl)
        elif isinstance(ev, RecoverInstance):
            self._fleet_recover(ev.instance, ctrl)
        else:
            raise ValueError(f"unknown fleet event {ev!r}")

    def _fleet_degrade(self, iid: int, factor: float, link_factor: float,
                       ctrl: FleetController):
        """Partial failure: the instance keeps serving, just slower.  No
        state moves here — the health EWMA surfaces the slowdown to the
        kernels, and hedging kernels react to it."""
        inst = self.sim.instances[iid]
        if not inst.alive:
            return
        inst.degrade_factor = float(factor)
        inst.link_degrade = float(link_factor)
        ctrl.note("degrade", iid, float(factor), float(link_factor))
        ctrl.stats["degrades"] += 1
        # observe immediately if idle: health starts converging to the
        # new factor without waiting for the next arrival/completion
        self.sim.kick(inst)

    def _fleet_recover(self, iid: int, ctrl: FleetController):
        inst = self.sim.instances[iid]
        if not inst.alive:
            return
        inst.degrade_factor = 1.0
        inst.link_degrade = 1.0
        ctrl.note("recover", iid)
        ctrl.stats["recoveries"] += 1
        self.sim.kick(inst)

    # -- abort lifecycle / deadline shedding ----------------------------------
    def abort_request(self, rid: int) -> Optional[SimRequest]:
        """First-class cancel: remove every trace of ``rid`` — queue
        entry, decode residency, replica + lag marks, prefix pins,
        planner cursor, placement — on every instance.  The ledgers
        reconcile to the shrunken resident sets on next read, so the
        blocks are freed with zero leakage."""
        from repro.serving.request import Phase
        found: Optional[SimRequest] = None
        for inst in self.sim.instances:
            for r in list(inst.prefill_queue):
                if r.rid == rid:
                    inst.prefill_queue = [q for q in inst.prefill_queue
                                          if q.rid != rid]
                    found = r
            r = inst.decode_batch.pop(rid, None)
            if r is not None:
                found = r
            r = inst.replicas.pop(rid, None)
            if r is not None:
                found = found or r
            inst.synced_marks.pop(rid, None)
            inst.hit_runs.pop(rid, None)
            inst.shared_runs.pop(rid, None)
            if inst.prefix_cache is not None:
                inst.prefix_cache.unpin(rid)
        self.placement.pop(rid, None)
        self.planner.forget(rid)
        if found is not None:
            found.phase = Phase.ABORTED
        return found

    def shed_overdue(self, inst: SimInstance, now: float,
                     deadline: float) -> List[SimRequest]:
        """Deadline-aware admission: a backlogged request whose queue
        wait already exceeds ``deadline`` will blow TTFT no matter what
        — reject it now instead of serving it late.  Prompts mid-chunk
        (planner cursor > 0) are executing, not waiting: never shed."""
        overdue = [r for r in inst.prefill_queue
                   if now - r.arrival > deadline
                   and self.planner.cursor(r.rid) == 0]
        if not overdue:
            return []
        gone = {r.rid for r in overdue}
        inst.prefill_queue = [r for r in inst.prefill_queue
                              if r.rid not in gone]
        for r in overdue:
            inst.hit_runs.pop(r.rid, None)
            if inst.prefix_cache is not None:
                inst.prefix_cache.unpin(r.rid)
        return overdue

    def _rebind_topology(self):
        """Membership changed (join appended an instance / revived an
        index): adapters with a static topology recompute it here."""
        pass

    def _fleet_kill(self, iid: int, ctrl: FleetController):
        """Same contract (and trace order) as ``LiveCluster.fleet_kill``:
        promote onto warm replicas, re-queue what is truly lost, drop
        orphaned replicas, re-route the prefill backlog."""
        sim = self.sim
        inst = sim.instances[iid]
        if not inst.alive:
            return
        ctrl.note("kill", iid)
        ctrl.stats["kills"] += 1
        # the in-flight iteration dies with the instance: prompts whose
        # final chunk was executing left the queue at compile time —
        # recover them so they re-queue like the rest of the backlog
        if inst._running is not None:
            pf = prefill_part(inst._running[0])
            if pf is not None:
                inst.prefill_queue[:0] = [it.req for it in pf.items
                                          if it.completes]
            inst._running = None
            inst.busy = False
        inst.epoch += 1          # stale inst_done events are ignored
        plan = ctrl.plan_failover(self.view(), iid)
        # 1. promotions: the warm replica takes over at its synced line
        for pr in plan.promotions:
            r = inst.decode_batch.pop(pr.rid)
            dst = sim.instances[pr.dst]
            if pr.lost_lines:
                rollback_tokens(r, pr.lost_lines)
                ctrl.stats["lost_lines"] += pr.lost_lines
            dst.decode_batch[pr.rid] = r
            dst.replicas.pop(pr.rid, None)
            dst.synced_marks.pop(pr.rid, None)
            self.placement[pr.rid] = (pr.dst, None)
            ctrl.note("promote", pr.rid, pr.src, pr.dst, pr.lost_lines)
            ctrl.stats["promotions"] += 1
            dst.note_peak()
        # 2. truly lost state: re-enters the heap as an arrival NOW
        # (never re-appended to sim.submitted — each rid stays
        # single-counted in the metrics)
        def _requeue_resident(rid: int, r: SimRequest):
            ctrl.note("requeue", rid)
            ctrl.stats["requeues"] += 1
            ctrl.stats["lost_decode_tokens"] += r.generated
            ctrl.stats["reprefill_tokens"] += reset_for_reprefill(r)
            r.prefix_hit = None     # re-stamps wherever it re-routes
            self.planner.forget(rid)
            old = self.placement.pop(rid, (None, None))
            if old[1] is not None and old[1] != iid:
                sim.instances[old[1]].replicas.pop(rid, None)
                sim.instances[old[1]].synced_marks.pop(rid, None)
            sim.push(sim.now, "arrival", r)

        for rid in plan.requeues:
            _requeue_resident(rid, inst.decode_batch.pop(rid))
        # residents invisible to the placement ledger (the baseline
        # adapters never maintain one — the live executor tracks
        # placements for every policy): same fate, rid order
        for rid in sorted(inst.decode_batch):
            _requeue_resident(rid, inst.decode_batch.pop(rid))
        # 3. replicas this instance hosted for surviving primaries
        for rid in plan.dropped_replicas:
            pl = self.placement.get(rid)
            if pl:
                self.placement[rid] = (pl[0], None)
            ctrl.note("drop_replica", rid)
        # 4. routed-but-unstarted prompts re-route (no tokens re-run);
        # 5. prompts mid-chunk lose their partial prefill work
        fresh = [r for r in inst.prefill_queue
                 if self.planner.cursor(r.rid) == 0]
        mid = [r for r in inst.prefill_queue
               if self.planner.cursor(r.rid) > 0]
        for r in fresh:
            ctrl.note("requeue", r.rid)
            ctrl.stats["requeue_backlog"] += 1
            r.prefix_hit = None
            sim.push(sim.now, "arrival", r)
        for r in mid:
            ctrl.note("requeue", r.rid)
            ctrl.stats["requeues"] += 1
            ctrl.stats["reprefill_tokens"] += self.planner.cursor(r.rid)
            self.planner.forget(r.rid)
            reset_for_reprefill(r)
            r.prefix_hit = None
            sim.push(sim.now, "arrival", r)
        inst.prefill_queue = []
        inst.replicas.clear()
        inst.synced_marks.clear()
        # the prefix cache dies with the HBM it indexed (rejoin at this
        # rank starts cold) — same teardown as the live executor
        inst.hit_runs.clear()
        inst.shared_runs.clear()
        if inst.prefix_cache is not None:
            inst.prefix_cache.release_all()
        inst.alive = False
        inst.draining = False
        for other in sim.instances:
            sim.kick(other)

    def _fleet_join(self, iid: Optional[int], ctrl: FleetController):
        sim = self.sim
        if iid is not None and iid < len(sim.instances):
            inst = sim.instances[iid]
            if inst.alive:
                return           # join of a live index: no-op
            # replacement hardware at the same rank (state died at kill)
            inst.alive = True
            inst.draining = False
        else:
            inst = SimInstance(len(sim.instances), sim.perf, sim.max_batch,
                               sim.block_lines)
            if sim.prefix_cache:
                inst.enable_prefix_cache(sim.prefix_cache_blocks)
            sim.instances.append(inst)
        ctrl.note("join", inst.iid)
        ctrl.stats["joins"] += 1
        self._rebind_topology()
        # warm scale-up: the kernel mirrors resident requests onto the
        # joined instance before any new arrival routes there
        for act in self.kernel.warm_on_join(self.view(), inst.iid):
            if not isinstance(act, StreamState) or not act.as_replica:
                continue
            r = sim.instances[act.src].decode_batch.get(act.rid)
            if r is None:
                continue
            inst.replicas[act.rid] = r
            self.placement[act.rid] = (act.src, inst.iid)
            ctrl.stats["warm_streams"] += 1
        inst.note_peak()
        sim.kick(inst)

    def _fleet_drain(self, iid: int, ctrl: FleetController):
        inst = self.sim.instances[iid]
        if not inst.alive or inst.draining:
            return
        inst.draining = True
        ctrl.note("drain", iid)
        ctrl.stats["drains"] += 1
        self.settle_drains(ctrl)

    def settle_drains(self, ctrl: FleetController):
        for inst in self.sim.instances:
            if not (inst.draining and inst.alive):
                continue
            if inst.busy or inst.decode_batch or inst.prefill_queue:
                continue
            # only replicas remain: surrender the copies and retire
            for rid in list(inst.replicas):
                pl = self.placement.get(rid)
                if pl and pl[1] == inst.iid:
                    self.placement[rid] = (pl[0], None)
            inst.replicas.clear()
            inst.synced_marks.clear()
            inst.alive = False
            inst.draining = False
            ctrl.note("drained", inst.iid)


# ---------------------------------------------------------------------------
# vLLM
# ---------------------------------------------------------------------------


class VLLMPolicy(KernelPolicy):

    def __init__(self, kernel: Optional[SchedulerPolicy] = None,
                 fuse_decode_steps: int = 1):
        super().__init__(kernel or VLLMScheduler(),
                         fuse_decode_steps=fuse_decode_steps)

    def next_plan(self, inst):
        actions: List[Action] = []
        in_prog, fresh = self._queue_split(inst)
        take = list(in_prog)
        if fresh:
            n = self.kernel.prefill_batch(self.view(), inst.iid, fresh)
            take += fresh[:n]
        actions += self._prefill_actions(inst, take)
        if inst.decode_batch:
            # co-batched prefill+decode iteration (the TBT spike)
            actions.append(Decode(inst.iid))
        return self._compile(inst, actions)

    def on_prefill_done(self, inst, reqs):
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
            else:
                inst.decode_batch[r.rid] = r
            self._note_prefilled(inst, r)
        inst.note_peak()


# ---------------------------------------------------------------------------
# Sarathi-Serve (chunked prefill — related-work baseline)
# ---------------------------------------------------------------------------


class SarathiPolicy(VLLMPolicy):
    """Chunked prefill now lives in the shared step planner: the
    per-iteration ``chunk_tokens`` budget is spent across the queue
    (in-progress prompts first, cursors resumed against the ledger) and
    the resulting MixedPlan is priced by ``PerfModel.plan_time`` — the
    old ``_chunk_work`` side-channel and per-adapter cost override are
    gone, and the identical planner drives the live engines."""

    def __init__(self, chunk_tokens: int = 512, fuse_decode_steps: int = 1):
        super().__init__(SarathiScheduler(chunk_tokens),
                         fuse_decode_steps=fuse_decode_steps)
        self.chunk_tokens = chunk_tokens


# ---------------------------------------------------------------------------
# ULB (Universal Load Balancing — PAPERS.md competitor)
# ---------------------------------------------------------------------------


class ULBPolicy(VLLMPolicy):
    """Least-outstanding-work routing over vLLM-style continuous
    batching: same execution mechanics as :class:`VLLMPolicy`, different
    routing kernel (``repro.scheduling.ulb``)."""

    def __init__(self, kernel: Optional[ULBScheduler] = None,
                 fuse_decode_steps: int = 1):
        super().__init__(kernel or ULBScheduler(),
                         fuse_decode_steps=fuse_decode_steps)


# ---------------------------------------------------------------------------
# Splitwise
# ---------------------------------------------------------------------------


class SplitwisePolicy(KernelPolicy):

    def __init__(self, n_prefill: int,
                 kernel: Optional[SplitwiseScheduler] = None,
                 fuse_decode_steps: int = 1):
        super().__init__(kernel or SplitwiseScheduler(n_prefill),
                         fuse_decode_steps=fuse_decode_steps)
        self.n_prefill = n_prefill

    def bind(self, sim):
        super().bind(sim)
        self._rebind_topology()

    def _rebind_topology(self):
        self.prefill_insts = self.sim.instances[: self.n_prefill]
        self.decode_insts = self.sim.instances[self.n_prefill:]

    def next_plan(self, inst):
        if inst in self.prefill_insts:
            if inst.prefill_queue:
                take = inst.prefill_queue[:MAX_PREFILL_BATCH]
                return self._compile(inst, self._prefill_actions(inst, take))
            return None
        if inst.decode_batch:
            return self._compile(inst, [Decode(inst.iid)])
        return None

    def on_prefill_done(self, inst, reqs):
        # KV transfer to the decode instance is on the critical path:
        # priced as an un-overlapped whole-state TransferPlan
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                self._note_prefilled(inst, r)
                continue
            actions = self.kernel.place_after_prefill(self.view(), inst.iid,
                                                      r)
            act = (actions[0] if actions
                   else StreamState(r.rid, src=inst.iid, dst=inst.iid))
            dt = inst.perf.plan_time(TransferPlan(
                inst.iid, act, lines=r.prompt_len, overlap_layers=False))
            # a browned-out link (DegradeInstance.link_factor) stretches
            # the un-overlapped KV handoff
            dt *= inst.link_degrade
            # the request leaves for its decode instance: the prefill
            # instance's cache still indexes the prompt head it computed
            self._note_prefilled(inst, r)
            self.sim.push(self.sim.now + dt, "join_decode", (act.dst, r))


# ---------------------------------------------------------------------------
# AcceLLM
# ---------------------------------------------------------------------------


class AcceLLMPolicy(KernelPolicy):

    def __init__(self, redundancy: bool = True,
                 kernel: Optional[AcceLLMScheduler] = None,
                 fuse_decode_steps: int = 1):
        super().__init__(kernel or AcceLLMScheduler(redundancy=redundancy),
                         fuse_decode_steps=fuse_decode_steps)

    @property
    def redundancy(self) -> bool:
        return self.kernel.redundancy

    def bind(self, sim):
        super().bind(sim)
        if len(sim.instances) % 2 != 0:
            raise ValueError(
                f"{self.name} organizes instances in pairs: got "
                f"{len(sim.instances)} instances (need an even count)")
        self._rebind_topology()

    def _rebind_topology(self):
        # pairs over floor(n/2): a join may append an odd instance,
        # which stays unpaired (partner() -> None) until its mate joins
        insts = self.sim.instances
        self.pairs = [(insts[i], insts[i + 1])
                      for i in range(0, len(insts) - 1, 2)]
        self.pair_of = {}
        for pa, pb in self.pairs:
            self.pair_of[pa.iid] = (pa, pb)
            self.pair_of[pb.iid] = (pa, pb)

    def partner(self, inst: SimInstance) -> Optional[SimInstance]:
        pair = self.pair_of.get(inst.iid)
        if pair is None:
            return None
        pa, pb = pair
        return pb if inst is pa else pa

    # -- dynamic roles ---------------------------------------------------------
    def next_plan(self, inst):
        if inst.prefill_queue:
            view = self._inst_view(inst)
            take = []
            for r in inst.prefill_queue:
                if (len(take) >= MAX_PREFILL_BATCH
                        or not view.can_admit(r, taking=len(take))):
                    break
                take.append(r)
            if not take:
                self._evict_replica(inst)  # memory pressure (§4.2.5)
                if inst.prefill_queue and view.can_admit(
                        inst.prefill_queue[0]):
                    take = [inst.prefill_queue[0]]
            if take:
                # before flipping to prefill, hand this side's decode work
                # to the partner via replica promotion (zero cost) so token
                # generation never stalls — the crux of §4.1.1/Fig. 6.
                # (never a MixedPlan: the planner would refuse, §4.2.3)
                self._handoff_decodes(inst)
                return self._compile(inst, self._prefill_actions(inst, take))
        if inst.decode_batch:
            # the DecodePlan carries the mirrored-request count, so the
            # per-step replica sync bound (Fig. 10) is priced centrally
            # by PerfModel.plan_time, not by an adapter override
            return self._compile(inst, [Decode(inst.iid)])
        return None

    def _handoff_decodes(self, inst):
        partner = self.partner(inst)
        if partner is None or not partner.alive or partner.draining:
            return
        if (partner.busy and partner._running
                and not isinstance(partner._running[0], DecodePlan)):
            return
        for rid in list(inst.decode_batch):
            pl = self.placement.get(rid, (None, None))
            if pl[1] != partner.iid:
                continue  # no replica on partner: this request must stall
            if rid in partner.synced_marks:
                continue  # stale replica cannot take the primary role
            r = inst.decode_batch.pop(rid)
            partner.decode_batch[rid] = r
            partner.replicas.pop(rid, None)
            inst.replicas[rid] = r
            self.placement[rid] = (partner.iid, inst.iid)
        self.sim.kick(partner)

    # -- placement: per-layer streamed during prefill (§4.2.4) -----------------
    def on_prefill_done(self, inst, reqs):
        partner = self.partner(inst)
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                continue
            # transfer already overlapped with prefill: the request joins
            # its primary's decode batch now, per the kernel's decision
            actions = self.kernel.place_after_prefill(self.view(), inst.iid,
                                                      r)
            dst_iid, rep_iid = inst.iid, None
            for act in actions:
                if not isinstance(act, StreamState):
                    continue
                if act.as_replica:
                    rep_iid = act.dst
                else:
                    dst_iid = act.dst
                    if act.retain_replica:
                        rep_iid = act.src
            dst = self.sim.instances[dst_iid]
            dst.decode_batch[r.rid] = r
            if rep_iid is not None:
                self.sim.instances[rep_iid].replicas[r.rid] = r
            self.placement[r.rid] = (dst_iid, rep_iid)
            self._note_prefilled(inst, r)
            # the copy landing on the OTHER instance adopts ITS cache's
            # resident head, if any (the live engine's import_stream
            # peek): a shared-prefix replica holds only its unique
            # suffix in new pool blocks
            for iid in {dst_iid, rep_iid} - {inst.iid, None}:
                other = self.sim.instances[iid]
                key = sim_prefix_key(other, r)
                if key and other.prefix_cache is not None:
                    run2 = other.prefix_cache.peek_blocks(key)
                    if run2:
                        other.shared_runs[r.rid] = run2
            dst.note_peak()
            if rep_iid is not None:
                self.sim.instances[rep_iid].note_peak()
        if partner is not None:
            self.sim.kick(partner)

    def on_decode_done(self, inst, finished):
        # drop replicas of exactly the requests that finished this
        # iteration (tracked explicitly — scanning a suffix of the global
        # finished list leaked replicas on bursty completions)
        for r in finished:
            pl = self.placement.pop(r.rid, None)
            if pl and pl[1] is not None:
                self.sim.instances[pl[1]].replicas.pop(r.rid, None)
                self.sim.instances[pl[1]].synced_marks.pop(r.rid, None)
        self._rebalance(inst)

    # -- load balancing by count + state bytes (§4.1.3) -------------------------
    def _rebalance(self, inst):
        pair = self.pair_of.get(inst.iid)
        if pair is None:
            return
        pa, pb = pair
        if pa.busy and pb.busy:
            return
        if (pa.busy or pb.busy) and not self._hedge_pending(pa, pb):
            # regular balancing waits for a fully idle pair; a pending
            # straggler hedge must not — the hedge window IS the window
            # in which the sick side is grinding a slow iteration.
            # Moving a request off a busy instance is safe under the
            # snapshot semantics of _handle_done (same as abort): the
            # in-flight iteration simply stops crediting it tokens, and
            # it resumes on the healthy side's next kick.
            return
        actions = self.kernel.rebalance(self.view(), inst.iid // 2)
        for act in actions:
            if isinstance(act, MirrorSync):
                # catch-up delta ahead of a promotion: the stale replica
                # absorbs the lines it was missing and is current again
                self.sim.instances[act.replica].synced_marks.pop(
                    act.rid, None)
                continue
            assert isinstance(act, PromoteReplica)
            src = self.sim.instances[act.src]
            dst = self.sim.instances[act.dst]
            r = src.decode_batch.pop(act.rid)
            dst.decode_batch[act.rid] = r
            # zero-cost: dst already held the (now current) replica
            dst.replicas.pop(act.rid, None)
            dst.synced_marks.pop(act.rid, None)
            src.replicas[act.rid] = r
            self.placement[act.rid] = (act.dst, act.src)
            if act.hedge and self.sim.fleet is not None:
                self.sim.fleet.stats["hedges"] += 1
        if actions:
            self.sim.kick(pa)
            self.sim.kick(pb)

    def _hedge_pending(self, pa, pb) -> bool:
        """Exactly one pair side's health EWMA is over the kernel's
        hedge threshold — the only situation in which the kernel would
        emit hedge flips rather than regular balancing moves."""
        thr = getattr(self.kernel, "hedge_threshold", None)
        if thr is None or not getattr(self.kernel, "hedging", False):
            return False
        return max(pa.health, pb.health) >= thr > min(pa.health, pb.health)

    # -- graceful degradation (§4.2.5) ----------------------------------------
    def _evict_replica(self, inst):
        view = self._inst_view(inst)
        for act in self.kernel.evict(self.view(), [view]):
            assert isinstance(act, EvictReplica)
            self.sim.instances[act.instance].replicas.pop(act.rid, None)
            pl = self.placement.get(act.rid)
            if pl:
                self.placement[act.rid] = (pl[0], None)
