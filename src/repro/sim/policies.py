"""Simulator adapters for the shared scheduling kernels.

The serving systems of the paper's evaluation (§5.2) plus one from its
related work (§2) are *decided* by the backend-agnostic kernels in
``repro.scheduling`` — the same objects that drive live JAX engines
through ``repro.scheduling.live`` — and *executed* here against the
discrete-event simulator's analytic cost model:

  VLLMPolicy      — vLLM-style: independent instances, continuous batching
                    that co-schedules prefill with decode (prefill
                    prioritized). No KV movement. TBT spikes when prompts
                    land mid-decode (paper Fig. 5 / 16).
  SplitwisePolicy — Splitwise-style static disaggregation: n_p dedicated
                    prefill instances, rest decode-only; post-prefill KV
                    transfer to a decode instance is on the request's
                    critical path (Fig. 1 Case B).
  SarathiPolicy   — Sarathi-Serve-style chunked prefill: prompts split into
                    fixed-size chunks co-scheduled with decode, bounding
                    (not eliminating) the TBT spike — trades TTFT for TBT.
  AcceLLMPolicy   — the paper's system: instance pairs, dynamic roles,
                    per-layer-overlapped KV streaming, redundant KV copies,
                    count+state-bytes decode balancing, replica eviction
                    under memory pressure.

Each adapter owns only simulator mechanics (event pushes, durations,
busy-state handling); routing, role selection, placement, rebalancing and
eviction decisions are delegated to its kernel.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.actions import (EvictReplica, PromoteReplica,
                                      StreamState)
from repro.scheduling.base import MAX_PREFILL_BATCH, SchedulerPolicy
from repro.scheduling.baselines import (SarathiScheduler, SplitwiseScheduler,
                                        VLLMScheduler)
from repro.sim.cluster import Policy, SimInstance, Simulator
from repro.sim.workload import SimRequest

__all__ = ["AcceLLMPolicy", "VLLMPolicy", "SplitwisePolicy", "SarathiPolicy",
           "SimInstanceView", "SimClusterView", "MAX_PREFILL_BATCH"]


# ---------------------------------------------------------------------------
# Views: the simulator's cost model behind the scheduling protocols
# ---------------------------------------------------------------------------


class SimInstanceView:
    """InstanceView over a SimInstance (see repro.scheduling.views)."""

    def __init__(self, inst: SimInstance,
                 placement: Dict[int, Tuple[int, Optional[int]]]):
        self._i = inst
        self._placement = placement

    @property
    def index(self) -> int:
        return self._i.iid

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> int:
        return max(0, self._i.max_batch - len(self._i.decode_batch))

    def mem_free(self) -> float:
        return self._i.mem_free()

    def free_blocks(self) -> int:
        return self._i.free_blocks()

    def primary_bytes(self) -> float:
        costs = self._i.store.costs
        return sum(costs.bytes_at(r.total_len)
                   for r in self._i.decode_batch.values())

    def replica_bytes(self) -> float:
        costs = self._i.store.costs
        return sum(costs.bytes_at(r.total_len)
                   for r in self._i.replicas.values())

    def can_admit(self, req, taking: int = 0) -> bool:
        fits = self._i.mem_free() >= self._i.perf.kv_bytes(req.prompt_len)
        return fits and len(self._i.decode_batch) + taking < self._i.max_batch

    def can_hold_primary(self, req, resident: bool = False) -> bool:
        # the simulator's decode batch is elastic; memory pressure is
        # handled by eviction rather than refusing placement
        return True

    def can_hold_replica(self, req, resident: bool = False) -> bool:
        return self._i.mem_free() >= self._i.perf.kv_bytes(req.total_len)

    def can_queue(self) -> bool:
        return True

    # -- load ----------------------------------------------------------------
    def decode_load(self) -> int:
        return len(self._i.decode_batch)

    def prefill_backlog(self) -> int:
        return len(self._i.prefill_queue)

    def prefill_backlog_tokens(self) -> int:
        return sum(r.prompt_len for r in self._i.prefill_queue)

    def decode_weights(self) -> Dict[int, float]:
        return {rid: self._i.perf.kv_bytes(r.total_len)
                for rid, r in self._i.decode_batch.items()}

    def replica_weights(self) -> Dict[int, float]:
        return {rid: self._i.perf.kv_bytes(r.total_len)
                for rid, r in self._i.replicas.items()}

    # -- mirror ledger --------------------------------------------------------
    def request_lines(self) -> Dict[int, int]:
        return {rid: r.total_len for rid, r in self._i.decode_batch.items()}

    def replica_synced(self) -> Dict[int, int]:
        # the simulator executes the mirror inside the decode-step cost,
        # so a replica is current as of its request's last decode
        return {rid: r.total_len for rid, r in self._i.replicas.items()}


class SimClusterView:
    """ClusterView over a Simulator (see repro.scheduling.views)."""

    def __init__(self, sim: Simulator,
                 placement: Dict[int, Tuple[int, Optional[int]]]):
        self._views = [SimInstanceView(i, placement) for i in sim.instances]
        self._placement = placement

    def instances(self):
        return self._views

    def pairs(self):
        return [(self._views[i], self._views[i + 1])
                for i in range(0, len(self._views) - 1, 2)]

    def placements(self) -> Dict[int, Tuple[int, Optional[int]]]:
        return self._placement


class KernelPolicy(Policy):
    """Base adapter: binds a scheduling kernel to the simulator."""

    kernel: SchedulerPolicy
    #: rid -> (primary iid, replica iid or None); empty for policies
    #: without redundancy
    placement: Dict[int, Tuple[int, Optional[int]]]

    def __init__(self, kernel: SchedulerPolicy):
        self.kernel = kernel
        self.placement = {}

    @property
    def name(self):  # type: ignore[override]
        return self.kernel.name

    def view(self) -> SimClusterView:
        return SimClusterView(self.sim, self.placement)

    def route(self, req: SimRequest) -> Optional[SimInstance]:
        idx = self.kernel.route(self.view(), req)
        return None if idx is None else self.sim.instances[idx]


# ---------------------------------------------------------------------------
# vLLM
# ---------------------------------------------------------------------------


class VLLMPolicy(KernelPolicy):

    def __init__(self, kernel: Optional[SchedulerPolicy] = None):
        super().__init__(kernel or VLLMScheduler())

    def next_action(self, inst):
        if inst.prefill_queue:
            n = self.kernel.prefill_batch(self.view(), inst.iid,
                                          inst.prefill_queue)
            take = [inst.prefill_queue.pop(0) for _ in range(n)]
            if take:
                # co-batched prefill+decode iteration (the TBT spike)
                return ("mixed", take) if inst.decode_batch else ("prefill",
                                                                  take)
        if inst.decode_batch:
            return ("decode",)
        return None

    def on_prefill_done(self, inst, reqs):
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
            else:
                inst.decode_batch[r.rid] = r
        inst.note_peak()


# ---------------------------------------------------------------------------
# Sarathi-Serve (chunked prefill — related-work baseline)
# ---------------------------------------------------------------------------


class SarathiPolicy(VLLMPolicy):

    def __init__(self, chunk_tokens: int = 512):
        super().__init__(SarathiScheduler(chunk_tokens))
        self.chunk_tokens = chunk_tokens
        self._chunk_work: Dict[int, int] = {}   # iid -> tokens this iter

    def next_action(self, inst):
        # True intra-prompt chunking is a cost-model concern the event
        # simulator can express exactly, so it stays here; admission limits
        # on the iteration-clocked live executor use the kernel's
        # prefill_batch budget instead.
        completed: List[SimRequest] = []
        budget = self.chunk_tokens
        view = SimInstanceView(inst, self.placement)
        while budget > 0 and inst.prefill_queue:
            r = inst.prefill_queue[0]
            if not view.can_admit(r, taking=len(completed)):
                break
            prog = getattr(r, "prefill_progress", 0)
            take = min(r.prompt_len - prog, budget)
            r.prefill_progress = prog + take
            budget -= take
            if r.prefill_progress >= r.prompt_len:
                completed.append(inst.prefill_queue.pop(0))
            # budget exhausted mid-request: loop exits via budget == 0
        used = self.chunk_tokens - budget
        self._chunk_work[inst.iid] = used
        if used or completed:
            return ("mixed", completed)
        if inst.decode_batch:
            return ("decode",)
        return None

    def action_time(self, inst, action):
        if action[0] != "mixed":
            return None
        used = self._chunk_work.get(inst.iid, 0)
        t = inst.perf.decode_step_time(
            [r.total_len for r in inst.decode_batch.values()])
        if used:
            t += inst.perf.prefill_time([used])
        return t


# ---------------------------------------------------------------------------
# Splitwise
# ---------------------------------------------------------------------------


class SplitwisePolicy(KernelPolicy):

    def __init__(self, n_prefill: int):
        super().__init__(SplitwiseScheduler(n_prefill))
        self.n_prefill = n_prefill

    def bind(self, sim):
        super().bind(sim)
        self.prefill_insts = sim.instances[: self.n_prefill]
        self.decode_insts = sim.instances[self.n_prefill:]

    def next_action(self, inst):
        if inst in self.prefill_insts:
            if inst.prefill_queue:
                take = inst.prefill_queue[:MAX_PREFILL_BATCH]
                del inst.prefill_queue[:MAX_PREFILL_BATCH]
                return ("prefill", take)
            return None
        return ("decode",) if inst.decode_batch else None

    def on_prefill_done(self, inst, reqs):
        # KV transfer to the decode instance is on the critical path
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                continue
            actions = self.kernel.place_after_prefill(self.view(), inst.iid,
                                                      r)
            dst_iid = actions[0].dst if actions else inst.iid
            dt = inst.perf.kv_transfer_time(r.prompt_len,
                                            overlap_layers=False)
            self.sim.push(self.sim.now + dt, "join_decode", (dst_iid, r))


# ---------------------------------------------------------------------------
# AcceLLM
# ---------------------------------------------------------------------------


class AcceLLMPolicy(KernelPolicy):

    def __init__(self, redundancy: bool = True,
                 kernel: Optional[AcceLLMScheduler] = None):
        super().__init__(kernel or AcceLLMScheduler(redundancy=redundancy))

    @property
    def redundancy(self) -> bool:
        return self.kernel.redundancy

    def bind(self, sim):
        super().bind(sim)
        n = len(sim.instances)
        assert n % 2 == 0, "AcceLLM organizes instances in pairs"
        self.pairs = [(sim.instances[i], sim.instances[i + 1])
                      for i in range(0, n, 2)]
        self.pair_of = {}
        for pa, pb in self.pairs:
            self.pair_of[pa.iid] = (pa, pb)
            self.pair_of[pb.iid] = (pa, pb)

    def partner(self, inst: SimInstance) -> SimInstance:
        pa, pb = self.pair_of[inst.iid]
        return pb if inst is pa else pa

    # -- dynamic roles ---------------------------------------------------------
    def next_action(self, inst):
        if inst.prefill_queue:
            view = SimInstanceView(inst, self.placement)
            take = []
            while (inst.prefill_queue and len(take) < MAX_PREFILL_BATCH
                   and view.can_admit(inst.prefill_queue[0],
                                      taking=len(take))):
                take.append(inst.prefill_queue.pop(0))
            if not take:
                self._evict_replica(inst)  # memory pressure (§4.2.5)
                if inst.prefill_queue and view.can_admit(
                        inst.prefill_queue[0]):
                    take = [inst.prefill_queue.pop(0)]
            if take:
                # before flipping to prefill, hand this side's decode work
                # to the partner via replica promotion (zero cost) so token
                # generation never stalls — the crux of §4.1.1/Fig. 6.
                self._handoff_decodes(inst)
                return ("prefill", take)
        if inst.decode_batch:
            return ("decode",)
        return None

    def _handoff_decodes(self, inst):
        partner = self.partner(inst)
        if partner.busy and partner._running and partner._running[0] != "decode":
            return
        for rid in list(inst.decode_batch):
            pl = self.placement.get(rid, (None, None))
            if pl[1] != partner.iid:
                continue  # no replica on partner: this request must stall
            r = inst.decode_batch.pop(rid)
            partner.decode_batch[rid] = r
            partner.replicas.pop(rid, None)
            inst.replicas[rid] = r
            self.placement[rid] = (partner.iid, inst.iid)
        self.sim.kick(partner)

    # -- placement: per-layer streamed during prefill (§4.2.4) -----------------
    def on_prefill_done(self, inst, reqs):
        partner = self.partner(inst)
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                continue
            # transfer already overlapped with prefill: the request joins
            # its primary's decode batch now, per the kernel's decision
            actions = self.kernel.place_after_prefill(self.view(), inst.iid,
                                                      r)
            dst_iid, rep_iid = inst.iid, None
            for act in actions:
                if not isinstance(act, StreamState):
                    continue
                if act.as_replica:
                    rep_iid = act.dst
                else:
                    dst_iid = act.dst
                    if act.retain_replica:
                        rep_iid = act.src
            dst = self.sim.instances[dst_iid]
            dst.decode_batch[r.rid] = r
            if rep_iid is not None:
                self.sim.instances[rep_iid].replicas[r.rid] = r
            self.placement[r.rid] = (dst_iid, rep_iid)
            dst.note_peak()
            if rep_iid is not None:
                self.sim.instances[rep_iid].note_peak()
        self.sim.kick(partner)

    # -- decode: mirror traffic may bound the step (Fig. 10) -------------------
    def decode_step_time(self, inst):
        t = inst.perf.decode_step_time(
            [r.total_len for r in inst.decode_batch.values()])
        if self.redundancy:
            mirrored = sum(1 for rid in inst.decode_batch
                           if self.placement.get(rid, (None, None))[1]
                           is not None)
            # mirror traffic charged from the shared ledger costs: one
            # new KV line per mirrored request per step (§4.1.2)
            t_link = (inst.store.mirror_bytes_per_step(mirrored)
                      / inst.perf.inst.link_bw)
            t = max(t, t_link)
        return t

    def on_decode_done(self, inst, finished):
        # drop replicas of exactly the requests that finished this
        # iteration (tracked explicitly — scanning a suffix of the global
        # finished list leaked replicas on bursty completions)
        for r in finished:
            pl = self.placement.pop(r.rid, None)
            if pl and pl[1] is not None:
                self.sim.instances[pl[1]].replicas.pop(r.rid, None)
        self._rebalance(inst)

    # -- load balancing by count + state bytes (§4.1.3) -------------------------
    def _rebalance(self, inst):
        pa, pb = self.pair_of[inst.iid]
        if pa.busy or pb.busy:
            return
        actions = self.kernel.rebalance(self.view(), inst.iid // 2)
        for act in actions:
            assert isinstance(act, PromoteReplica)
            src = self.sim.instances[act.src]
            dst = self.sim.instances[act.dst]
            r = src.decode_batch.pop(act.rid)
            dst.decode_batch[act.rid] = r
            # zero-cost: dst already held the replica; roles swap
            dst.replicas.pop(act.rid, None)
            src.replicas[act.rid] = r
            self.placement[act.rid] = (act.dst, act.src)
        if actions:
            self.sim.kick(pa)
            self.sim.kick(pb)

    # -- graceful degradation (§4.2.5) ----------------------------------------
    def _evict_replica(self, inst):
        view = SimInstanceView(inst, self.placement)
        for act in self.kernel.evict(self.view(), [view]):
            assert isinstance(act, EvictReplica)
            self.sim.instances[act.instance].replicas.pop(act.rid, None)
            pl = self.placement.get(act.rid)
            if pl:
                self.placement[act.rid] = (pl[0], None)
