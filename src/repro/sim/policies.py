"""The serving systems of the paper's evaluation (§5.2) + one more from
its related work (§2).

  VLLMPolicy      — vLLM-style: independent instances, continuous batching
                    that co-schedules prefill with decode (prefill
                    prioritized). No KV movement. TBT spikes when prompts
                    land mid-decode (paper Fig. 5 / 16).
  SplitwisePolicy — Splitwise-style static disaggregation: n_p dedicated
                    prefill instances, rest decode-only; post-prefill KV
                    transfer to a decode instance is on the request's
                    critical path (Fig. 1 Case B).
  SarathiPolicy   — Sarathi-Serve-style chunked prefill (beyond the paper's
                    baselines, from its §2): prompts split into fixed-size
                    chunks co-scheduled with decode, bounding (not
                    eliminating) the TBT spike — trades TTFT for TBT.
  AcceLLMPolicy   — the paper's system: instance pairs, dynamic roles,
                    per-layer-overlapped KV streaming, redundant KV copies,
                    count+state-bytes decode balancing, replica eviction
                    under memory pressure.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.balancer import Item, partition, should_rebalance
from repro.sim.cluster import Policy, SimInstance
from repro.sim.workload import SimRequest

MAX_PREFILL_BATCH = 4


def _fits(inst: SimInstance, req: SimRequest, extra: float = 0.0) -> bool:
    return inst.mem_free() >= inst.perf.kv_bytes(req.prompt_len) + extra


# ---------------------------------------------------------------------------
# vLLM
# ---------------------------------------------------------------------------


class VLLMPolicy(Policy):
    name = "vllm"

    def route(self, req):
        # least-loaded instance with memory headroom
        ok = [i for i in self.sim.instances if _fits(i, req)]
        pool = ok or self.sim.instances
        return min(pool, key=lambda i: len(i.decode_batch)
                   + len(i.prefill_queue))

    def next_action(self, inst):
        if inst.prefill_queue:
            take = []
            while (inst.prefill_queue and len(take) < MAX_PREFILL_BATCH
                   and len(inst.decode_batch) + len(take) < inst.max_batch
                   and _fits(inst, inst.prefill_queue[0])):
                take.append(inst.prefill_queue.pop(0))
            if take:
                # co-batched prefill+decode iteration (the TBT spike)
                return ("mixed", take) if inst.decode_batch else ("prefill", take)
        if inst.decode_batch:
            return ("decode",)
        return None

    def on_prefill_done(self, inst, reqs):
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
            else:
                inst.decode_batch[r.rid] = r
        inst.note_peak()


# ---------------------------------------------------------------------------
# Sarathi-Serve (chunked prefill — related-work baseline)
# ---------------------------------------------------------------------------


class SarathiPolicy(VLLMPolicy):
    name = "sarathi"

    def __init__(self, chunk_tokens: int = 512):
        self.chunk_tokens = chunk_tokens
        self._chunk_work: Dict[int, int] = {}   # iid -> tokens this iter

    def next_action(self, inst):
        completed: List[SimRequest] = []
        budget = self.chunk_tokens
        while budget > 0 and inst.prefill_queue:
            r = inst.prefill_queue[0]
            if not _fits(inst, r) or (len(inst.decode_batch)
                                      + len(completed) >= inst.max_batch):
                break
            prog = getattr(r, "prefill_progress", 0)
            take = min(r.prompt_len - prog, budget)
            r.prefill_progress = prog + take
            budget -= take
            if r.prefill_progress >= r.prompt_len:
                completed.append(inst.prefill_queue.pop(0))
            # budget exhausted mid-request: loop exits via budget == 0
        used = self.chunk_tokens - budget
        self._chunk_work[inst.iid] = used
        if used or completed:
            return ("mixed", completed)
        if inst.decode_batch:
            return ("decode",)
        return None

    def action_time(self, inst, action):
        if action[0] != "mixed":
            return None
        used = self._chunk_work.get(inst.iid, 0)
        t = inst.perf.decode_step_time(
            [r.total_len for r in inst.decode_batch.values()])
        if used:
            t += inst.perf.prefill_time([used])
        return t


# ---------------------------------------------------------------------------
# Splitwise
# ---------------------------------------------------------------------------


class SplitwisePolicy(Policy):
    name = "splitwise"

    def __init__(self, n_prefill: int):
        self.n_prefill = n_prefill

    def bind(self, sim):
        super().bind(sim)
        self.prefill_insts = sim.instances[: self.n_prefill]
        self.decode_insts = sim.instances[self.n_prefill:]

    def route(self, req):
        return min(self.prefill_insts,
                   key=lambda i: sum(r.prompt_len for r in i.prefill_queue))

    def next_action(self, inst):
        if inst in self.prefill_insts:
            if inst.prefill_queue:
                take = inst.prefill_queue[:MAX_PREFILL_BATCH]
                del inst.prefill_queue[:MAX_PREFILL_BATCH]
                return ("prefill", take)
            return None
        return ("decode",) if inst.decode_batch else None

    def on_prefill_done(self, inst, reqs):
        # KV transfer to the decode instance is on the critical path
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                continue
            dst = min(self.decode_insts,
                      key=lambda i: len(i.decode_batch) - i.mem_free() * 1e-18)
            dt = inst.perf.kv_transfer_time(r.prompt_len, overlap_layers=False)
            self.sim.push(self.sim.now + dt, "join_decode", (dst.iid, r))


# ---------------------------------------------------------------------------
# AcceLLM
# ---------------------------------------------------------------------------


class AcceLLMPolicy(Policy):
    name = "accellm"

    def __init__(self, redundancy: bool = True):
        self.redundancy = redundancy
        # rid -> (primary iid, replica iid or None)
        self.placement: Dict[int, Tuple[int, Optional[int]]] = {}

    def bind(self, sim):
        super().bind(sim)
        n = len(sim.instances)
        assert n % 2 == 0
        self.pairs = [(sim.instances[i], sim.instances[i + 1])
                      for i in range(0, n, 2)]
        self.pair_of = {}
        for pa, pb in self.pairs:
            self.pair_of[pa.iid] = (pa, pb)
            self.pair_of[pb.iid] = (pa, pb)

    def partner(self, inst: SimInstance) -> SimInstance:
        pa, pb = self.pair_of[inst.iid]
        return pb if inst is pa else pa

    # -- routing: pair with most free memory (§4.2.2) -----------------------
    def route(self, req):
        def pair_free(p):
            return p[0].mem_free() + p[1].mem_free()
        pair = max(self.pairs, key=pair_free)
        # inside the pair, prefill lands on the less decode-loaded side
        pa, pb = pair
        return pa if len(pa.decode_batch) <= len(pb.decode_batch) else pb

    # -- dynamic roles ---------------------------------------------------------
    def next_action(self, inst):
        if inst.prefill_queue:
            take = []
            while (inst.prefill_queue and len(take) < MAX_PREFILL_BATCH
                   and _fits(inst, inst.prefill_queue[0])):
                take.append(inst.prefill_queue.pop(0))
            if not take:
                self._evict_replica(inst)  # memory pressure (§4.2.5)
                if inst.prefill_queue and _fits(inst, inst.prefill_queue[0]):
                    take = [inst.prefill_queue.pop(0)]
            if take:
                # before flipping to prefill, hand this side's decode work
                # to the partner via replica promotion (zero cost) so token
                # generation never stalls — the crux of §4.1.1/Fig. 6.
                self._handoff_decodes(inst)
                return ("prefill", take)
        if inst.decode_batch:
            return ("decode",)
        return None

    def _handoff_decodes(self, inst):
        partner = self.partner(inst)
        if partner.busy and partner._running and partner._running[0] != "decode":
            return
        for rid in list(inst.decode_batch):
            pl = self.placement.get(rid, (None, None))
            if pl[1] != partner.iid:
                continue  # no replica on partner: this request must stall
            r = inst.decode_batch.pop(rid)
            partner.decode_batch[rid] = r
            partner.replicas.pop(rid, None)
            inst.replicas[rid] = r
            self.placement[rid] = (partner.iid, inst.iid)
        self.sim.kick(partner)

    def on_prefill_done(self, inst, reqs):
        partner = self.partner(inst)
        for r in reqs:
            if r.done:
                r.finish_time = self.sim.now
                self.sim.finished.append(r)
                continue
            # per-layer streamed during prefill (§4.2.4): transfer already
            # overlapped, the request joins the partner's decode batch now;
            # the prefilling side retains its copy as the replica.
            dst, rep = partner, inst
            if len(dst.decode_batch) > len(inst.decode_batch) + 1:
                dst, rep = inst, partner
            dst.decode_batch[r.rid] = r
            replica_iid = None
            if self.redundancy and rep.mem_free() >= rep.perf.kv_bytes(
                    r.total_len):
                rep.replicas[r.rid] = r
                replica_iid = rep.iid
            self.placement[r.rid] = (dst.iid, replica_iid)
            dst.note_peak()
            rep.note_peak()
        self.sim.kick(partner)

    # -- decode: mirror traffic may bound the step (Fig. 10) -------------------
    def decode_step_time(self, inst):
        t = inst.perf.decode_step_time(
            [r.total_len for r in inst.decode_batch.values()])
        if self.redundancy:
            mirrored = sum(1 for rid in inst.decode_batch
                           if self.placement.get(rid, (None, None))[1]
                           is not None)
            t_link = (inst.perf.mirror_bytes_per_step(mirrored)
                      / inst.perf.inst.link_bw)
            t = max(t, t_link)
        return t

    def on_decode_done(self, inst):
        # drop replicas of finished requests
        for r in list(self.sim.finished[-8:]):
            pl = self.placement.pop(r.rid, None)
            if pl and pl[1] is not None:
                self.sim.instances[pl[1]].replicas.pop(r.rid, None)
        self._rebalance(inst)

    # -- load balancing by count + state bytes (§4.1.3) -------------------------
    def _rebalance(self, inst):
        pa, pb = self.pair_of[inst.iid]
        if pa.busy or pb.busy:
            return
        items = []
        for side, e in ((0, pa), (1, pb)):
            for rid, r in e.decode_batch.items():
                movable = self.placement.get(rid, (None, None))[1] is not None
                items.append(Item(rid=rid, weight=e.perf.kv_bytes(r.total_len),
                                  home=side, movable=movable))
        if not should_rebalance(items):
            return
        _, _, moves = partition(items)
        for rid, src_i, dst_i in moves:
            src = (pa, pb)[src_i]
            dst = (pa, pb)[dst_i]
            r = src.decode_batch.pop(rid)
            dst.decode_batch[rid] = r
            # zero-cost: dst already held the replica; roles swap
            dst.replicas.pop(rid, None)
            src.replicas[rid] = r
            self.placement[rid] = (dst.iid, src.iid)
        self.sim.kick(pa)
        self.sim.kick(pb)

    def _evict_replica(self, inst):
        if not inst.replicas:
            return
        rid = max(inst.replicas, key=lambda k: inst.replicas[k].total_len)
        inst.replicas.pop(rid)
        pl = self.placement.get(rid)
        if pl:
            self.placement[rid] = (pl[0], None)
