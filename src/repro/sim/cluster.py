"""Discrete-event cluster simulator (paper §5.1).

Instances execute *step plans* (:mod:`repro.stepplan`): a policy adapter
compiles each iteration's scheduling actions into the same plan objects
the live executor runs, and the event loop prices every one through the
single cost entry point ``PerfModel.plan_time(plan)``.  The event loop
keeps a heap of (time, event); a ``Policy`` decides routing, batching,
KV movement and balancing — adapters in ``repro.sim.policies`` reproduce
the paper's systems (AcceLLM / Splitwise / vLLM / Sarathi).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.kvstore import SimStore
from repro.scheduling.views import step_health
from repro.serving.request import Phase
from repro.sim.perf import PerfModel
from repro.sim.workload import SimRequest
from repro.stepplan import (DecodePlan, MixedPlan, PrefillPlan, StepPlan,
                            decode_part, prefill_part)
from repro.workloads import ModeledSecondsClock, TimelinePoint
from repro.workloads.spec import RequestSource


@dataclass
class SimInstance:
    iid: int
    perf: PerfModel
    max_batch: int
    block_lines: int = 16
    decode_batch: Dict[int, SimRequest] = field(default_factory=dict)
    replicas: Dict[int, SimRequest] = field(default_factory=dict)
    prefill_queue: List[SimRequest] = field(default_factory=list)
    busy: bool = False
    #: fleet state (repro.fleet): dead instances stay in the list so
    #: indices remain stable; ``epoch`` bumps on kill so in-flight
    #: ``inst_done`` events from a previous life are ignored
    alive: bool = True
    draining: bool = False
    epoch: int = 0
    #: partial failure (repro.fleet.DegradeInstance): compute iterations
    #: on this instance are priced ``degrade_factor`` x slow and its
    #: transfers ``link_degrade`` x slow until a RecoverInstance lands
    degrade_factor: float = 1.0
    link_degrade: float = 1.0
    #: health EWMA the scheduling views expose (1.0 = nominal) — the
    #: same ``step_health`` arithmetic the live executor runs
    health: float = 1.0
    #: sparse replica lag marks: rid -> synced line.  The sim prices the
    #: mirror inside the decode step, so replicas are current (and
    #: absent from this dict) unless a fleet event or an injected lag
    #: says otherwise; ``replica_synced`` falls back to ``total_len``.
    synced_marks: Dict[int, int] = field(default_factory=dict)
    # peak memory tracking (paper Fig. 9)
    peak_state_bytes: float = 0.0
    busy_time: float = 0.0
    # current running iteration: (StepPlan, decode-batch snapshot,
    # start time)
    _running: Optional[Tuple[StepPlan, tuple, float]] = None
    #: block-table accounting ledger (repro.kvstore) — the same
    #: arithmetic the live PagedStore runs; (re)built in __post_init__
    store: Optional[SimStore] = None
    #: radix prefix cache over the ledger (None: disabled).  The SAME
    #: ``repro.prefixcache.PrefixCache`` class the live engine runs —
    #: only the token alphabet differs (``(prefix_id, pos)`` pairs here)
    prefix_cache: Optional[object] = None
    #: rid -> cached block run its ledger table adopts as a shared head
    #: on (re)alloc; pruned to resident rids at each reconcile
    shared_runs: Dict[int, List[int]] = field(default_factory=dict)
    #: pinned hit runs awaiting their prefill's completion
    hit_runs: Dict[int, List[int]] = field(default_factory=dict)

    def __post_init__(self):
        if self.store is None:
            self.store = SimStore(self.perf.line_costs,
                                  self.perf.kv_capacity_bytes,
                                  block_lines=self.block_lines)

    def enable_prefix_cache(self, capacity_blocks: Optional[int] = None):
        from repro.prefixcache import PrefixCache
        if capacity_blocks is None:
            capacity_blocks = self.store.ledger.num_blocks // 2
        self.prefix_cache = PrefixCache(self.store.ledger,
                                        capacity_blocks=capacity_blocks)

    def synced_store(self) -> SimStore:
        """The ledger, reconciled to the current resident sets.  The
        simulator mutates ``decode_batch``/``replicas`` at event
        granularity (and consistency tests drive them directly), so
        membership and line counts are re-derived on read; the byte and
        block arithmetic is the shared ``BlockLedger``'s."""
        resident = {rid: r.total_len for rid, r in self.decode_batch.items()}
        for rid, r in self.replicas.items():
            resident.setdefault(rid, r.total_len)
        if self.shared_runs:
            # a request that left residency re-stamps (and re-adopts)
            # fresh if it ever returns — stale runs must not leak into
            # a later realloc of the same rid
            for rid in list(self.shared_runs):
                if rid not in resident:
                    del self.shared_runs[rid]
        return self.store.reconcile(resident, shared=self.shared_runs)

    def state_bytes(self) -> float:
        # direct line-exact sum (== the ledger's used_bytes, same
        # LineCosts): byte reads are hot (note_peak per event, can_admit
        # per routing decision) and need no ledger reconcile
        arrays = self.__dict__.get("_arrays")
        if arrays is not None:
            # array state attached: the incremental aggregates hold the
            # same exact-integer sum
            return arrays.recs[self.iid].state_bytes()
        costs = self.store.costs
        return (sum(costs.bytes_at(r.total_len)
                    for r in self.decode_batch.values())
                + sum(costs.bytes_at(r.total_len)
                      for r in self.replicas.values()))

    def mem_free(self) -> float:
        return self.perf.kv_capacity_bytes - self.state_bytes()

    def free_blocks(self) -> int:
        return self.synced_store().free_blocks()

    def note_peak(self):
        self.peak_state_bytes = max(self.peak_state_bytes, self.state_bytes())

    _OBSERVED = frozenset(
        ("decode_batch", "replicas", "prefill_queue", "alive", "draining"))

    def __setattr__(self, name, value):
        # when an ArrayClusterState (repro.scale) is attached, container
        # rebinds (``inst.prefill_queue = [...]`` in the compile/fleet
        # paths) are re-wrapped in observing containers and fleet-state
        # flips invalidate the usable mask — existing mutation sites
        # stay coherent without being edited (untracked attributes skip
        # the hook call: this intercepts every SimInstance setattr)
        if name in SimInstance._OBSERVED:
            arrays = self.__dict__.get("_arrays")
            if arrays is not None:
                value = arrays.on_setattr(self, name, value)
        object.__setattr__(self, name, value)


class Policy:
    """Hooks the simulator calls; see repro.sim.policies."""

    name = "base"

    def bind(self, sim: "Simulator"):
        self.sim = sim

    def route(self, req: SimRequest) -> Optional[SimInstance]:
        raise NotImplementedError

    def next_plan(self, inst: SimInstance) -> Optional[StepPlan]:
        """The instance's next iteration as a step plan (or None to
        idle).  The event loop prices it via ``perf.plan_time``."""
        raise NotImplementedError

    def on_prefill_done(self, inst: SimInstance, reqs: List[SimRequest]):
        raise NotImplementedError

    def on_decode_done(self, inst: SimInstance,
                       finished: List[SimRequest]):
        """Called after each decode iteration with the requests that
        finished in it (explicitly, so policies can release per-request
        resources without scanning global history)."""
        pass

    def note_decode_advance(self, inst: SimInstance, rids, steps: int):
        """The decode span over snapshot ``rids`` generated ``steps``
        tokens per still-resident member — the bulk-update hook the
        array-backed state (repro.scale) uses instead of per-token
        bookkeeping.  ``rids`` is the batch snapshot; consumers filter
        to survivors (``rid in inst.decode_batch``) themselves, since
        handoffs may have added non-snapshot residents mid-span and
        finished requests already left.  Dict-backed policies need
        nothing here."""
        pass

    def on_fleet_event(self, ev, ctrl):
        """Apply a :mod:`repro.fleet` event (kill / join / drain /
        degrade / recover).  ``ctrl`` is the run's ``FleetController`` —
        the policy applies the controller's failover plan to its own
        bookkeeping."""
        raise NotImplementedError(
            f"policy {self.name} has no fleet support")

    def abort_request(self, rid: int) -> Optional[SimRequest]:
        """Tear down every trace of ``rid`` (queue entry, decode
        residency, replica, planner cursor, prefix pins); returns the
        request record if it was found, None otherwise."""
        raise NotImplementedError(
            f"policy {self.name} has no abort support")

    def shed_overdue(self, inst: SimInstance, now: float,
                     deadline: float) -> List[SimRequest]:
        """Remove (and return) backlogged requests on ``inst`` whose
        queue wait already exceeds ``deadline`` — deadline-aware
        admission shedding.  Only not-yet-started requests may shed."""
        return []

    def settle_drains(self, ctrl):
        """Retire draining instances whose residents have completed
        (called by the event loop after each event when a fleet is
        active)."""
        pass


class Simulator:
    def __init__(self, policy: Policy, perf, n_instances: int,
                 max_batch: int = 64, block_lines: int = 16,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None,
                 timeline_stride: int = 1,
                 max_queue: Optional[int] = None,
                 shed_deadline: Optional[float] = None):
        # ``perf`` is one PerfModel for a homogeneous pod, or a sequence
        # of n_instances models for a heterogeneous one (e.g. H100-class
        # and 910B2-class slices scheduled by the same kernel)
        if isinstance(perf, (list, tuple)):
            if len(perf) != n_instances:
                raise ValueError(
                    f"{len(perf)} perf models for {n_instances} instances")
            perfs = list(perf)
        else:
            perfs = [perf] * n_instances
        # default model: fleet joins past the pod land on this hardware
        self.perf = perfs[0] if perfs else perf
        # remembered so fleet joins build replacement instances with the
        # original shape (mirrors LiveCluster._engine_kwargs)
        self.max_batch = max_batch
        self.block_lines = block_lines
        self.prefix_cache = prefix_cache
        self.prefix_cache_blocks = prefix_cache_blocks
        self.fleet = None            # FleetController of the active run
        self.instances = [SimInstance(i, perfs[i], max_batch, block_lines)
                          for i in range(n_instances)]
        if prefix_cache:
            for inst in self.instances:
                inst.enable_prefix_cache(prefix_cache_blocks)
        self.policy = policy
        policy.bind(self)
        self.clock = ModeledSecondsClock()
        self._heap: List[tuple] = []
        #: pending arrival times (min-heap), maintained incrementally so
        #: fused-decode horizon checks never rescan the event heap
        self._arrivals: List[float] = []
        self._seq = itertools.count()
        self._kicking: set = set()   # re-entrancy guard for kick()
        self.finished: List[SimRequest] = []
        self.dropped: List[SimRequest] = []
        self.submitted: List[SimRequest] = []   # every request offered
        # admission control: a bounded cluster-wide backlog plus
        # deadline-aware shedding (a request whose queue wait already
        # blew the TTFT budget is rejected, not served late)
        self.max_queue = max_queue
        self.shed_deadline = shed_deadline
        self.shed: List[SimRequest] = []        # admission-control rejects
        self.aborted: List[SimRequest] = []     # cancelled mid-flight
        self.timeline: List[TimelinePoint] = []
        #: sample the timeline every N events (1 = every event).  At
        #: 10^6-request scale a per-event list OOMs the report; metrics
        #: that read the timeline interpolate across the stride.
        self.timeline_stride = max(1, timeline_stride)
        self._ticks = 0
        # wall-clock spent inside the scheduling policy (routing, plan
        # compilation, completion hooks) — the scheduler-μs/iteration
        # metric.  A depth counter keeps nested calls (kick() re-entered
        # from inside next_plan via decode handoffs) from double-counting.
        self.sched_time_s = 0.0
        self.n_iterations = 0
        self._sched_depth = 0
        self._sched_t0 = 0.0
        # closed-loop pump (set by run() when the source demands it)
        self._pump: Optional[Iterator] = None
        self._pump_target = 0
        self._pump_issued = 0

    @property
    def now(self) -> float:
        return self.clock.now

    @now.setter
    def now(self, t: float):
        self.clock.now = t

    @property
    def sched_us_per_iter(self) -> float:
        """Mean scheduler wall-μs per completed instance iteration."""
        return self.sched_time_s * 1e6 / max(1, self.n_iterations)

    # -- scheduler timing ---------------------------------------------------------
    def _sched_begin(self):
        self._sched_depth += 1
        if self._sched_depth == 1:
            self._sched_t0 = time.perf_counter()

    def _sched_end(self):
        self._sched_depth -= 1
        if self._sched_depth == 0:
            self.sched_time_s += time.perf_counter() - self._sched_t0

    # -- event helpers ---------------------------------------------------------
    def push(self, time: float, kind: str, data=None):
        heapq.heappush(self._heap, (time, next(self._seq), kind, data))
        if kind == "arrival":
            heapq.heappush(self._arrivals, time)

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival still strictly in the future (None if no
        arrival is pending) — the fused-decode span bound."""
        while self._arrivals and self._arrivals[0] < self.now:
            heapq.heappop(self._arrivals)
        return self._arrivals[0] if self._arrivals else None

    def kick(self, inst: SimInstance):
        """Start the next iteration on an idle instance."""
        if inst.busy or not inst.alive:
            return
        if inst.iid in self._kicking:
            return
        self._kicking.add(inst.iid)
        self._sched_begin()
        try:
            if self.shed_deadline is not None and inst.prefill_queue:
                for r in self.policy.shed_overdue(inst, self.now,
                                                  self.shed_deadline):
                    self._shed(r)
            plan = self.policy.next_plan(inst)
        finally:
            self._sched_end()
            self._kicking.discard(inst.iid)
        if plan is None:
            # an idle kick still observes the instance: health keeps
            # converging toward the current degrade factor, so a fully
            # hedged-away straggler that later recovers decays back
            # under the hedge threshold (the live executor updates
            # every alive instance once per step; events are the sim's
            # step boundary) instead of freezing sick and suppressing
            # its pair's rebalance forever
            inst.health = step_health(inst.health, inst.degrade_factor)
            return
        # ONE cost entry point for every iteration shape (ISSUE 4
        # acceptance): the plan the adapter compiled is priced as-is,
        # on the hardware of the instance that runs it.  A degraded
        # instance (repro.fleet.DegradeInstance) runs the identical plan
        # degrade_factor x slow, and its health EWMA tracks the slowdown
        # one iteration at a time — the signal hedging kernels read.
        dur = inst.perf.plan_time(plan)
        if inst.degrade_factor != 1.0:
            dur *= inst.degrade_factor
        inst.health = step_health(inst.health, inst.degrade_factor)
        inst.busy = True
        inst.busy_time += dur
        inst._running = (plan, tuple(inst.decode_batch), self.now)
        self.push(self.now + dur, "inst_done", (inst.iid, inst.epoch))

    # -- admission control / abort ------------------------------------------------
    def _shed(self, req: SimRequest):
        """Admission-control reject: terminal, counted (Phase.SHED stays
        in ``submitted`` so slo_summary scores it as a miss)."""
        req.phase = Phase.SHED
        self.shed.append(req)
        if self.fleet is not None:
            self.fleet.note("shed", req.rid)
            self.fleet.stats["sheds"] += 1

    def backlog_depth(self) -> int:
        """Cluster-wide admission backlog (requests routed but not yet
        prefilled) — what ``max_queue`` bounds."""
        return sum(len(i.prefill_queue) for i in self.instances)

    def abort(self, rid: int) -> Optional[SimRequest]:
        """First-class cancel: tear down ``rid``'s serving state
        everywhere via the policy, stamp it ``Phase.ABORTED``."""
        self._sched_begin()
        try:
            req = self.policy.abort_request(rid)
        finally:
            self._sched_end()
        if req is not None:
            self.aborted.append(req)
            if self.fleet is not None:
                self.fleet.note("abort", rid)
                self.fleet.stats["aborts"] += 1
        return req

    # -- event handlers -----------------------------------------------------------
    def _handle_arrival(self, req: SimRequest):
        if (self.max_queue is not None
                and self.backlog_depth() >= self.max_queue):
            self._shed(req)
            return
        self._sched_begin()
        try:
            inst = self.policy.route(req)
        finally:
            self._sched_end()
        if inst is None:
            self.dropped.append(req)
            return
        inst.prefill_queue.append(req)
        self.kick(inst)

    def _handle_done(self, data):
        iid, epoch = data if isinstance(data, tuple) else (data, 0)
        inst = self.instances[iid]
        if not inst.alive or epoch != inst.epoch or inst._running is None:
            return      # the iteration died with its instance (fleet kill)
        plan, batch_snapshot, started = inst._running
        inst.busy = False
        inst._running = None
        self.n_iterations += 1
        pf = prefill_part(plan)
        dc = decode_part(plan)
        if pf is not None:
            # only items whose final chunk ran complete their prefill
            # (they left the queue when the plan was compiled); partial
            # chunks keep their request queued — the planner's cursor
            # resumes it next iteration
            # an aborted request's in-flight chunk still burns the time
            # it was priced at, but its completion is void
            reqs = [it.req for it in pf.items
                    if it.completes and it.req.phase is not Phase.ABORTED]
            for r in reqs:
                r.first_token_time = self.now
                r.token_times.append(self.now)
                r.generated += 1
            self._sched_begin()
            try:
                self.policy.on_prefill_done(inst, reqs)
            finally:
                self._sched_end()
        if dc is not None:
            # a fused plan IS dc.steps decode iterations: each request
            # in the snapshot advances once per step until done.  Token
            # times spread evenly across the span's modeled duration, so
            # per-token TBT/SLO metrics stay comparable to the live
            # executor (which stamps one iteration apart) instead of
            # bunching at plan completion.
            steps = max(1, dc.steps)
            per_step = (self.now - started) / steps
            finished_now: List[SimRequest] = []
            for j in range(steps):
                t_j = started + per_step * (j + 1)
                for rid in batch_snapshot:
                    r = inst.decode_batch.get(rid)
                    if r is None:
                        continue
                    r.generated += 1
                    r.token_times.append(t_j)
                    if r.done:
                        r.finish_time = t_j
                        self.finished.append(r)
                        finished_now.append(r)
                        del inst.decode_batch[rid]
            self._sched_begin()
            try:
                # the snapshot's still-resident members advanced exactly
                # `steps` tokens; the policy filters survivors itself so
                # dict-backed policies (a no-op hook) pay nothing
                self.policy.note_decode_advance(inst, batch_snapshot,
                                                steps)
                self.policy.on_decode_done(inst, finished_now)
            finally:
                self._sched_end()
        inst.note_peak()
        self.kick(inst)

    def _handle_join(self, data):
        iid, req = data
        inst = self.instances[iid]
        if req.phase is Phase.ABORTED:
            return      # cancelled while its KV transfer was in flight
        if not inst.alive or inst.draining:
            # the decode target died/cordoned while the KV transfer was
            # in flight: the state is lost, the request re-prefills
            from repro.fleet import reset_for_reprefill
            if self.fleet is not None:
                self.fleet.note("requeue", req.rid)
                self.fleet.stats["requeues"] += 1
                self.fleet.stats["lost_decode_tokens"] += req.generated
                self.fleet.stats["reprefill_tokens"] += \
                    reset_for_reprefill(req)
            else:
                reset_for_reprefill(req)
            req.prefix_hit = None    # re-stamps wherever it re-routes
            self.push(self.now, "arrival", req)
            return
        inst.decode_batch[req.rid] = req
        inst.note_peak()
        self.kick(inst)

    # -- observability -----------------------------------------------------------
    def _sample_timeline(self):
        self._ticks += 1
        if (self._ticks - 1) % self.timeline_stride:
            return
        running = [i._running[0] if i.busy and i._running else None
                   for i in self.instances]
        n_prefill = sum(1 for p in running
                        if isinstance(p, (PrefillPlan, MixedPlan)))
        n_decode = sum(1 for p in running if isinstance(p, DecodePlan))
        self.timeline.append(TimelinePoint(
            t=self.now,
            queue_depth=sum(len(i.prefill_queue) for i in self.instances),
            n_prefill=n_prefill, n_decode=n_decode,
            n_idle=len(self.instances) - n_prefill - n_decode))

    # -- closed-loop refill -------------------------------------------------------
    def _pump_refill(self):
        while (self._pump is not None
               and self._pump_issued - len(self.finished) - len(self.dropped)
               - len(self.shed) - len(self.aborted)
               < self._pump_target):
            r = next(self._pump, None)
            if r is None:
                self._pump = None
                return
            r.arrival = self.now
            self._pump_issued += 1
            self.submitted.append(r)
            self.push(self.now, "arrival", r)

    # -- main loop ---------------------------------------------------------------
    def run(self, requests: Optional[List[SimRequest]] = None,
            horizon: float = float("inf"),
            source: Optional[RequestSource] = None,
            fleet=None):
        """Run to completion (or ``horizon``).

        ``requests`` is the classic pre-materialized list; ``source`` is a
        :class:`repro.workloads.RequestSource` — open-loop sources feed
        the event heap directly (one traffic time unit == one modeled
        second), closed-loop sources keep ``source.concurrency`` requests
        in flight, issuing the next on each completion.

        ``fleet`` is a :class:`repro.fleet.FleetController`: its event
        stream (kills / joins / drains, in modeled seconds) lands on the
        same heap and dispatches through ``policy.on_fleet_event``.
        """
        if fleet is not None:
            self.fleet = fleet
            for ev in fleet.drain_all():
                self.push(ev.t, "fleet", ev)
        if source is not None:
            if source.concurrency:
                self._pump = iter(source)
                self._pump_target = source.concurrency
                self._pump_refill()
            else:
                requests = list(source)
        for r in (requests or []):
            self.submitted.append(r)
            self.push(r.arrival, "arrival", r)
        while self._heap:
            t, _, kind, data = heapq.heappop(self._heap)
            if t > horizon:
                break
            self.now = t
            if kind == "arrival":
                # keep the arrival mirror-heap drained even when no
                # fusing policy ever asks for next_arrival()
                if self._arrivals and self._arrivals[0] <= t:
                    heapq.heappop(self._arrivals)
                self._handle_arrival(data)
            elif kind == "inst_done":
                self._handle_done(data)
            elif kind == "join_decode":
                self._handle_join(data)
            elif kind == "abort":
                self.abort(data)
            elif kind == "fleet":
                self.policy.on_fleet_event(data, self.fleet)
            if self.fleet is not None and any(i.draining
                                              for i in self.instances):
                self.policy.settle_drains(self.fleet)
            self._sample_timeline()
            if self._pump is not None:
                self._pump_refill()
        return self.finished
