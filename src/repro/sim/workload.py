"""Workload generators (paper Table 2: uniform light / mixed / heavy)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

WORKLOADS = {
    # name: (prefill lo-hi, decode lo-hi)  — paper Table 2
    "light": ((20, 500), (20, 500)),
    "mixed": ((20, 1000), (20, 1000)),
    "heavy": ((500, 1000), (500, 1000)),
}


@dataclass
class SimRequest:
    rid: int
    arrival: float
    prompt_len: int
    decode_len: int
    # filled by the simulator
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)
    generated: int = 0

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.decode_len

    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    def jct(self) -> float:
        return self.finish_time - self.arrival

    def tbts(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]


def make_workload(name: str, rate: float, duration: float,
                  seed: int = 0) -> List[SimRequest]:
    """Poisson arrivals at ``rate`` req/s for ``duration`` seconds with
    uniform prompt/decode lengths per the paper's Table 2."""
    (plo, phi), (dlo, dhi) = WORKLOADS[name]
    rng = np.random.default_rng(seed)
    reqs: List[SimRequest] = []
    t, rid = 0.0, 0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            break
        reqs.append(SimRequest(
            rid=rid, arrival=t,
            prompt_len=int(rng.integers(plo, phi + 1)),
            decode_len=int(rng.integers(dlo, dhi + 1))))
        rid += 1
    return reqs
