"""Analytic per-iteration performance model.

Mirrors the paper's simulator (§5.1): computation, HBM bandwidth, memory
requirements and KV-transfer costs, parameterized by ModelConfig and
InstanceSpec. Prefill is compute-bound (§3.2); decode is HBM-bound (§3.3):
per decode step the instance must stream the weights once plus every
batched request's KV cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Sequence

from repro.configs.base import ModelConfig
from repro.core.kvbytes import state_bytes_at
from repro.sim.devices import InstanceSpec

DTYPE_BYTES = 2


@dataclass(frozen=True)
class PerfModel:
    cfg: ModelConfig
    inst: InstanceSpec

    def __post_init__(self):
        if self.kv_capacity_bytes <= 0:
            raise ValueError(
                f"instance HBM too small for {self.cfg.name!r}: weights "
                f"(+10% activations) need "
                f"{1.1 * self.weight_bytes / 1e9:.1f} GB but the instance "
                f"has {self.inst.hbm_bytes / 1e9:.1f} GB — no capacity "
                f"left for KV/serving state.  Use more/larger devices per "
                f"instance (InstanceSpec) or a smaller model.")

    @property
    def weight_bytes(self) -> float:
        return self.cfg.param_count() * DTYPE_BYTES

    @property
    def active_weight_bytes(self) -> float:
        """Bytes of weights actually read per decode step (MoE: active only)."""
        return self.cfg.param_count(active_only=True) * DTYPE_BYTES

    @property
    def kv_capacity_bytes(self) -> float:
        """HBM left for serving state after weights (+10% activations)."""
        return self.inst.hbm_bytes - 1.1 * self.weight_bytes

    @cached_property
    def line_costs(self) -> "LineCosts":
        """The shared per-line cost card (``repro.kvstore.LineCosts``)
        the SimStore ledger and the live PagedStore both charge from."""
        from repro.kvstore import LineCosts
        return LineCosts.from_config(self.cfg, DTYPE_BYTES)

    # -- prefill (compute-bound, §3.2) --------------------------------------
    def prefill_flops(self, prompt_lens: Sequence[int]) -> float:
        n_active = self.cfg.param_count(active_only=True)
        total = 0.0
        n_attn = sum(1 for b in self.cfg.block_pattern if b == "attn")
        for s in prompt_lens:
            total += 2.0 * n_active * s
            # causal attention: 2 matmuls * s^2/2 * heads*hd per attn layer
            total += 2.0 * n_attn * (s * s) * self.cfg.num_heads * self.cfg.head_dim
        return total

    def prefill_time(self, prompt_lens: Sequence[int]) -> float:
        if not prompt_lens:
            return 0.0
        t_compute = self.prefill_flops(prompt_lens) / (self.inst.tflops * 1e12)
        # weights must stream at least once per pass
        t_mem = self.weight_bytes / self.inst.hbm_bw
        return max(t_compute, t_mem)

    # -- decode (HBM-bound, §3.3) --------------------------------------------
    def decode_step_time(self, lengths: Sequence[int]) -> float:
        if not lengths:
            return 0.0
        kv = sum(state_bytes_at(self.cfg, l, DTYPE_BYTES) for l in lengths)
        t_mem = (self.active_weight_bytes + kv) / self.inst.hbm_bw
        flops = 2.0 * self.cfg.param_count(active_only=True) * len(lengths)
        t_compute = flops / (self.inst.tflops * 1e12)
        return max(t_mem, t_compute)

    # -- KV movement ----------------------------------------------------------
    def kv_bytes(self, length: int) -> float:
        return state_bytes_at(self.cfg, length, DTYPE_BYTES)

    def kv_transfer_time(self, length: int, *, overlap_layers: bool = False
                         ) -> float:
        """Whole-state transfer between instances. With per-layer streaming
        (AcceLLM §4.2.4) only the last layer's worth is visible latency."""
        t = self.kv_bytes(length) / self.inst.link_bw
        if overlap_layers:
            return t / max(1, len(self.cfg.block_pattern))
        return t

    # per-step mirror traffic is priced by the KV-store ledger:
    # SimStore.mirror_bytes_per_step (== LineCosts.mirror_bytes(1) per
    # mirrored request, the quantity the live executor counts in
    # stats['mirror_bytes'])
