"""Analytic per-iteration performance model.

Mirrors the paper's simulator (§5.1): computation, HBM bandwidth, memory
requirements and KV-transfer costs, parameterized by ModelConfig and
InstanceSpec. Prefill is compute-bound (§3.2); decode is HBM-bound (§3.3):
per decode step the instance must stream the weights once plus every
batched request's KV cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.kvbytes import (bytes_per_token, fixed_state_bytes,
                                state_bytes_at)
from repro.scheduling.actions import MirrorSync, StreamState
from repro.sim.devices import InstanceSpec
from repro.stepplan import DecodePlan, MixedPlan, PrefillPlan, TransferPlan

DTYPE_BYTES = 2


@dataclass(frozen=True)
class PerfModel:
    cfg: ModelConfig
    inst: InstanceSpec

    def __post_init__(self):
        if self.kv_capacity_bytes <= 0:
            raise ValueError(
                f"instance HBM too small for {self.cfg.name!r}: weights "
                f"(+10% activations) need "
                f"{1.1 * self.weight_bytes / 1e9:.1f} GB but the instance "
                f"has {self.inst.hbm_bytes / 1e9:.1f} GB — no capacity "
                f"left for KV/serving state.  Use more/larger devices per "
                f"instance (InstanceSpec) or a smaller model.")

    @cached_property
    def weight_bytes(self) -> float:
        return self.cfg.param_count() * DTYPE_BYTES

    @cached_property
    def active_weight_bytes(self) -> float:
        """Bytes of weights actually read per decode step (MoE: active only)."""
        return self.cfg.param_count(active_only=True) * DTYPE_BYTES

    # param/arch walks are priced once; the sim calls these per iteration
    @cached_property
    def _n_active(self) -> int:
        return self.cfg.param_count(active_only=True)

    @cached_property
    def _n_attn(self) -> int:
        return sum(1 for b in self.cfg.block_pattern if b == "attn")

    @cached_property
    def _line_bytes(self) -> float:
        return bytes_per_token(self.cfg, DTYPE_BYTES)

    @cached_property
    def _fixed_bytes(self) -> int:
        return fixed_state_bytes(self.cfg, DTYPE_BYTES)

    @property
    def kv_capacity_bytes(self) -> float:
        """HBM left for serving state after weights (+10% activations)."""
        return self.inst.hbm_bytes - 1.1 * self.weight_bytes

    @cached_property
    def line_costs(self) -> "LineCosts":
        """The shared per-line cost card (``repro.kvstore.LineCosts``)
        the SimStore ledger and the live PagedStore both charge from."""
        from repro.kvstore import LineCosts
        return LineCosts.from_config(self.cfg, DTYPE_BYTES)

    # -- prefill (compute-bound, §3.2) --------------------------------------
    def prefill_flops(self, prompt_lens: Sequence[int]) -> float:
        n_active = self._n_active
        total = 0.0
        n_attn = self._n_attn
        for s in prompt_lens:
            total += 2.0 * n_active * s
            # causal attention: 2 matmuls * s^2/2 * heads*hd per attn layer
            total += 2.0 * n_attn * (s * s) * self.cfg.num_heads * self.cfg.head_dim
        return total

    def prefill_time(self, prompt_lens: Sequence[int]) -> float:
        if not prompt_lens:
            return 0.0
        t_compute = self.prefill_flops(prompt_lens) / (self.inst.tflops * 1e12)
        # weights must stream at least once per pass
        t_mem = self.weight_bytes / self.inst.hbm_bw
        return max(t_compute, t_mem)

    def chunked_prefill_time(self, chunks: Sequence[Tuple[int, int]]) -> float:
        """Prefill time for resumed chunks ``(start, end)``: a chunk's
        queries attend over ALL cached history rows ``[0, end)``, not
        just the chunk — the cost the live ``prefill_chunk`` path
        actually pays.  ``(0, s)`` degenerates to ``prefill_time([s])``
        exactly."""
        if not chunks:
            return 0.0
        n_active = self._n_active
        n_attn = self._n_attn
        total = 0.0
        for start, end in chunks:
            c = end - start
            total += 2.0 * n_active * c
            # causal q*k pairs: c*start full-history plus c^2/2 in-chunk,
            # scaled like prefill_flops' (s*s) convention (2 matmuls)
            total += (2.0 * n_attn * (c * c + 2.0 * c * start)
                      * self.cfg.num_heads * self.cfg.head_dim)
        t_compute = total / (self.inst.tflops * 1e12)
        t_mem = self.weight_bytes / self.inst.hbm_bw
        return max(t_compute, t_mem)

    # -- decode (HBM-bound, §3.3) --------------------------------------------
    def decode_step_time(self, lengths: Sequence[int]) -> float:
        """Deprecated: price decode through
        ``plan_time(DecodePlan(...))`` — the one step-cost entry point —
        so block granularity, mirror bounds and dispatch amortization
        are never bypassed."""
        import warnings
        warnings.warn(
            "PerfModel.decode_step_time is deprecated; price decode "
            "iterations through plan_time(DecodePlan(0, lengths=...))",
            DeprecationWarning, stacklevel=2)
        return self.plan_time(DecodePlan(0, lengths=tuple(lengths)))

    def _decode_iter_time(self, lengths: Sequence[int],
                          block_lines: int = 0, grown: int = 0) -> float:
        """One decode iteration over the resident ``lengths``: HBM-bound
        over active weights + each request's KV read.  With
        ``block_lines`` the read is block-granular — what the paged
        gather actually DMAs — so lines round up to whole blocks;
        ``grown`` models lines already appended by earlier steps of a
        fused plan."""
        if not lengths:
            return 0.0
        # integer line totals, one multiply: bytes are exact integers in
        # float64 so this equals the per-request Σ state_bytes_at bit
        # for bit (sums stay far below 2**53)
        if block_lines:
            tot = sum(-(-(l + grown) // block_lines) * block_lines
                      for l in lengths)
        else:
            tot = sum(lengths) + grown * len(lengths)
        kv = self._line_bytes * tot + self._fixed_bytes * len(lengths)
        t_mem = (self.active_weight_bytes + kv) / self.inst.hbm_bw
        flops = 2.0 * self._n_active * len(lengths)
        t_compute = flops / (self.inst.tflops * 1e12)
        return max(max(t_mem, t_compute),
                   self.tp_collective_time(len(lengths)))

    def tp_collective_time(self, batch: int) -> float:
        """Per-step tensor-parallel all-reduce over the slice's intra
        fabric (ring: ``2 (n-1)/n`` activation bytes per layer).  Priced
        ONLY when a spec declares ``intra_link_gbps`` explicitly — the
        seed model treats the TP fabric as free, and every existing
        snapshot must stay bit-identical unless a spec opts in."""
        n = self.inst.n_devices
        if self.inst.intra_link_gbps is None or n <= 1 or batch <= 0:
            return 0.0
        act = batch * self.cfg.d_model * DTYPE_BYTES
        layers = len(self.cfg.block_pattern)
        return layers * 2.0 * (n - 1) / n * act / self.inst.intra_link_bw

    # -- step plans (THE simulator cost entry point) --------------------------
    def plan_time(self, plan) -> float:
        """Price one :class:`repro.stepplan.StepPlan` — the simulator's
        only step-cost entry point: ``sim/cluster.py`` and every policy
        adapter charge iterations exclusively through here, so the cost
        arithmetic for an iteration lives in one place, keyed by the
        same plan objects the live executor runs.

        * PrefillPlan — compute-bound prompt work over the items' real
          chunk spans, including each resumed chunk's attention over
          its cached history (bucket padding is a live-compile concern,
          not modeled cost).
        * DecodePlan  — HBM-bound batch iterations over the resident
          line counts, read at the pool's block granularity (the paged
          gather DMAs whole blocks, not exact lines); ``steps`` fused
          iterations price each step at its grown lengths and pay the
          fixed per-dispatch overhead (``InstanceSpec.dispatch_s``)
          ONCE — the amortization the live engine's fused scan
          realizes.  When requests are mirrored, the per-step replica
          sync (one KV line each over the pair link) may bound each
          step instead (paper Fig. 10).
        * MixedPlan   — prefill + decode co-batched: the sum (the vLLM
          TBT spike of Fig. 5/16).
        * TransferPlan — StreamState moves the whole state over the
          link (per-layer overlapped when flagged, §4.2.4); MirrorSync
          moves only its delta lines; role flips and evictions are
          free.
        """
        if isinstance(plan, MixedPlan):
            t = self.plan_time(plan.prefill)
            if plan.decode is not None:
                t += self.plan_time(plan.decode)
            return t
        if isinstance(plan, PrefillPlan):
            return self.chunked_prefill_time(
                [(it.start, it.end) for it in plan.items])
        if isinstance(plan, DecodePlan):
            if not plan.lengths:
                return 0.0
            # mirror traffic charged from the shared ledger costs:
            # one new KV line per mirrored request per step (§4.1.2)
            t_link = (plan.mirrored * self.line_costs.mirror_bytes(1)
                      / self.inst.link_bw)
            total = self.inst.dispatch_s       # once per plan, not per step
            for j in range(max(1, plan.steps)):
                t = self._decode_iter_time(plan.lengths, plan.block_lines,
                                           grown=j)
                if plan.mirrored:
                    t = max(t, t_link)
                total += t
            return total
        if isinstance(plan, TransferPlan):
            # the fabric the bytes ride: mirror/stream between instances
            # defaults to the inter-slice link; an intra-slice plan
            # (same-host mesh slices) prices at the TP fabric's rate
            bw = self.link_bw_for(plan.link)
            if isinstance(plan.action, StreamState):
                return self.kv_transfer_time(
                    plan.lines, overlap_layers=plan.overlap_layers, bw=bw)
            if isinstance(plan.action, MirrorSync):
                return self.line_costs.mirror_bytes(plan.lines) / bw
            return 0.0  # PromoteReplica / EvictReplica: zero-cost flips
        raise TypeError(f"not a step plan: {plan!r}")

    # -- KV movement ----------------------------------------------------------
    def kv_bytes(self, length: int) -> float:
        return state_bytes_at(self.cfg, length, DTYPE_BYTES)

    def kv_transfer_time(self, length: int, *, overlap_layers: bool = False,
                         bw: float | None = None) -> float:
        """Whole-state transfer between instances. With per-layer streaming
        (AcceLLM §4.2.4) only the last layer's worth is visible latency."""
        t = self.kv_bytes(length) / (self.inst.link_bw if bw is None else bw)
        if overlap_layers:
            return t / max(1, len(self.cfg.block_pattern))
        return t

    def link_bw_for(self, link: str) -> float:
        """Bandwidth (bytes/s) of the named fabric — ``"inter"`` for the
        instance-to-instance network, ``"intra"`` for the in-slice TP
        link (``TransferPlan.link``)."""
        return (self.inst.intra_link_bw if link == "intra"
                else self.inst.inter_link_bw)

    # per-step mirror traffic is priced by the KV-store ledger:
    # SimStore.mirror_bytes_per_step (== LineCosts.mirror_bytes(1) per
    # mirrored request, the quantity the live executor counts in
    # stats['mirror_bytes'])
