"""Accelerator device models (paper Table 1 + the TPU target of this repo)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    fp16_tflops: float        # peak dense fp16/bf16
    hbm_gb: float
    hbm_bw_gbps: float        # GB/s
    link_gbps: float          # inter-device / inter-instance GB/s
    # achievable fractions (calibration knobs; defaults follow common MFU /
    # bandwidth-utilization figures for serving workloads)
    compute_eff: float = 0.55
    bw_eff: float = 0.80


# Paper Table 1
H100 = DeviceSpec("H100", fp16_tflops=989.0, hbm_gb=80.0,
                  hbm_bw_gbps=3350.0, link_gbps=900.0)
ASCEND_910B2 = DeviceSpec("910B2", fp16_tflops=400.0, hbm_gb=64.0,
                          hbm_bw_gbps=1800.0, link_gbps=392.0)
# This repo's deployment target (roofline constants from the brief)
TPU_V5E = DeviceSpec("v5e", fp16_tflops=197.0, hbm_gb=16.0,
                     hbm_bw_gbps=819.0, link_gbps=50.0)

DEVICES = {d.name: d for d in (H100, ASCEND_910B2, TPU_V5E)}


@dataclass(frozen=True)
class InstanceSpec:
    """An AcceLLM instance: n accelerators under tensor parallelism
    (paper §4.2.3: 4 accelerators, TP=4, full model replica per instance)."""

    device: DeviceSpec
    n_devices: int = 4
    #: fixed host-side cost per decode *dispatch* (kernel launch + the
    #: host round-trip that reads the sampled tokens back), in seconds.
    #: A fused multi-step DecodePlan pays it once per plan, not per
    #: token — the amortization the live engine's ``decode_multi`` scan
    #: realizes.  0 keeps the seed cost model (pure roofline).
    dispatch_s: float = 0.0
    #: per-link bandwidths of the mesh slice backing this instance
    #: (repro.meshserve): *intra*-slice is the NVLink/ICI-class fabric
    #: the TP collectives ride; *inter*-slice is the network link that
    #: carries MirrorSync / StreamState traffic between instances.
    #: ``None`` falls back to the device's ``link_gbps`` for both, so
    #: the seed cost model is unchanged unless a spec says otherwise.
    #: This is the ONE home of link pricing — benchmarks and the sim
    #: must read bandwidths from here, never hardcode them.
    intra_link_gbps: Optional[float] = None
    inter_link_gbps: Optional[float] = None

    @property
    def tflops(self) -> float:
        return self.device.fp16_tflops * self.n_devices * self.device.compute_eff

    @property
    def hbm_bytes(self) -> float:
        return self.device.hbm_gb * 1e9 * self.n_devices

    @property
    def hbm_bw(self) -> float:
        return self.device.hbm_bw_gbps * 1e9 * self.n_devices * self.device.bw_eff

    @property
    def intra_link_bw(self) -> float:
        """Bytes/s across devices WITHIN this instance's mesh slice."""
        g = (self.intra_link_gbps if self.intra_link_gbps is not None
             else self.device.link_gbps)
        return g * 1e9

    @property
    def inter_link_bw(self) -> float:
        """Bytes/s between this instance's slice and another's."""
        g = (self.inter_link_gbps if self.inter_link_gbps is not None
             else self.device.link_gbps)
        return g * 1e9

    @property
    def link_bw(self) -> float:
        """Instance-to-instance bandwidth (mirror/stream traffic rides
        the inter-slice link)."""
        return self.inter_link_bw
