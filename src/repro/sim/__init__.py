from repro.sim.cluster import Policy, SimInstance, Simulator
from repro.sim.devices import ASCEND_910B2, DEVICES, H100, TPU_V5E, InstanceSpec
from repro.sim.metrics import Summary, summarize
from repro.sim.perf import PerfModel
from repro.sim.policies import (AcceLLMPolicy, SarathiPolicy,
                                SplitwisePolicy, ULBPolicy, VLLMPolicy)
from repro.sim.workload import WORKLOADS, SimRequest, make_workload

__all__ = [
    "Simulator", "SimInstance", "Policy", "PerfModel", "InstanceSpec",
    "H100", "ASCEND_910B2", "TPU_V5E", "DEVICES", "Summary", "summarize",
    "AcceLLMPolicy", "SarathiPolicy", "SplitwisePolicy", "ULBPolicy",
    "VLLMPolicy", "WORKLOADS",
    "SimRequest", "make_workload",
]
