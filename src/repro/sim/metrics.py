"""Metric aggregation: TTFT / TBT / JCT / cost-efficiency (paper §3.4)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.sim.workload import SimRequest


@dataclass
class Summary:
    n_finished: int
    ttft_p50: float
    ttft_p99: float
    tbt_mean: float
    tbt_p99: float
    tbt_worst: float
    jct_p50: float
    jct_p99: float
    tokens_per_inst_s: float
    duration: float

    def row(self) -> str:
        return (f"{self.n_finished},{self.ttft_p50:.4f},{self.ttft_p99:.4f},"
                f"{self.tbt_mean:.5f},{self.tbt_p99:.5f},{self.tbt_worst:.5f},"
                f"{self.jct_p50:.3f},{self.jct_p99:.3f},"
                f"{self.tokens_per_inst_s:.2f}")

    HEADER = ("finished,ttft_p50,ttft_p99,tbt_mean,tbt_p99,tbt_worst,"
              "jct_p50,jct_p99,tok_per_inst_s")


def summarize(finished: List[SimRequest], n_instances: int,
              duration: float) -> Summary:
    if not finished:
        return Summary(0, *([float("nan")] * 7), 0.0, duration)
    ttfts = np.array([r.ttft() for r in finished])
    jcts = np.array([r.jct() for r in finished])
    tbts = np.concatenate([np.asarray(r.tbts()) for r in finished
                           if len(r.token_times) > 1] or [np.zeros(1)])
    tokens = sum(r.generated for r in finished)
    return Summary(
        n_finished=len(finished),
        ttft_p50=float(np.percentile(ttfts, 50)),
        ttft_p99=float(np.percentile(ttfts, 99)),
        tbt_mean=float(tbts.mean()),
        tbt_p99=float(np.percentile(tbts, 99)),
        tbt_worst=float(tbts.max()),
        jct_p50=float(np.percentile(jcts, 50)),
        jct_p99=float(np.percentile(jcts, 99)),
        tokens_per_inst_s=tokens / (n_instances * duration),
        duration=duration,
    )
