"""Metric aggregation: TTFT / TBT / JCT / cost-efficiency (paper §3.4),
plus the SLO axes (attainment / goodput) from the shared traffic layer."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

from repro.workloads.metrics import SLO, slo_summary


@dataclass
class Summary:
    n_finished: int
    ttft_p50: float
    ttft_p99: float
    tbt_mean: float
    tbt_p99: float
    tbt_worst: float
    jct_p50: float
    jct_p99: float
    tokens_per_inst_s: float
    duration: float
    n_unfinished: int = 0
    slo_attainment: float = float("nan")
    goodput: float = float("nan")
    #: mean wall-clock scheduler overhead per iteration (µs) — filled
    #: from ``Simulator.sched_us_per_iter`` / the live cluster's
    #: counterpart when the caller passes it; nan when untimed
    sched_us_per_iter: float = float("nan")

    def row(self) -> str:
        return (f"{self.n_finished},{self.ttft_p50:.4f},{self.ttft_p99:.4f},"
                f"{self.tbt_mean:.5f},{self.tbt_p99:.5f},{self.tbt_worst:.5f},"
                f"{self.jct_p50:.3f},{self.jct_p99:.3f},"
                f"{self.tokens_per_inst_s:.2f},{self.n_unfinished},"
                f"{self.slo_attainment:.4f},{self.goodput:.3f}")

    HEADER = ("finished,ttft_p50,ttft_p99,tbt_mean,tbt_p99,tbt_worst,"
              "jct_p50,jct_p99,tok_per_inst_s,unfinished,slo_attainment,"
              "goodput")


def summarize(requests: Iterable, n_instances: int, duration: float,
              slo: Optional[SLO] = None,
              sched_us_per_iter: float = float("nan")) -> Summary:
    """Aggregate latency metrics over a request set.

    Unfinished requests (no ``finish_time``) are counted into
    ``n_unfinished`` and excluded from the percentiles rather than
    crashing the aggregation — an overloaded open-loop run is a result,
    not an error.  With ``slo`` set, ``slo_attainment``/``goodput`` score
    the whole submitted set (unfinished = missed)."""
    reqs = list(requests)
    finished = [r for r in reqs if r.finish_time is not None]
    n_unfinished = len(reqs) - len(finished)
    if slo is not None:
        s = slo_summary(reqs, slo, duration)
        slo_attainment, goodput = s.attainment, s.goodput
    else:
        slo_attainment = goodput = float("nan")
    if not finished:
        return Summary(0, *([float("nan")] * 7), 0.0, duration,
                       n_unfinished=n_unfinished,
                       slo_attainment=slo_attainment, goodput=goodput,
                       sched_us_per_iter=sched_us_per_iter)
    ttfts = np.array([r.ttft() for r in finished])
    jcts = np.array([r.jct() for r in finished])
    all_tbts = [np.asarray(r.tbts()) for r in finished
                if len(r.token_times) > 1]
    # no [0.0] sentinel: a run with no inter-token gaps has no TBT at all
    tbts = np.concatenate(all_tbts) if all_tbts else np.array([float("nan")])
    tokens = sum(r.generated for r in finished)
    return Summary(
        n_finished=len(finished),
        ttft_p50=float(np.percentile(ttfts, 50)),
        ttft_p99=float(np.percentile(ttfts, 99)),
        tbt_mean=float(tbts.mean()),
        tbt_p99=float(np.percentile(tbts, 99)),
        tbt_worst=float(tbts.max()),
        jct_p50=float(np.percentile(jcts, 50)),
        jct_p99=float(np.percentile(jcts, 99)),
        tokens_per_inst_s=tokens / (n_instances * duration),
        duration=duration,
        n_unfinished=n_unfinished,
        slo_attainment=slo_attainment,
        goodput=goodput,
        sched_us_per_iter=sched_us_per_iter,
    )
