"""The shared step planner: scheduling actions -> execution plans.

``Planner.compile(actions, view)`` groups one iteration's declarative
actions into per-instance :mod:`repro.stepplan.plans` objects.  Both
backends run one planner instance per executor, configured from the same
policy kernel (``Planner.for_policy``), so an iteration's shape — what
is batched, how prompts are bucketed and chunked, whether prefill may
co-schedule with decode — is decided in exactly one place:

* **Bucketing** — whole-prompt items share a power-of-two
  ``bucket_len`` (the live backend's jit cache key; the sim prices real
  token counts).
* **Chunking** — with ``chunk_tokens`` set (Sarathi), the per-iteration
  prompt-token budget is spent across the prefill actions in order,
  in-progress prompts first; cursors are tracked here and resumed on the
  next compile, so a prompt longer than the budget spans iterations on
  *either* backend.
* **The §4.2.3 invariant** — a policy with ``allow_mixed = False``
  (AcceLLM, Splitwise) can never see prefill and decode co-scheduled on
  one instance: compile raises :class:`PlanError` instead of producing a
  :class:`MixedPlan`.

Transfer actions (``StreamState`` / ``MirrorSync`` / ``PromoteReplica``
/ ``EvictReplica``) are wrapped into :class:`TransferPlan` with the line
counts the cost model needs, resolved against the view's ledger.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

from repro.stepplan.plans import (DecodePlan, MixedPlan, PlanError,
                                  PrefillItem, PrefillPlan, StepPlan,
                                  TransferPlan, bucket_len)

if TYPE_CHECKING:  # runtime import would cycle: scheduling -> live -> here
    from repro.scheduling.actions import Action, Prefill


class Planner:
    def __init__(self, allow_mixed: bool = True,
                 chunk_tokens: Optional[int] = None,
                 bucket_floor: int = 16,
                 max_bucket: Optional[int] = None):
        if chunk_tokens is not None and chunk_tokens <= 0:
            raise ValueError(f"chunk_tokens must be positive: {chunk_tokens}")
        self.allow_mixed = allow_mixed
        self.chunk_tokens = chunk_tokens
        self.bucket_floor = bucket_floor
        self.max_bucket = max_bucket
        #: False when the executor cannot resume prompts mid-chunk
        #: (recurrent stacks): the chunk budget then throttles how many
        #: WHOLE prompts are planned per iteration instead of splitting
        #: them, so Sarathi's bounded-work-per-iteration contract
        #: survives on every backend.
        self.chunk_execution = True
        #: False for executors that never price plans (the live
        #: backend): DecodePlan lengths/mirrored are ledger-dict builds
        #: per instance per iteration, wasted when nothing reads them.
        #: Tracing re-enables them regardless (golden-trace equality).
        self.decode_details = True
        #: fused decode ceiling: how many decode iterations one
        #: DecodePlan may execute as a single dispatch.  1 disables
        #: fusing (seed semantics); the executor raises it for idle
        #: open-loop stretches.
        self.max_fuse_steps = 1
        #: per-iteration fuse bound set by the executor before compile
        #: (iterations until the next arrival / scheduling point; None =
        #: unbounded).  Fusing never crosses a scheduling decision.
        self.fuse_horizon: Optional[int] = None
        #: rid -> prompt tokens already prefilled (resumable chunk
        #: cursors; entries exist only while a prompt is mid-chunk).
        self._cursors: Dict[int, int] = {}
        #: optional normalized plan log (golden-trace consistency tests)
        self.trace: Optional[list] = None

    @classmethod
    def for_policy(cls, policy, max_bucket: Optional[int] = None) -> "Planner":
        """Configure a planner from a ``SchedulerPolicy`` kernel: the
        kernel declares whether it mixes phases (``allow_mixed``) and its
        chunk budget (``chunk_tokens``)."""
        return cls(allow_mixed=getattr(policy, "allow_mixed", True),
                   chunk_tokens=getattr(policy, "chunk_tokens", None),
                   max_bucket=max_bucket)

    # -- cursor feedback ------------------------------------------------------
    def cursor(self, rid: int) -> int:
        """Prompt tokens of ``rid`` already planned (0 = not started or
        finished).  Executor views report chunk progress through this,
        so policy kernels see planner feedback (backlog tokens shrink as
        chunks land)."""
        return self._cursors.get(rid, 0)

    def forget(self, rid: int):
        """Drop the chunk cursor of an abandoned request."""
        self._cursors.pop(rid, None)

    # -- compilation ----------------------------------------------------------
    def compile(self, actions: Sequence["Action"], view) -> List[StepPlan]:
        """Group one iteration's actions into per-instance plans.

        Prefill/Decode actions merge into PrefillPlan / DecodePlan /
        MixedPlan per instance (first-seen instance order); transfer
        actions are wrapped in order after them."""
        from repro.scheduling.actions import Decode, Prefill
        prefills: Dict[int, List["Prefill"]] = {}
        decodes = set()
        order: List[int] = []
        transfers: List["Action"] = []
        for act in actions:
            if isinstance(act, Prefill):
                if act.instance not in prefills and act.instance not in decodes:
                    order.append(act.instance)
                prefills.setdefault(act.instance, []).append(act)
            elif isinstance(act, Decode):
                if act.instance not in prefills and act.instance not in decodes:
                    order.append(act.instance)
                decodes.add(act.instance)
            else:
                transfers.append(act)

        plans: List[StepPlan] = []
        for idx in order:
            pplan = None
            acts = prefills.get(idx, [])
            items = self._plan_items(acts)
            if items:
                bucket = bucket_len(
                    max((it.prompt_len for it in items if it.completes
                         and it.start == 0), default=0),
                    floor=self.bucket_floor, cap=self.max_bucket)
                pplan = PrefillPlan(idx, tuple(items), bucket,
                                    self.chunk_tokens)
            dplan = self._decode_plan(idx, view) if idx in decodes else None
            if pplan is not None and dplan is not None:
                if not self.allow_mixed:
                    raise PlanError(
                        f"instance {idx}: prefill and decode co-scheduled in "
                        f"one iteration, but this policy forbids mixing "
                        f"(AcceLLM §4.2.3: prefill and decode are never "
                        f"co-scheduled on one instance)")
                plan: StepPlan = MixedPlan(idx, pplan, dplan)
            else:
                plan = pplan if pplan is not None else dplan
            if plan is not None:
                plans.append(plan)
                self._note(plan)
        for act in transfers:
            plans.append(self._wrap_transfer(act, view))
        return plans

    # -- chunking (resumable cursors) -----------------------------------------
    @staticmethod
    def _hit(act: "Prefill") -> int:
        """Prefix-cache hit stamped on the request at action creation:
        the prefill starts past it — chunk cursors are seeded there and
        whole-prompt items price only the suffix.  Both backends stamp
        before compile, so plans (and golden traces) agree."""
        return int(getattr(act.req, "prefix_hit", 0) or 0)

    def _plan_items(self, acts: Sequence["Prefill"]) -> List[PrefillItem]:
        items: List[PrefillItem] = []
        if self.chunk_tokens is None:
            for act in acts:
                items.append(PrefillItem(act.rid, act.prompt_len,
                                         self._hit(act),
                                         act.prompt_len, req=act.req))
            return items
        budget = self.chunk_tokens
        for act in acts:
            if budget <= 0:
                break
            if not self.chunk_execution:
                # whole-prompt throttle: always admit the first prompt
                # (so oversized prompts cannot starve), further ones
                # only while the budget lasts (engines without chunk
                # resume have no prefix cache either: start stays 0)
                if items and act.prompt_len > budget:
                    break
                items.append(PrefillItem(act.rid, act.prompt_len, 0,
                                         act.prompt_len, req=act.req))
                budget -= act.prompt_len
                continue
            cur = self._cursors.get(act.rid, self._hit(act))
            take = min(max(act.prompt_len - cur, 0), budget)
            if take <= 0 and cur >= act.prompt_len:
                continue
            end = cur + take
            items.append(PrefillItem(act.rid, act.prompt_len, cur, end,
                                     req=act.req))
            budget -= take
            if end >= act.prompt_len:
                self._cursors.pop(act.rid, None)
            else:
                self._cursors[act.rid] = end
        return items

    # -- decode stats from the view ledger ------------------------------------
    def _decode_plan(self, idx: int, view) -> DecodePlan:
        # the per-iteration ledger summaries are skipped whenever they
        # can't be consumed: executor doesn't price plans, no trace, and
        # fusing is off — statically (max_fuse_steps) or for THIS
        # iteration (the executor's fuse_horizon says a scheduling
        # point is due next tick anyway)
        horizon = (self.fuse_horizon if self.fuse_horizon is not None
                   else self.max_fuse_steps)
        fusing = self.max_fuse_steps > 1 and horizon > 1
        if not self.decode_details and self.trace is None and not fusing:
            return DecodePlan(idx)
        inst = view.instances()[idx]
        bl = inst.block_lines() if hasattr(inst, "block_lines") else 0
        stats = getattr(inst, "decode_plan_stats", None)
        if stats is not None:
            # array-backed views (repro.scale) serve the rid-ordered
            # length tuple + mirrored count straight from their caches —
            # same values as the dict walk below, no dicts built
            lengths, mirrored = stats()
            if not lengths:
                return DecodePlan(idx, block_lines=bl)
            return DecodePlan(idx, lengths, mirrored,
                              steps=self._fuse_steps(inst, mirrored),
                              block_lines=bl)
        lines = inst.request_lines()
        if not lines:
            # membership is resolved at execution time (a request may
            # stream in post-prefill, within the iteration); an empty
            # plan prices to zero on the sim side
            return DecodePlan(idx, block_lines=bl)
        placements = view.placements()
        mirrored = sum(1 for rid in lines
                       if placements.get(rid, (None, None))[1] is not None)
        lengths = tuple(l for _, l in sorted(lines.items()))
        return DecodePlan(idx, lengths, mirrored,
                          steps=self._fuse_steps(inst, mirrored),
                          block_lines=bl)

    def _fuse_steps(self, inst, mirrored: int) -> int:
        """How many decode iterations this instance may run as one fused
        dispatch.  Mirror-bound decode (any resident request with a
        replica) keeps ``steps == 1``: its per-step ``MirrorSync`` is a
        scheduling point the fused scan must not run past.  So does a
        non-empty prefill backlog — the instance's role can flip next
        iteration.  Otherwise the executor's ``fuse_horizon`` (time to
        the next arrival) and the residents' shortest remaining token
        budget cap the span, so a fused block never runs past the
        iteration its first request completes."""
        n = min(self.max_fuse_steps,
                self.fuse_horizon if self.fuse_horizon is not None
                else self.max_fuse_steps)
        if n <= 1 or mirrored or inst.prefill_backlog():
            return 1
        if hasattr(inst, "decode_remaining"):
            rem = inst.decode_remaining()
            if rem:
                n = min(n, max(1, min(rem.values())))
        # floor to a power of two: `steps` is a static shape of the live
        # backend's jitted scan, so arbitrary horizon values would each
        # compile a fresh kernel (flooring never overruns a scheduling
        # point, it only ends the span early)
        return 1 << (n.bit_length() - 1)

    # -- transfer wrapping ----------------------------------------------------
    def _wrap_transfer(self, act: "Action", view) -> TransferPlan:
        from repro.scheduling.actions import (EvictReplica, MirrorSync,
                                              PromoteReplica, StreamState)
        if isinstance(act, StreamState):
            lines = view.instances()[act.src].request_lines().get(act.rid, 0)
            # lines already resident in the destination's prefix cache
            # don't move: a shared-prefix replica streams its unique
            # suffix only
            lines = max(0, lines - getattr(act, "skip_lines", 0))
            return TransferPlan(act.src, act, lines=lines,
                                overlap_layers=True)
        if isinstance(act, MirrorSync):
            lo, hi = act.from_line, act.to_line
            if hi is None:
                hi = view.instances()[act.primary].request_lines().get(
                    act.rid, 0)
            if lo is None:
                lo = view.instances()[act.replica].replica_synced().get(
                    act.rid, 0)
            return TransferPlan(act.primary, act, lines=max(0, hi - lo))
        if isinstance(act, (PromoteReplica, EvictReplica)):
            inst = act.src if isinstance(act, PromoteReplica) else act.instance
            return TransferPlan(inst, act, lines=0)
        raise PlanError(f"cannot wrap action {act!r} into a transfer plan")

    # -- trace ----------------------------------------------------------------
    def _note(self, plan: StepPlan):
        if self.trace is None:
            return
        if isinstance(plan, DecodePlan) and not plan.lengths:
            return      # empty decode: a no-op placeholder, not work
        self.trace.append(_normalize(plan))


def _normalize(plan: StepPlan):
    """Backend-independent plan descriptor for golden-trace equality."""
    if isinstance(plan, MixedPlan):
        if plan.decode is None or not plan.decode.lengths:
            # nothing was resident to co-batch: this iteration IS a
            # prefill (the empty decode part only lets the live executor
            # run the same-iteration join)
            return _normalize(plan.prefill)
        return ("mixed", plan.instance, _normalize(plan.prefill)[2:],
                _normalize(plan.decode)[2:])
    if isinstance(plan, PrefillPlan):
        return ("prefill", plan.instance,
                tuple((it.rid, it.start, it.end) for it in plan.items),
                plan.bucket_len)
    if isinstance(plan, DecodePlan):
        # block_lines is a pricing detail, not iteration shape: excluded
        return ("decode", plan.instance, plan.lengths, plan.mirrored,
                plan.steps)
    return ("transfer", plan.instance, type(plan.action).__name__, plan.lines)
