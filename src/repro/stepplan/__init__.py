"""Step-plan layer: one execution-plan vocabulary, two backends.

``Planner.compile(actions, view)`` turns a scheduling iteration's
declarative actions into :class:`StepPlan` objects; the live executor
runs them on real engines, the simulator prices them through
``PerfModel.plan_time(plan)``.  See docs/ARCHITECTURE.md §"Step-plan
layer"."""
from repro.stepplan.planner import Planner
from repro.stepplan.plans import (DecodePlan, MixedPlan, PlanError,
                                  PrefillItem, PrefillPlan, StepPlan,
                                  TransferPlan, bucket_len, decode_part,
                                  prefill_part)

__all__ = ["Planner", "PlanError", "StepPlan", "PrefillItem", "PrefillPlan",
           "DecodePlan", "MixedPlan", "TransferPlan", "bucket_len",
           "prefill_part", "decode_part"]
