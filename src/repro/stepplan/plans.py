"""Step plans: the backend-agnostic unit of batch execution.

A *step plan* describes what one scheduling iteration executes on one
instance — the layer between the policy's declarative actions
(:mod:`repro.scheduling.actions`) and the backends.  The shared
:class:`repro.stepplan.Planner` compiles actions into plans; the live
executor *runs* them (``InstanceEngine.prefill_batch`` / ``decode``) and
the simulator *prices* them through the single cost entry point
``PerfModel.plan_time(plan)``.  Because both backends consume the
identical plan objects, live-vs-sim iteration semantics are comparable
by construction — the same way the traffic layer made time comparable
and the KV store made bytes comparable.

Plan vocabulary:

* :class:`PrefillPlan` — a batched prefill iteration: one or more
  :class:`PrefillItem` chunks, prompt lengths padded to power-of-two
  buckets (``bucket_len``) so the live engine compiles one kernel per
  bucket shape instead of one per distinct prompt length.  Items may be
  *chunks* of a prompt (Sarathi-style intra-prompt chunking) with
  resumable cursors over the KV ledger.
* :class:`DecodePlan` — one decode iteration over the instance's
  resident batch; carries the per-request line counts (the cost model's
  input) and the number of mirrored requests (whose per-step replica
  sync may bound the step, paper Fig. 10).
* :class:`MixedPlan` — prefill and decode co-scheduled in one iteration.
  Only baselines that deliberately mix (vLLM / Sarathi) may produce
  these; the planner *rejects* them for the AcceLLM policy — the §4.2.3
  invariant lives in one place instead of three executors.
* :class:`TransferPlan` — a state-movement action (``StreamState`` /
  ``MirrorSync`` / ``PromoteReplica`` / ``EvictReplica``) wrapped with
  the line count the cost model needs to price it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Tuple, Union

if TYPE_CHECKING:  # runtime import would cycle: scheduling -> live -> here
    from repro.scheduling.actions import Action


class PlanError(RuntimeError):
    """Raised when actions cannot be compiled into a legal plan (e.g.
    prefill+decode mixing under a policy that forbids it, §4.2.3)."""


def bucket_len(n: int, floor: int = 16, cap: Optional[int] = None) -> int:
    """Smallest power-of-two >= ``n`` (>= ``floor``), clamped to ``cap``.

    This is the padded shape a live backend compiles for: a stream of
    arbitrary prompt lengths maps onto O(log(max_len)) compiled kernels
    instead of one per distinct length."""
    b = max(1, floor)
    while b < n:
        b <<= 1
    if cap is not None:
        b = min(b, cap)
    return b


@dataclass(frozen=True)
class PrefillItem:
    """One request's share of a prefill iteration: prompt tokens
    ``[start, end)``.  ``start == 0 and end == prompt_len`` is a whole
    prompt; anything else is a resumable chunk whose cursor the planner
    tracks against the KV ledger."""
    rid: int
    prompt_len: int
    start: int
    end: int
    #: the backend's request record (live ``Request`` / ``SimRequest``);
    #: carried for executors, excluded from plan equality.
    req: object = field(default=None, compare=False, repr=False)

    @property
    def tokens(self) -> int:
        return self.end - self.start

    @property
    def completes(self) -> bool:
        """Whether this item finishes its request's prefill."""
        return self.end >= self.prompt_len


@dataclass(frozen=True)
class PrefillPlan:
    instance: int
    items: Tuple[PrefillItem, ...]
    #: padded token length of the batched whole-prompt path (power of
    #: two; the jit cache key on the live backend).
    bucket_len: int
    #: the per-iteration prompt-token budget that produced the items
    #: (None = unchunked).
    chunk_tokens: Optional[int] = None

    @property
    def total_tokens(self) -> int:
        return sum(it.tokens for it in self.items)

    def completed_rids(self) -> Tuple[int, ...]:
        return tuple(it.rid for it in self.items if it.completes)


@dataclass(frozen=True)
class DecodePlan:
    instance: int
    #: resident primaries' KV line counts (sorted by rid) — the decode
    #: cost model's input on the sim backend.
    lengths: Tuple[int, ...] = ()
    #: how many of those primaries have a replica to mirror into; their
    #: per-step sync traffic may bound the step (Fig. 10).
    mirrored: int = 0
    #: fused decode iterations this plan executes as one dispatch
    #: (``Planner`` decides; mirror-bound decode keeps ``steps == 1`` so
    #: every generated line syncs to its replica the same iteration).
    #: The live engine runs them as a single jitted ``lax.scan``; the
    #: cost model amortizes the per-dispatch overhead across them.
    steps: int = 1
    #: KV-pool block granularity (lines/block) of the executing
    #: instance: the paged gather reads whole blocks, so the cost model
    #: rounds each request's lines up to it (0 = price exact lines).
    block_lines: int = 0


@dataclass(frozen=True)
class MixedPlan:
    """Prefill co-scheduled with decode (vLLM / Sarathi baselines only —
    the planner refuses to build these for policies with
    ``allow_mixed = False``)."""
    instance: int
    prefill: PrefillPlan
    decode: Optional[DecodePlan] = None


@dataclass(frozen=True)
class TransferPlan:
    """A state-movement action plus the ledger quantities that price it:
    ``lines`` is the whole-state line count for a ``StreamState`` (or
    the delta line count for a ``MirrorSync``)."""
    instance: int
    action: "Action" = field(compare=False)
    lines: int = 0
    #: per-layer streamed transfer (§4.2.4): only the last layer's worth
    #: is exposed latency.
    overlap_layers: bool = False
    #: which fabric the bytes ride (repro.meshserve): ``"inter"`` is the
    #: instance-to-instance network link (mirror/stream between mesh
    #: slices); ``"intra"`` is the NVLink/ICI-class link within one
    #: slice.  The cost model picks the matching ``InstanceSpec``
    #: bandwidth; every transfer the planner emits today is inter-slice.
    link: str = "inter"


StepPlan = Union[PrefillPlan, DecodePlan, MixedPlan, TransferPlan]


def prefill_part(plan: StepPlan) -> Optional[PrefillPlan]:
    """The prefill work inside ``plan``, unwrapping MixedPlan."""
    if isinstance(plan, MixedPlan):
        return plan.prefill
    return plan if isinstance(plan, PrefillPlan) else None


def decode_part(plan: StepPlan) -> Optional[DecodePlan]:
    """The decode work inside ``plan``, unwrapping MixedPlan."""
    if isinstance(plan, MixedPlan):
        return plan.decode
    return plan if isinstance(plan, DecodePlan) else None
