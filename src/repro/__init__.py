"""AcceLLM reproduction: redundancy-based LLM serving on JAX/TPU."""

__version__ = "0.1.0"
