"""Mesh/sharding context shared across the model and launch layers.

The model code is mesh-agnostic: it consults this module for the active mesh
and logical-axis mapping. The launcher (or tests) installs a context via
``use_mesh``. With no context installed everything is single-device local
(CPU smoke tests).

Logical axes:
  batch  — data-parallel batch dim        -> ("pod", "data") or ("data",)
  model  — tensor/expert parallel dim     -> ("model",)
  none   — replicated
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class ShardCtx:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ()      # mesh axes forming the batch dim
    model_axis: Optional[str] = None      # mesh axis for tensor/expert parallel
    # MoE dispatch strategy: "a2a" (tokens shard over model axis, two
    # all_to_alls) or "psum" (each model shard computes its local experts on
    # all tokens, partial results all-reduced). "auto" picks per call site.
    moe_strategy: str = "auto"

    @property
    def batch_size_divisor(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]


_CTX = ShardCtx()


def current() -> ShardCtx:
    return _CTX


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], *, batch_axes=("data",), model_axis="model",
             moe_strategy: str = "auto"):
    global _CTX
    prev = _CTX
    _CTX = ShardCtx(mesh=mesh, batch_axes=tuple(batch_axes),
                    model_axis=model_axis, moe_strategy=moe_strategy)
    try:
        yield _CTX
    finally:
        _CTX = prev


def spec(*logical) -> P:
    """Translate logical axis names into a PartitionSpec for the active mesh."""
    ctx = _CTX
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax == "batch":
            out.append(ctx.batch_axes if ctx.batch_axes else None)
        elif ax == "model":
            out.append(ctx.model_axis)
        elif ax == "seq":
            # sequence parallelism: activations shard their seq dim over the
            # model axis between TP blocks (Megatron-SP); §Perf iteration 2
            out.append(ctx.model_axis)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def constrain(x, *logical):
    """with_sharding_constraint against the active mesh (no-op without one)."""
    ctx = _CTX
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec(*logical)))


def named(*logical) -> Optional[NamedSharding]:
    ctx = _CTX
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, spec(*logical))
