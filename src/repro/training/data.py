"""Synthetic token data pipeline: deterministic, shard-aware, infinite.

A "document LM" stream: tokens drawn from a Zipf-ish distribution with
per-document Markov structure so loss actually decreases during the e2e
training example (pure-uniform tokens give a flat loss — useless for
validating the optimizer path).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -a
    return (p / p.sum()).astype(np.float64)


def batches(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Yields (global_batch, seq_len+1) int32 — inputs are [:, :-1],
    labels are [:, 1:]."""
    rng = np.random.default_rng(cfg.seed)
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    # Markov bigram structure: each token biases the next towards
    # (token * 7 + 3) % vocab with prob q — learnable signal.
    q = 0.5
    while True:
        base = rng.choice(cfg.vocab_size, size=(cfg.global_batch,
                                                cfg.seq_len + 1), p=probs)
        follow = rng.random((cfg.global_batch, cfg.seq_len)) < q
        nxt = (base[:, :-1] * 7 + 3) % cfg.vocab_size
        base[:, 1:] = np.where(follow, nxt, base[:, 1:])
        yield base.astype(np.int32)
