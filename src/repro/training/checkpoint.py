"""Minimal dependency-free checkpointing: pytree <-> .npz with path keys."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix: str, node: Any):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}", node[k])
        elif isinstance(node, (list, tuple)):
            tag = "T" if isinstance(node, tuple) else "L"
            for i, v in enumerate(node):
                walk(f"{prefix}/{tag}{i}", v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat


def save(path: str, tree):
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **{k: v for k, v in flat.items()})


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def walk(prefix: str, node: Any):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}", node[k]) for k in sorted(node)}
        if isinstance(node, (list, tuple)):
            tag = "T" if isinstance(node, tuple) else "L"
            out = [walk(f"{prefix}/{tag}{i}", v) for i, v in enumerate(node)]
            return tuple(out) if isinstance(node, tuple) else out
        arr = data[prefix]
        return jax.numpy.asarray(arr).astype(node.dtype).reshape(node.shape)

    return walk("", like)
