"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, warmup: int, total: int, min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def wsd(step, *, warmup: int, total: int, decay_frac: float = 0.1,
        min_frac: float = 0.01):
    """Warmup -> Stable (flat) -> Decay (last ``decay_frac`` of steps,
    exponential-ish linear-in-log decay per the MiniCPM recipe)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    decay_start = total * (1.0 - decay_frac)
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1),
                    0.0, 1.0)
    decay = jnp.exp(jnp.log(jnp.maximum(min_frac, 1e-6)) * prog)
    return warm * jnp.where(step < decay_start, 1.0, decay)


SCHEDULES = {"cosine": cosine, "wsd": wsd}
