"""AdamW with configurable optimizer-state dtype (bf16 m/v for the >=398B
MoE archs so the per-chip memory analysis stays inside v5e HBM — DESIGN §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # "float32" | "bfloat16"


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(cfg: AdamWConfig, params) -> OptState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState,
                 lr_scale: jax.Array):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), gnorm
