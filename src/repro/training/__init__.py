from repro.training.data import DataConfig, batches
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)
from repro.training.schedules import SCHEDULES, cosine, wsd
from repro.training.train_step import (cross_entropy, loss_fn,
                                       make_train_step, train_step)

__all__ = [
    "AdamWConfig", "OptState", "init_opt_state", "adamw_update",
    "cosine", "wsd", "SCHEDULES", "DataConfig", "batches",
    "cross_entropy", "loss_fn", "train_step", "make_train_step",
]
