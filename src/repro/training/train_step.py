"""Training step: CE loss (+ router aux + optional MTP) and AdamW update."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_train
from repro.training.optimizer import AdamWConfig, OptState, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """logits (B,S,V) f32, labels (B,S) int32 -> scalar mean NLL."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    inputs = dict(batch)
    tokens = inputs.pop("tokens")
    labels = inputs.pop("labels")
    logits, aux = forward_train(cfg, params, {"tokens": tokens, **inputs})
    ce = cross_entropy(logits, labels)
    loss = ce + aux
    metrics = {"ce": ce, "router_aux": aux}
    return loss, metrics


def train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, params,
               opt_state: OptState, batch: Dict[str, jax.Array],
               lr_scale: jax.Array):
    """One optimizer step; returns (params, opt_state, metrics)."""
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    params, opt_state, gnorm = adamw_update(opt_cfg, params, grads,
                                            opt_state, lr_scale)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    return functools.partial(train_step, cfg, opt_cfg)
