from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                     analyze, collective_bytes, model_flops)

__all__ = ["analyze", "collective_bytes", "model_flops", "Roofline",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]
