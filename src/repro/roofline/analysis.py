"""Roofline analysis from the compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = weighted_collective_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-chip: the SPMD
module is a single-device program). Collective bytes are NOT in
cost_analysis: we parse the post-partitioning HLO text, crediting each
collective its result-shape bytes x a per-kind wire factor, and multiply
ops inside ``while`` bodies by the loop's ``known_trip_count`` (the layer
scan!), propagated through the computation call graph.

Wire factors (ring-algorithm per-device bytes, n = group size):
  all-gather      ~ R * (n-1)/n            (R = result bytes)
  all-reduce      ~ 2R * (n-1)/n
  reduce-scatter  ~ R                       (R = input ~ result*n; we see
                                             the result: R_res * (n-1))
  all-to-all      ~ R * (n-1)/n
  collective-permute ~ R

Hardware constants (TPU v5e, from the brief): 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI (single-link conservative).
"""
from __future__ import annotations

import json
import math
import os
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) (?:\([^)]*\))? ?->", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?body=%?([\w.\-]+).*?known_trip_count\":\{\"n\":\"(\d+)\"",
    re.S)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    if kind == "all-gather":
        return result_bytes * (n - 1) / max(n, 1)
    if kind == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / max(n, 1)
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)
    if kind == "all-to-all":
        return result_bytes * (n - 1) / max(n, 1)
    return float(result_bytes)  # collective-permute


def split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        header = re.match(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> ", line)
        if header and line.rstrip().endswith("{"):
            cur_name = header.group(1)
            cur_lines = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = ""
                comps[cur_name] = ""
                comps["__entry_name__"] = cur_name  # type: ignore
            continue
        if line.startswith("}"):
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def _multipliers(comps: Dict[str, str], entry: Optional[str]
                 ) -> Dict[str, float]:
    """Loop-trip multiplier per computation, propagated from ENTRY."""
    edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    for cname, body in comps.items():
        for line in body.splitlines():
            trip = 1
            wm = re.search(r"known_trip_count\":\{\"n\":\"(\d+)\"", line)
            if wm:
                trip = int(wm.group(1))
            for callee in _CALL_RE.findall(line):
                if callee in comps:
                    edges[cname].append((callee, trip if " while(" in line
                                         else 1))
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry is None or entry not in comps:
        entry = next(iter(comps)) if comps else None
    if entry is None:
        return mult
    mult[entry] = 1.0
    for _ in range(len(comps)):
        changed = False
        for cname, outs in edges.items():
            if mult.get(cname, 0.0) <= 0:
                continue
            for callee, trip in outs:
                want = mult[cname] * trip
                if want > mult.get(callee, 0.0):
                    mult[callee] = want
                    changed = True
        if not changed:
            break
    return mult


def _entry_and_comps(hlo: str):
    comps = split_computations(hlo)
    entry = comps.pop("__entry_name__", None)
    comps.pop("__entry__", None)
    return entry, comps


def collective_bytes(hlo: str) -> Tuple[float, Dict[str, float]]:
    """Per-device wire bytes of one program execution, with loop
    multipliers propagated through the call graph."""
    entry, comps = _entry_and_comps(hlo)
    mult = _multipliers(comps, entry)
    total = 0.0
    by_kind: Dict[str, float] = {}
    for cname, body in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for line in body.splitlines():
            lm = re.match(r"\s*%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                          r"reduce-scatter|all-to-all|collective-permute)"
                          r"(?:-start)?\(", line)
            if not lm:
                continue
            shape_str, kind = lm.group(1), lm.group(2)
            rb = _shape_bytes(shape_str)
            n = _group_size(line)
            wb = _wire_bytes(kind, rb, n) * m
            total += wb
            by_kind[kind] = by_kind.get(kind, 0.0) + wb
    return total, by_kind


# ---------------------------------------------------------------------------
# Exact matmul FLOPs from HLO (XLA cost_analysis counts while bodies ONCE —
# a known undercount; we re-derive dot FLOPs with the loop multipliers)
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+) = ([\w\[\],{}\s]+?) "
                       r"([\w\-]+)\(")
_PARAM_RE = re.compile(r"([\w.\-]+): ([\w]+\[[\d,]*\])")
_DOT_OPS_RE = re.compile(r" dot\(%?([\w.\-]+), %?([\w.\-]+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(shape_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def dot_flops(hlo: str) -> float:
    """Per-device matmul FLOPs of one execution (elementwise ops excluded,
    documented in EXPERIMENTS.md; matmuls dominate every assigned arch)."""
    raw = hlo
    # computation headers carry parameter shapes
    entry, comps = _entry_and_comps(raw)
    mult = _multipliers(comps, entry)

    # header param shapes per computation
    header_shapes: Dict[str, Dict[str, str]] = {}
    for line in raw.splitlines():
        h = re.match(r"^(?:ENTRY )?%?([\w.\-]+) \((.*)\) -> ", line)
        if h and line.rstrip().endswith("{"):
            header_shapes[h.group(1)] = dict(
                (nm, sh) for nm, sh in _PARAM_RE.findall(h.group(2)))

    total = 0.0
    for cname, body in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        local: Dict[str, str] = dict(header_shapes.get(cname, {}))
        lines = body.splitlines()
        for line in lines:
            im = _INSTR_RE.match(line)
            if im:
                local[im.group(1)] = im.group(2).strip()
        for line in lines:
            if " dot(" not in line:
                continue
            im = _INSTR_RE.match(line)
            ops = _DOT_OPS_RE.search(line)
            lc = _LHS_C_RE.search(line)
            if not (im and ops):
                continue
            res_dims = _shape_dims(im.group(2)) or []
            lhs_shape = local.get(ops.group(1))
            contract = 1
            if lhs_shape is not None and lc:
                ldims = _shape_dims(lhs_shape) or []
                for ci in lc.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        contract *= ldims[int(ci)]
            n_res = 1
            for d in res_dims:
                n_res *= d
            total += 2.0 * n_res * contract * m
    return total


# ---------------------------------------------------------------------------
# Analytic HBM bytes (the CPU backend's cost_analysis bytes are unusable:
# loop bodies counted once AND bf16 weights upcast to f32 by the CPU
# emitter; we model the real TPU traffic structurally instead)
# ---------------------------------------------------------------------------

ACT_IO_FACTOR = 12   # per-layer activation reads+writes, in units of
                     # tokens x d_model x 2B (block I/O, qkv/ffn temps)


def _scan_state_bytes(cfg, shape, chips: int) -> float:
    """Per-chip HBM traffic of recurrent-state carries over a full-sequence
    pass: every scan step reads+writes the carry. mLSTM runs CHUNKWISE
    (state touched once per chunk of 64 — §Perf iteration 7); mamba/sLSTM
    are per-step but their states are small."""
    if shape.kind == "decode":
        return 0.0
    from repro.models.state import xlstm_dims
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // 16, 1)                     # batch over the data axis
    total = 0.0
    for blk in cfg.block_pattern:
        if blk == "mamba":
            mc = cfg.mamba
            st = mc.expand * cfg.d_model * mc.d_state * 4
            total += 2.0 * b_loc * st * S       # r+w per step
        elif blk == "mlstm":
            _, hd = xlstm_dims(cfg, "mlstm")
            st = cfg.num_heads * hd * hd * 4
            steps = max(S // 64, 1)             # chunkwise: once per chunk
            total += 2.0 * b_loc * st * steps
        elif blk == "slstm":
            total += 2.0 * b_loc * 4 * cfg.d_model * 4 * S
    return total


def analytic_bytes(cfg, shape, chips: int = 256,
                   layout: str = "tp") -> float:
    """Per-chip HBM bytes of one step on the single-pod mesh
    (layout "tp": TP=16 on 'model', 16-way batch/FSDP on 'data';
    layout "fsdp": pure ZeRO-3 — each chip streams the full gathered
    weights but holds 1/256 of batch/optimizer)."""
    from repro.core.kvbytes import state_bytes_at
    tp = 1 if layout == "fsdp" else 16
    p_total = cfg.param_count() * 2
    p_active = cfg.param_count(active_only=True) * 2
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers

    if shape.kind == "decode":
        tokens_per_chip = max(B // 16, 1)
        w = p_active / tp                       # weights: TP-sharded read
        state = B * state_bytes_at(cfg, min(S, 1 << 30)) / chips
        acts = tokens_per_chip * d * 2 * ACT_IO_FACTOR * L
        logits = tokens_per_chip * cfg.vocab_size / tp * 4
        return w + state + acts + logits

    tokens = B * S
    # batch over data axis (tp) or the whole mesh (fsdp)
    tokens_per_chip = tokens / (chips if layout == "fsdp" else 16)
    acts = tokens_per_chip * d * 2 * ACT_IO_FACTOR * L
    logits = tokens_per_chip * cfg.vocab_size / tp * 4
    scan_state = _scan_state_bytes(cfg, shape, chips)
    if shape.kind == "prefill":
        w = p_active / tp
        kv_writes = tokens * (state_bytes_at(cfg, 1)
                              - state_bytes_at(cfg, 0)) / chips
        return w + acts + kv_writes + logits + scan_state

    # train: fwd + bwd weight reads (gathered per chip = model shard),
    # remat recompute of fwd activations, optimizer streams (FSDP-sharded)
    opt_bytes = 4 if cfg.param_count() <= 100e9 else 2
    w = 2 * p_total / tp
    opt = (2 * 2 + 2 * opt_bytes) * cfg.param_count() / chips  # p,g + m,v r/w
    # fwd + bwd + remat-fwd passes over the recurrent-state traffic
    return w + 2 * acts + opt + logits + 3 * scan_state


# ---------------------------------------------------------------------------
# Roofline record
# ---------------------------------------------------------------------------


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float            # useful FLOPs (6ND / 2ND), global
    hlo_flops_global: float
    collective_by_kind: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops_global \
            if self.hlo_flops_global else float("nan")


def model_flops(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per request
    return 2.0 * n_active * tokens


def analyze(record: dict, hlo_text: Optional[str], cfg, shape,
            chips: int = 256) -> Roofline:
    # loop-corrected matmul FLOPs from the partitioned HLO; fall back to the
    # (body-once) cost_analysis number when no HLO text was saved
    if hlo_text:
        fl = dot_flops(hlo_text)
        coll, by_kind = collective_bytes(hlo_text)
    else:
        fl = record.get("flops", 0.0)
        coll, by_kind = 0.0, {}
    by = analytic_bytes(cfg, shape, chips, layout=record.get("layout", "tp"))
    return Roofline(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        t_compute=fl / PEAK_FLOPS,
        t_memory=by / HBM_BW,
        t_collective=coll / LINK_BW,
        model_flops=model_flops(cfg, shape),
        hlo_flops_global=fl * chips,
        collective_by_kind=by_kind,
    )
