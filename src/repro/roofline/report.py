"""Roofline report CLI: reads results/dryrun_single.json + saved HLO and
emits the EXPERIMENTS.md §Roofline table.

Usage: PYTHONPATH=src python -m repro.roofline.report [--results DIR]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import analyze


def fmt_s(t: float) -> str:
    if t >= 1.0:
        return f"{t:7.2f}s "
    if t >= 1e-3:
        return f"{t * 1e3:7.2f}ms"
    return f"{t * 1e6:7.1f}us"


def one_liner(r) -> str:
    hints = {
        "compute": "raise MXU utilization / cut redundant FLOPs "
                   "(remat & masked-block waste)",
        "memory": "cut HBM traffic: fuse, shrink f32 temps, chunkwise scan",
        "collective": "reshard to remove all-gathers / overlap collectives "
                      "with compute",
    }
    return hints[r.dominant]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--dryrun", default="dryrun_single.json")
    ap.add_argument("--json", default=None, help="also dump terms as json")
    args = ap.parse_args()

    with open(os.path.join(args.results, args.dryrun)) as f:
        records = json.load(f)

    rows = []
    out_json = []
    for rec in records:
        if not rec.get("ok"):
            rows.append((rec["arch"], rec["shape"], "FAILED", "", "", "", "",
                         "", ""))
            continue
        cfg = get_config(rec["arch"])
        shape = INPUT_SHAPES[rec["shape"]]
        tag = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        hlo_path = os.path.join(args.results, f"hlo_{tag}.txt")
        hlo = open(hlo_path).read() if os.path.exists(hlo_path) else None
        r = analyze(rec, hlo, cfg, shape)
        rows.append((
            r.arch, r.shape, fmt_s(r.t_compute), fmt_s(r.t_memory),
            fmt_s(r.t_collective), r.dominant,
            f"{r.model_flops:.2e}", f"{r.useful_ratio:.2f}",
            one_liner(r)))
        out_json.append({
            "arch": r.arch, "shape": r.shape, "mesh": r.mesh,
            "t_compute": r.t_compute, "t_memory": r.t_memory,
            "t_collective": r.t_collective, "dominant": r.dominant,
            "model_flops": r.model_flops,
            "hlo_flops_global": r.hlo_flops_global,
            "useful_ratio": r.useful_ratio,
            "collective_by_kind": r.collective_by_kind,
        })

    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS | useful | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for row in rows:
        print("| " + " | ".join(str(c) for c in row) + " |")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out_json, f, indent=1)


if __name__ == "__main__":
    main()
