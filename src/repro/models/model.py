"""Top-level model: embedding → (encoder) → layer stack → head.

Pure-functional API used by training, serving, and the dry-run:

  init_params(key, cfg)                          -> params pytree
  forward_train(cfg, params, batch)              -> (logits, aux_loss)
  init_state(cfg, batch, seq_len, long_context)  -> serving state pytree
  prefill(cfg, params, batch, state)             -> (last_logits, state)
  decode_step(cfg, params, tokens, state, t)     -> (logits, state)

``batch`` is a dict: {"tokens": (B,S) int32} plus, per the modality
carve-out, {"patch_embeds": (B,P,E)} for VLMs or {"frames": (B,F,E)} for
audio enc-dec (precomputed frontend embeddings — see DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import (apply_stack, init_stack, init_stack_state,
                                 layer_specs, plan_segments)
from repro.models.common import (KeyGen, dense_init, dtype_of, embed_init,
                                 rms_norm, sinusoidal_positions)
from repro.models.state import cache_capacity

Array = jax.Array


def _segs(cfg: ModelConfig):
    return plan_segments(layer_specs(cfg))


def _seq_shard_ok(seq_len: int) -> bool:
    """Sequence parallelism gate (§Perf iteration 2 — REFUTED on this
    GSPMD version: the constraints added resharding all-gathers instead of
    converting TP all-reduces to RS+AG; collective term regressed 19.8s ->
    25.9s on phi3 train_4k). Kept behind an env flag for future compilers.
    """
    import os
    if os.environ.get("REPRO_SEQ_PARALLEL", "0") != "1":
        return False
    from repro import sharding as _sh
    c = _sh.current()
    return (c.mesh is not None and c.model_size > 1
            and seq_len % c.model_size == 0 and seq_len >= c.model_size)


def _enc_segs(cfg: ModelConfig):
    return plan_segments(layer_specs(cfg, decoder=False))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.dtype)
    kg = KeyGen(key)
    params: Dict[str, Any] = {}
    params["embed"] = embed_init(kg(), cfg.vocab_size, cfg.d_model, dtype)
    _, params["segments"] = init_stack(kg(), cfg, dtype)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg(), cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        e = cfg.frontend.embed_dim
        params["proj"] = {
            "w1": dense_init(kg(), e, cfg.d_model, dtype),
            "w2": dense_init(kg(), cfg.d_model, cfg.d_model, dtype),
        }
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same dims per config (asserted)
        assert cfg.encoder.d_model == cfg.d_model
        _, enc_segments = init_stack(kg(), enc_cfg, dtype, decoder=False)
        params["encoder"] = {
            "segments": enc_segments,
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    if cfg.mtp_depth:
        # DeepSeek-V3 multi-token-prediction head: combine proj + one layer
        from repro.models.blocks import LayerSpec, init_layer
        spec = LayerSpec("attn", False, cfg.d_ff, False)
        params["mtp"] = {
            "norm": jnp.ones((cfg.d_model,), dtype),
            "combine": dense_init(kg(), 2 * cfg.d_model, cfg.d_model, dtype),
            "layer": init_layer(kg(), cfg, spec, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens: Array, positions: Array) -> Array:
    x = params["embed"][tokens]
    if cfg.abs_pos == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x


def _embed_inputs(cfg, params, batch: Dict[str, Array]) -> Array:
    """Token (+ visual prefix) embedding for decoder-only models."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        pe = batch["patch_embeds"]
        prefix = jax.nn.gelu(pe.astype(jnp.float32)
                             @ params["proj"]["w1"].astype(jnp.float32))
        prefix = (prefix @ params["proj"]["w2"].astype(jnp.float32)
                  ).astype(params["embed"].dtype)
        P = pe.shape[1]
        positions = jnp.arange(P + S)
        tok_x = _embed_tokens(cfg, params, tokens, positions[P:])
        return jnp.concatenate([prefix, tok_x], axis=1), positions
    positions = jnp.arange(S)
    return _embed_tokens(cfg, params, tokens, positions), positions


def _head(cfg, params, x: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    return logits * cfg.logit_scale


# ---------------------------------------------------------------------------
# Encoder (enc-dec models)
# ---------------------------------------------------------------------------


def _encode(cfg, params, frames: Array) -> Array:
    """frames: (B, F, E) precomputed frontend embeddings (stub carve-out)."""
    x = frames.astype(params["final_norm"].dtype)
    pos = jnp.arange(x.shape[1])
    if cfg.abs_pos == "sinusoidal":
        x = x + sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    ctx = {"mode": "full", "positions": pos, "update_cache": False,
           "causal": False}
    segs = _enc_segs(cfg)
    x, _, _ = apply_stack(cfg, segs, params["encoder"]["segments"], x,
                          None, ctx)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.rms_norm_eps)


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward_train(cfg: ModelConfig, params, batch: Dict[str, Array],
                  remat: bool = True) -> Tuple[Array, Array]:
    """Full causal forward; returns (logits (B,S,V), router aux loss)."""
    segs = _segs(cfg)
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])
        x = _embed_tokens(cfg, params, tokens, pos)
        ctx = {"mode": "full", "positions": pos, "update_cache": False,
               "enc_out": enc_out, "precompute_cross": True,
               "seq_shard": _seq_shard_ok(tokens.shape[1])}
        # training has no cache: cross-attn recomputes K/V from enc_out
        x, _, aux = apply_stack(cfg, segs, params["segments"], x, None, ctx,
                                remat=remat)
    else:
        x, pos = _embed_inputs(cfg, params, batch)
        ctx = {"mode": "full", "positions": pos, "update_cache": False,
               "seq_shard": _seq_shard_ok(x.shape[1])}
        x, _, aux = apply_stack(cfg, segs, params["segments"], x, None, ctx,
                                remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    logits = _head(cfg, params, x)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    return logits, aux


def forward_mtp(cfg: ModelConfig, params, batch, hidden_no_head=None):
    """DeepSeek-V3 MTP auxiliary logits (depth 1): predict token t+2 from
    [h_t ; emb(token_{t+1})]. Used as an extra training loss term."""
    if not cfg.mtp_depth or "mtp" not in params:
        return None
    from repro.models.blocks import LayerSpec, apply_layer
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S)
    x, _ = _embed_inputs(cfg, params, batch)
    segs = _segs(cfg)
    ctx = {"mode": "full", "positions": pos, "update_cache": False}
    h, _, _ = apply_stack(cfg, segs, params["segments"], x, None, ctx,
                          remat=True)
    # shift: combine h_t with embedding of token_{t+1}
    h_t = h[:, :-1]
    e_next = params["embed"][tokens[:, 1:]]
    comb = jnp.concatenate([h_t, e_next], axis=-1) @ params["mtp"]["combine"]
    comb = rms_norm(comb, params["mtp"]["norm"], cfg.rms_norm_eps)
    spec = LayerSpec("attn", False, cfg.d_ff, False)
    out, _, _ = apply_layer(cfg, spec, params["mtp"]["layer"], comb, None,
                            {"mode": "full", "positions": pos[:-1],
                             "update_cache": False})
    return _head(cfg, params, out)


# ---------------------------------------------------------------------------
# Serving: state init / prefill / decode
# ---------------------------------------------------------------------------


def init_state(cfg: ModelConfig, batch: int, seq_len: int,
               long_context: bool = False, dtype_name: Optional[str] = None):
    dtype = dtype_of(dtype_name or cfg.dtype)
    segs = _segs(cfg)
    cross_len = None
    if cfg.is_encoder_decoder:
        cross_len = cfg.encoder.max_source_positions
    state: Dict[str, Any] = {
        "layers": init_stack_state(cfg, segs, batch, seq_len, long_context,
                                   dtype, cross_len=cross_len),
    }
    if cfg.is_encoder_decoder:
        state["enc_out"] = jnp.zeros(
            (batch, cross_len, cfg.d_model), dtype)
    return state


def prefill(cfg: ModelConfig, params, batch: Dict[str, Array], state,
            long_context: bool = False) -> Tuple[Array, Any]:
    """Process the prompt, fill the caches, return last-token logits."""
    segs = _segs(cfg)
    window = _window(cfg, long_context)
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
        state = dict(state, enc_out=enc_out)
        tokens = batch["tokens"]
        pos = jnp.arange(tokens.shape[1])
        x = _embed_tokens(cfg, params, tokens, pos)
        ctx = {"mode": "full", "positions": pos, "update_cache": True,
               "t": jnp.int32(0), "window": window, "enc_out": enc_out,
               "precompute_cross": True,
               "seq_shard": _seq_shard_ok(tokens.shape[1])}
    else:
        x, pos = _embed_inputs(cfg, params, batch)
        ctx = {"mode": "full", "positions": pos, "update_cache": True,
               "t": jnp.int32(0), "window": window,
               "seq_shard": _seq_shard_ok(x.shape[1])}
    layers, = (state["layers"],)
    x, layers, _ = apply_stack(cfg, segs, params["segments"], x, layers, ctx)
    state = dict(state, layers=layers)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_norm_eps)
    return _head(cfg, params, x)[:, 0], state


def prefill_batched(cfg: ModelConfig, params, tokens: Array, state,
                    lengths: Array, long_context: bool = False
                    ) -> Tuple[Array, Any]:
    """Right-padded multi-prompt prefill: ``tokens`` (B, L) with each
    row's true length in ``lengths`` (B,); returns per-row logits at the
    last *real* token and the filled caches.

    Only valid for decoder-only attention stacks (the step-plan layer's
    batched-bucketed path): padded positions write garbage K/V rows
    beyond each row's length, which the per-request decode clocks mask —
    a recurrent block would fold the padding into its state, so hybrid /
    xLSTM / enc-dec models use the unpadded single-prompt path instead.
    """
    segs = _segs(cfg)
    window = _window(cfg, long_context)
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed_tokens(cfg, params, tokens, positions)
    ctx = {"mode": "full", "positions": positions, "update_cache": True,
           "t": jnp.int32(0), "window": window,
           "seq_shard": _seq_shard_ok(S)}
    layers, = (state["layers"],)
    x, layers, _ = apply_stack(cfg, segs, params["segments"], x, layers, ctx)
    state = dict(state, layers=layers)
    last = x[jnp.arange(B), lengths - 1]
    last = rms_norm(last, params["final_norm"], cfg.rms_norm_eps)
    return _head(cfg, params, last), state


def prefill_chunk(cfg: ModelConfig, params, tokens: Array, state,
                  history: int, long_context: bool = False
                  ) -> Tuple[Array, Any]:
    """Resumable chunked prefill (Sarathi-style, executed for real):
    process ``tokens`` (B, C) at absolute positions [history, history+C)
    against a cache whose first ``history`` rows are already filled by
    earlier chunks.  Returns logits at the chunk's last token (only
    meaningful on the final chunk) and the extended caches.

    ``history`` is static (one compile per (chunk shape, cursor));
    attention-only stacks only — recurrent state continuation across
    chunks is not implemented."""
    segs = _segs(cfg)
    window = _window(cfg, long_context)
    B, C = tokens.shape
    positions = history + jnp.arange(C)
    x = _embed_tokens(cfg, params, tokens, positions)
    ctx = {"mode": "full", "positions": positions, "update_cache": True,
           "t": jnp.int32(history), "window": window, "history": history}
    layers, = (state["layers"],)
    x, layers, _ = apply_stack(cfg, segs, params["segments"], x, layers, ctx)
    state = dict(state, layers=layers)
    x = rms_norm(x[:, -1], params["final_norm"], cfg.rms_norm_eps)
    return _head(cfg, params, x), state


def decode_step(cfg: ModelConfig, params, tokens: Array, state, t: Array,
                long_context: bool = False, paged=None
                ) -> Tuple[Array, Any]:
    """One decode step: tokens (B,1) at clock t -> (logits (B,V), state).

    ``t`` is a scalar (homogeneous batch) or (B,) per-request clock
    (continuous batching).  With ``paged`` (a
    :class:`repro.models.attention.PagedDecode` context) the batch is
    compacted and attention reads K/V through block tables — see
    :func:`decode_step_paged`."""
    segs = _segs(cfg)
    window = _window(cfg, long_context)
    if jnp.ndim(t) == 0:
        pos = t + jnp.arange(1)
    else:
        pos = t[:, None] + jnp.arange(1)[None]       # (B, 1)
    x = _embed_tokens(cfg, params, tokens, pos)
    ctx = {"mode": "decode", "positions": pos, "update_cache": True,
           "t": t, "window": window}
    if paged is not None:
        ctx["paged"] = paged
    if cfg.is_encoder_decoder:
        ctx["enc_out"] = state["enc_out"]
    x, layers, _ = apply_stack(cfg, segs, params["segments"], x,
                               state["layers"], ctx)
    state = dict(state, layers=layers)
    x = rms_norm(x, params["final_norm"], cfg.rms_norm_eps)
    return _head(cfg, params, x)[:, 0], state


def decode_step_paged(cfg: ModelConfig, params, tokens: Array, state,
                      t: Array, slots: Array, tables: Array,
                      block_lines: int, long_context: bool = False
                      ) -> Tuple[Array, Any]:
    """One *compacted, paged* decode step (ISSUE 5): ``decode_step``
    with a :class:`~repro.models.attention.PagedDecode` context.

    ``tokens`` (Bc, 1) / ``t`` (Bc,) cover only the active primary
    slots; ``slots`` (Bc,) names each row's slot in the full cache
    state, and ``tables`` (Bc, max_blocks) its physical line blocks in
    the pool view (``PagedStore.decode_block_tables``).  Attention
    writes the new KV line at (slot, t mod W) and reads back through the
    block tables, so replica/free slots and dead cache rows cost
    nothing.  Attention-only decoder stacks, GQA attention only (the
    engine gates on ``supports_paged_decode``)."""
    from repro.models.attention import PagedDecode
    return decode_step(cfg, params, tokens, state, t,
                       long_context=long_context,
                       paged=PagedDecode(slots, tables, block_lines))


def decode_multi(cfg: ModelConfig, params, tokens: Array, state, t: Array,
                 slots: Array, tables: Array, budget: Array, keys: Array,
                 *, block_lines: int, temperature: float = 0.0,
                 eos_token: int = -1, long_context: bool = False
                 ) -> Tuple[Array, Any, Array]:
    """Fused multi-step paged decode: ``steps = keys.shape[0]``
    iterations of :func:`decode_step_paged` as ONE ``lax.scan``, with
    on-device sampling and EOS / budget short-circuiting — a single
    dispatch and a single host transfer for the whole span.

    Per row: ``budget`` is the remaining ``max_new_tokens``; a row goes
    dead once it has emitted its budget or sampled ``eos_token`` (-1 =
    no EOS).  Dead rows freeze: their clock stops, their (frozen) token
    re-writes the same reserved cache line, and their trace repeats the
    last token — the host reads only the first ``emitted[i]`` entries.
    Sampling draws one pre-split key per step (``sampling.decode_keys``)
    folded by slot, so the token stream is bit-identical to ``steps``
    sequential single-step calls, even as rows die mid-scan.

    Returns ``(tokens_all (steps, Bc), state, emitted (Bc,))``."""
    from repro.serving.sampling import sample_slots

    def body(carry, key):
        toks, st, tt, alive, emitted = carry
        logits, st = decode_step_paged(cfg, params, toks, st, tt, slots,
                                       tables, block_lines,
                                       long_context=long_context)
        nxt = sample_slots(logits, key, slots, temperature)
        nxt = jnp.where(alive, nxt, toks[:, 0])
        emitted = emitted + alive.astype(jnp.int32)
        tt = tt + alive.astype(tt.dtype)
        alive = alive & (nxt != eos_token) & (emitted < budget)
        return (nxt[:, None], st, tt, alive, emitted), nxt

    Bc = tokens.shape[0]
    init = (tokens, state, t, jnp.ones((Bc,), bool),
            jnp.zeros((Bc,), jnp.int32))
    (_, state, _, _, emitted), toks_all = jax.lax.scan(body, init, keys)
    return toks_all, state, emitted


def _window(cfg: ModelConfig, long_context: bool) -> Optional[int]:
    if long_context:
        if cfg.family == "hybrid":
            return None  # jamba: full attention, data-sharded KV
        return cfg.sliding_window or cfg.long_context_window
    return cfg.sliding_window
