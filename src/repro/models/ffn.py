"""Dense feed-forward layers (SwiGLU / GeLU MLP)."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, gelu, swiglu

Array = jax.Array


def init_dense_ffn(key, cfg: ModelConfig, d_ff: int, dtype):
    kg = KeyGen(key)
    d = cfg.d_model
    if cfg.activation == "swiglu":
        return {
            "w_gate": dense_init(kg(), d, d_ff, dtype),
            "w_up": dense_init(kg(), d, d_ff, dtype),
            "w_down": dense_init(kg(), d_ff, d, dtype),
        }
    return {
        "w_up": dense_init(kg(), d, d_ff, dtype),
        "w_down": dense_init(kg(), d_ff, d, dtype),
    }


def dense_ffn(cfg: ModelConfig, params, x: Array) -> Array:
    if cfg.activation == "swiglu":
        h = swiglu(x @ params["w_gate"], x @ params["w_up"])
    else:
        h = gelu(x @ params["w_up"])
    return h @ params["w_down"]
