"""Mixture-of-Experts FFN with TPU-native expert parallelism.

Token dispatch is sort-free scatter/gather (argsort ranking + capacity drop),
not the dense one-hot-einsum "dropping" formulation — the einsum form counts
T*E*C*d MAC FLOPs in HLO and would poison the roofline analysis.

Distribution strategies (chosen automatically from the active ShardCtx):
  local — no mesh (CPU smoke tests): all experts on one device.
  a2a   — tokens re-shard over the `model` axis; dispatch buffers exchanged
          with two all_to_alls (classic expert parallelism). Used when the
          sequence dim divides the model axis (train / prefill).
  psum  — every model shard computes its local expert slice over all tokens
          of its data shard and partial outputs are all-reduced. Used for
          decode steps (few tokens, weight-bound) where an a2a schedule
          would be latency-dominated anyway.

Shared experts (DeepSeek) and the dense residual MLP (Arctic) run outside
the routed path as plain TP-sharded dense FFNs.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs.base import ModelConfig

# jax < 0.5 exposes shard_map under jax.experimental with a differently
# named replication-check flag; newer releases promoted it to jax.shard_map
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}
from repro.models.common import KeyGen, dense_init, swiglu
from repro.models.ffn import dense_ffn, init_dense_ffn

Array = jax.Array


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    kg = KeyGen(key)
    d, f, e = cfg.d_model, m.expert_d_ff, m.num_experts
    assert cfg.activation == "swiglu", "routed experts implemented for swiglu"
    scale = d ** -0.5
    def ew(k_, a, b):
        return (jax.random.normal(k_, (e, a, b), jnp.float32) * scale).astype(dtype)
    p = {
        "router": dense_init(kg(), d, e, jnp.float32),
        "w_gate": ew(kg(), d, f),
        "w_up": ew(kg(), d, f),
        "w_down": (jax.random.normal(kg(), (e, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if m.num_shared_experts:
        sf = (m.shared_d_ff or f) * m.num_shared_experts
        p["shared"] = init_dense_ffn(kg(), cfg, sf, dtype)
    if m.dense_residual_d_ff:
        p["dense_residual"] = init_dense_ffn(kg(), cfg, m.dense_residual_d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Dispatch primitives (pure local math)
# ---------------------------------------------------------------------------


def _route(x2: Array, router: Array, top_k: int):
    """x2 (T, d) -> gates (T,k), expert ids (T,k), router probs (T,E)."""
    logits = x2.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eidx, probs


def _ranks_of(e_flat: Array, num_experts: int) -> Array:
    """Within-expert arrival rank of each flat assignment (stable)."""
    n = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    rank_sorted = jnp.arange(n) - start[sorted_e]
    return jnp.zeros_like(e_flat).at[order].set(rank_sorted)


def _fill_buffer(x2: Array, tok: Array, slot: Array, num_slots: int) -> Array:
    """Scatter token vectors into dispatch buffer; slot == num_slots drops."""
    buf = jnp.zeros((num_slots + 1, x2.shape[1]), x2.dtype)
    return buf.at[slot].set(x2[tok], mode="drop")[:num_slots]


def _expert_ffn(params, xs: Array) -> Array:
    """xs (E_loc, C, d) -> (E_loc, C, d); local expert slice of the weights."""
    h = swiglu(jnp.einsum("ecd,edf->ecf", xs, params["w_gate"]),
               jnp.einsum("ecd,edf->ecf", xs, params["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def _combine(y_flat: Array, slot: Array, gates: Array, T: int, k: int) -> Array:
    """Gather per-assignment outputs back and mix with gate weights."""
    d = y_flat.shape[-1]
    y_pad = jnp.concatenate([y_flat, jnp.zeros((1, d), y_flat.dtype)], 0)
    contrib = y_pad[slot]                                   # (T*k, d)
    g = gates.reshape(-1, 1).astype(jnp.float32)
    return (contrib.astype(jnp.float32) * g).reshape(T, k, d).sum(1)


def _aux_loss(eidx: Array, probs: Array, num_experts: int, coef: float) -> Array:
    tk = eidx.size
    counts = jnp.zeros((num_experts,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
    f = counts / tk
    p_mean = probs.mean(0)
    return num_experts * jnp.sum(f * p_mean) * coef


def _capacity(tokens: int, k: int, num_experts: int, cf: float) -> int:
    return max(1, math.ceil(tokens * k * cf / num_experts))


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def _routed_local(cfg: ModelConfig, params, x2: Array) -> Tuple[Array, Array]:
    m = cfg.moe
    T = x2.shape[0]
    gates, eidx, probs = _route(x2, params["router"], m.top_k)
    C = _capacity(T, m.top_k, m.num_experts, m.capacity_factor)
    e_flat = eidx.reshape(-1)
    ranks = _ranks_of(e_flat, m.num_experts)
    keep = ranks < C
    slot = jnp.where(keep, e_flat * C + ranks, m.num_experts * C)
    tok = jnp.arange(T * m.top_k) // m.top_k
    xs = _fill_buffer(x2, tok, slot, m.num_experts * C).reshape(m.num_experts, C, -1)
    ys = _expert_ffn(params, xs)
    y = _combine(ys.reshape(m.num_experts * C, -1), slot, gates, T, m.top_k)
    return y, _aux_loss(eidx, probs, m.num_experts, m.router_aux_loss_coef)


def _routed_psum(cfg: ModelConfig, params, x_loc: Array, model_axis: str,
                 mean_axes: Tuple[str, ...]) -> Tuple[Array, Array]:
    """Per-shard local experts over all local tokens; all-reduce partials."""
    m = cfg.moe
    B, S, d = x_loc.shape
    T = B * S
    x2 = x_loc.reshape(T, d)
    E_loc = params["w_gate"].shape[0]
    midx = jax.lax.axis_index(model_axis)
    gates, eidx, probs = _route(x2, params["router"], m.top_k)
    C = _capacity(T, m.top_k, m.num_experts, m.capacity_factor)
    e_flat = eidx.reshape(-1)
    ranks = _ranks_of(e_flat, m.num_experts)
    e_local = e_flat - midx * E_loc
    keep = (e_local >= 0) & (e_local < E_loc) & (ranks < C)
    slot = jnp.where(keep, e_local * C + ranks, E_loc * C)
    tok = jnp.arange(T * m.top_k) // m.top_k
    xs = _fill_buffer(x2, tok, slot, E_loc * C).reshape(E_loc, C, -1)
    ys = _expert_ffn(params, xs)
    y = _combine(ys.reshape(E_loc * C, -1), slot, gates, T, m.top_k)
    y = jax.lax.psum(y, model_axis)
    aux = _aux_loss(eidx, probs, m.num_experts, m.router_aux_loss_coef)
    if mean_axes:
        aux = jax.lax.pmean(aux, mean_axes)
    return y.reshape(B, S, d), aux


def _routed_a2a(cfg: ModelConfig, params, x_loc: Array, model_axis: str,
                mean_axes: Tuple[str, ...], model_size: int
                ) -> Tuple[Array, Array]:
    """Tokens sharded over the model axis; two all_to_alls (classic EP)."""
    m = cfg.moe
    B, S_loc, d = x_loc.shape
    T = B * S_loc
    x2 = x_loc.reshape(T, d)
    E, M = m.num_experts, model_size
    E_loc = E // M
    gates, eidx, probs = _route(x2, params["router"], m.top_k)
    C = _capacity(T, m.top_k, E, m.capacity_factor)
    e_flat = eidx.reshape(-1)
    ranks = _ranks_of(e_flat, E)
    keep = ranks < C
    slot = jnp.where(keep, e_flat * C + ranks, E * C)
    tok = jnp.arange(T * m.top_k) // m.top_k
    send = _fill_buffer(x2, tok, slot, E * C)               # (E*C, d)
    send = send.reshape(M, E_loc * C, d)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)                  # (M, E_loc*C, d)
    xs = recv.reshape(M, E_loc, C, d).transpose(1, 0, 2, 3).reshape(E_loc, M * C, d)
    ys = _expert_ffn(params, xs)
    back = ys.reshape(E_loc, M, C, d).transpose(1, 0, 2, 3).reshape(M, E_loc * C, d)
    got = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0,
                             tiled=False)                   # (M, E_loc*C, d)
    y = _combine(got.reshape(E * C, d), slot, gates, T, m.top_k)
    aux = _aux_loss(eidx, probs, E, m.router_aux_loss_coef)
    if mean_axes:
        aux = jax.lax.pmean(aux, mean_axes + (model_axis,))
    return y.reshape(B, S_loc, d), aux


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def moe_forward(cfg: ModelConfig, params, x: Array) -> Tuple[Array, Array]:
    """x (B, S, d) -> (y (B, S, d), router aux loss scalar)."""
    m = cfg.moe
    ctx = sharding.current()
    B, S, d = x.shape

    routed_params = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}

    if ctx.mesh is None or ctx.model_size == 1:
        y, aux = _routed_local(cfg, routed_params, x.reshape(B * S, d))
        y = y.reshape(B, S, d)
    else:
        M = ctx.model_size
        batch_shardable = B % ctx.batch_size_divisor == 0
        b_spec = ctx.batch_axes if batch_shardable else None
        seq_shardable = S % M == 0 and S >= M
        strategy = ctx.moe_strategy
        if strategy == "auto":
            strategy = "a2a" if seq_shardable else "psum"
        mean_axes = tuple(ctx.batch_axes) if batch_shardable else ()
        espec = P(ctx.model_axis, None, None)
        in_specs = (
            P(b_spec, ctx.model_axis if strategy == "a2a" else None, None),
            {"router": P(None, None), "w_gate": espec, "w_up": espec,
             "w_down": espec},
        )
        out_specs = (in_specs[0], P())
        if strategy == "a2a":
            fn = lambda xl, pl: _routed_a2a(cfg, pl, xl, ctx.model_axis,
                                            mean_axes, M)
        else:
            fn = lambda xl, pl: _routed_psum(cfg, pl, xl, ctx.model_axis,
                                             mean_axes)
        y, aux = _shard_map(
            fn, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs,
            **_SHARD_MAP_KW)(x, routed_params)

    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + dense_ffn(cfg, params["shared"], x)
    if "dense_residual" in params:
        y = y + dense_ffn(cfg, params["dense_residual"], x)
    return y, aux
