from repro.models.model import (decode_multi, decode_step,
                                decode_step_paged, forward_mtp,
                                forward_train, init_params, init_state,
                                prefill, prefill_batched, prefill_chunk)

__all__ = ["init_params", "forward_train", "forward_mtp", "init_state",
           "prefill", "prefill_batched", "prefill_chunk", "decode_step",
           "decode_step_paged", "decode_multi"]
