from repro.models.model import (decode_step, forward_mtp, forward_train,
                                init_params, init_state, prefill)

__all__ = ["init_params", "forward_train", "forward_mtp", "init_state",
           "prefill", "decode_step"]
