"""Attention: GQA (RoPE, optional sliding window) and MLA (DeepSeek latent).

Three execution paths:
  * full   — train / prefill over S tokens: chunked online-softmax "flash"
             in pure jnp (lax.scan over KV blocks), so the S x S score matrix
             is never materialized. On TPU the Pallas kernel in
             ``repro.kernels.flash_attention`` replaces this (same math).
  * decode — single query token against the KV cache: two einsums + softmax.
  * cross  — encoder-decoder cross attention (full or cached decode).

KV caches use ring-buffer indexing when capacity < logical position count.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, apply_rope, dense_init, rms_norm

Array = jax.Array

NEG_INF = -1e30

# Kernel backend switch: "jnp" (portable, used on CPU + dry-run), "pallas"
# (TPU target; compiled Mosaic kernels) or "auto" (pallas iff on TPU).
KERNEL_BACKEND = "auto"


def set_kernel_backend(name: str):
    global KERNEL_BACKEND
    assert name in ("jnp", "pallas", "auto")
    KERNEL_BACKEND = name


def _use_pallas() -> bool:
    if KERNEL_BACKEND == "pallas":
        return True
    if KERNEL_BACKEND == "auto":
        return jax.default_backend() == "tpu"
    return False


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp, differentiable)
# ---------------------------------------------------------------------------


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps scan shapes exact)."""
    c = min(n, target)
    while n % c:
        c -= 1
    return c


def flash_attention(
    q: Array,            # (B, Sq, H, hd)
    k: Array,            # (B, Skv, KVH, hd)
    v: Array,            # (B, Skv, KVH, hd)
    *,
    causal: bool,
    scale: float,
    window: Optional[int] = None,
    q_offset: int = 0,   # absolute position of q[0] relative to k[0]
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> Array:
    """Online-softmax attention over KV chunks. Never materializes SxS.
    Supports distinct value head dim (MLA: qk=192, v=128).

    GSPMD-friendly by construction (§Perf iteration 1): the head dim H is
    never split — GQA is expressed as a broadcast of K/V from KVH to H
    heads, which XLA fuses into the dot. Splitting H into (KVH, G) defeated
    sharding propagation and silently replicated attention across the mesh
    (SPMD "involuntary full rematerialization"). Explicit constraints pin
    batch to the data axis and heads to the model axis (uneven head counts
    are padded by GSPMD).
    """
    from repro import sharding
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    dv = v.shape[3]
    G = H // KVH
    qc = _pick_chunk(Sq, q_chunk)
    kc = _pick_chunk(Skv, kv_chunk)
    nq, nk = Sq // qc, Skv // kc

    def expand(t, d_last):
        # (B, Skv, KVH, d) -> (B, nk, kc, H, d): GQA broadcast, fused by XLA
        t = t.astype(jnp.float32).reshape(B, nk, kc, KVH, 1, d_last)
        t = jnp.broadcast_to(t, (B, nk, kc, KVH, G, d_last))
        return t.reshape(B, nk, kc, H, d_last)

    qf = q.astype(jnp.float32).reshape(B, nq, qc, H, hd)
    qf = sharding.constrain(qf, "batch", None, None, "model", None)
    kf = sharding.constrain(expand(k, hd), "batch", None, None, "model", None)
    vf = sharding.constrain(expand(v, dv), "batch", None, None, "model", None)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qc)
    k_pos = jnp.arange(Skv).reshape(nk, kc)

    def q_block(qb, qp, kv_lo, kv_hi):
        """qb (B,qc,H,hd); scans ONLY kv blocks [kv_lo, kv_hi)."""

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, kp = xs  # (B, kc, H, hd/dv), (kc,)
            s = jnp.einsum("bqhd,bchd->bhqc", qb, kb) * scale
            mask = jnp.ones((qp.shape[0], kp.shape[0]), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqc,bchd->bhqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kf[:, kv_lo:kv_hi].swapaxes(0, 1),
             vf[:, kv_lo:kv_hi].swapaxes(0, 1), k_pos[kv_lo:kv_hi]))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,H,qc,dv)
        return out.transpose(0, 2, 1, 3)                # (B,qc,H,dv)

    if causal and nq > 1:
        # causal block-skip (§Perf iteration 6): q blocks are grouped into
        # <=8 statically-unrolled BANDS; each band lax.maps its q blocks
        # over only the kv range any of them can see. Removes most of the
        # ~2x masked-block matmul waste without exploding HLO size
        # (residual waste ~ 1/(2*bands) ~ 6%).
        n_bands = min(nq, 8)
        per = -(-nq // n_bands)
        outs = []
        for b0 in range(0, nq, per):
            b1 = min(nq, b0 + per)
            q_end = q_offset + b1 * qc                   # static
            kv_hi = min(nk, -(-q_end // kc))
            kv_lo = 0
            if window is not None:
                kv_lo = max(0, (q_offset + b0 * qc - window + 1) // kc)
            band = jax.lax.map(
                lambda args, lo=kv_lo, hi=kv_hi: q_block(args[0], args[1],
                                                         lo, hi),
                (qf[:, b0:b1].swapaxes(0, 1), q_pos[b0:b1]))
            outs.append(band.swapaxes(0, 1))             # (B,nb,qc,H,dv)
        out = jnp.concatenate(outs, axis=1).reshape(B, Sq, H, dv)
    else:
        out = jax.lax.map(
            lambda args: q_block(args[0], args[1], 0, nk),
            (qf.swapaxes(0, 1), q_pos))                 # (nq,B,qc,H,dv)
        out = out.swapaxes(0, 1).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


class PagedDecode:
    """Paged-decode context threaded through the layer stack (ISSUE 5).

    The decode batch is *compacted*: row ``i`` of the activations is
    request slot ``slots[i]`` of the (full, ``num_slots``-row) cache
    state, and its K/V is read back through ``tables[i]`` — physical
    line-block ids into the pool view of the cache
    (``PagedStore.pool_view`` layout: the dense ``(B, W, ...)`` leaf
    reshaped to ``(B * W/block_lines, block_lines, ...)``).  Replica and
    free slots are simply absent from ``slots``, so they cost nothing.
    """

    __slots__ = ("slots", "tables", "block_lines")

    def __init__(self, slots: Array, tables: Array, block_lines: int):
        self.slots = slots            # (Bc,) int32 — state rows of the batch
        self.tables = tables          # (Bc, max_blocks) int32 pool block ids
        self.block_lines = block_lines


def decode_attention(
    q: Array,            # (B, 1, H, hd)
    k_cache: Array,      # (B, W, KVH, hd)
    v_cache: Array,      # (B, W, KVH, hd)
    *,
    scale: float,
    valid: Array,        # (W,) bool or (B, W) bool — which slots are live
) -> Array:
    B, _, H, hd = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qf, k_cache.astype(jnp.float32)) * scale
    if valid.ndim == 1:
        valid = valid[None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Ring-buffer cache helpers
# ---------------------------------------------------------------------------


def ring_write(cache: Array, values: Array, t: Array, capacity: int) -> Array:
    """Write values (B, S, ...) at logical positions [t, t+S) modulo capacity.

    ``t`` may be a scalar clock (shared by the batch — prefill) or a (B,)
    per-request clock (continuous batching decode)."""
    S = values.shape[1]
    if S >= capacity:
        # keep only the last `capacity` entries, already aligned to slots
        vals = values[:, -capacity:]
        pos = (t + S - capacity + jnp.arange(capacity)) % capacity
        return cache.at[:, pos].set(vals)
    if jnp.ndim(t) == 0:
        pos = (t + jnp.arange(S)) % capacity
        return cache.at[:, pos].set(values)

    def write_one(c, val, tt):
        pos = (tt + jnp.arange(S)) % capacity
        return c.at[pos].set(val)

    return jax.vmap(write_one)(cache, values, t)


def ring_valid(t_next: Array, capacity: int) -> Array:
    """Valid-slot mask after t_next tokens written into a ring of size cap.
    Scalar t -> (cap,); per-request (B,) t -> (B, cap)."""
    n_valid = jnp.minimum(t_next, capacity)
    if jnp.ndim(t_next) == 0:
        return jnp.arange(capacity) < n_valid
    return jnp.arange(capacity)[None] < n_valid[:, None]


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype, cross: bool = False):
    kg = KeyGen(key)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kg(), d, h * hd, dtype),
        "wk": dense_init(kg(), d, kvh * hd, dtype),
        "wv": dense_init(kg(), d, kvh * hd, dtype),
        "wo": dense_init(kg(), h * hd, d, dtype),
    }
    return p


def gqa_forward(
    cfg: ModelConfig,
    params,
    x: Array,                       # (B, S, D)
    *,
    mode: str,                      # "full" | "decode"
    positions: Array,               # (S,) absolute positions (or (B,S))
    state=None,                     # KV cache dict or None
    t: Optional[Array] = None,      # scalar clock (decode / cache writes)
    window: Optional[int] = None,
    update_cache: bool = False,
    causal: bool = True,
    history: int = 0,               # static: cached KV rows [0, history)
                                    # precede this chunk (chunked prefill)
    paged: Optional[PagedDecode] = None,  # compacted block-table decode
) -> Tuple[Array, Optional[dict]]:
    B, S, D = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)
    q = (x @ params["wq"]).reshape(B, S, h, hd)
    k = (x @ params["wk"]).reshape(B, S, kvh, hd)
    v = (x @ params["wv"]).reshape(B, S, kvh, hd)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "full" and history:
        # chunked-prefill continuation: this chunk's queries attend to
        # the previously cached rows (already roped at their absolute
        # positions) plus the chunk itself; new rows land at [t, t+S).
        assert state is not None and causal
        k_all = jnp.concatenate(
            [state["k"][:, :history].astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate(
            [state["v"][:, :history].astype(v.dtype), v], axis=1)
        out = flash_attention(q, k_all, v_all, causal=True, scale=scale,
                              window=window, q_offset=history)
        new_state = state
        if update_cache:
            cap = state["k"].shape[1]
            t0 = t if t is not None else jnp.int32(history)
            new_state = dict(state)
            new_state["k"] = ring_write(state["k"], k, t0, cap)
            new_state["v"] = ring_write(state["v"], v, t0, cap)
    elif mode == "full":
        if _use_pallas() and q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
            from repro.kernels.flash_attention import flash_attention_pallas
            out = flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                         window=window)
        else:
            out = flash_attention(q, k, v, causal=causal, scale=scale,
                                  window=window)
        new_state = state
        if update_cache and state is not None:
            cap = state["k"].shape[1]
            t0 = t if t is not None else jnp.int32(0)
            new_state = dict(state)
            new_state["k"] = ring_write(state["k"], k, t0, cap)
            new_state["v"] = ring_write(state["v"], v, t0, cap)
    elif mode == "decode" and paged is not None:
        # paged hot path (ISSUE 5): the batch is compacted to the active
        # primary slots; the new K/V line scatters into the full cache at
        # (slot, t mod W) and attention gathers back ONLY the request's
        # live line blocks through its block table — decode reads
        # O(resident lines), not O(num_slots * kv_capacity).
        assert state is not None and t is not None and S == 1
        from repro import sharding
        from repro.kernels.decode_attention import paged_decode_attention
        # mesh serving (repro.meshserve): pin the compacted query batch's
        # head dim to the slice's model axis so the per-head block gather
        # below stays shard-local (no-op without an active mesh)
        q = sharding.constrain(q, "batch", None, "model", None)
        cap = state["k"].shape[1]
        pos = t % cap
        kc = state["k"].at[paged.slots, pos].set(k[:, 0])
        vc = state["v"].at[paged.slots, pos].set(v[:, 0])
        bl = paged.block_lines
        pool_shape = (kc.shape[0] * (cap // bl), bl, kvh, hd)
        lengths = jnp.minimum(t + 1, cap)
        out = paged_decode_attention(
            q, kc.reshape(pool_shape), vc.reshape(pool_shape),
            paged.tables, lengths, scale=scale, use_pallas=_use_pallas())
        new_state = dict(state, k=kc, v=vc)
    elif mode == "decode":
        assert state is not None and t is not None
        cap = state["k"].shape[1]
        kc = ring_write(state["k"], k, t, cap)
        vc = ring_write(state["v"], v, t, cap)
        valid = ring_valid(t + S, cap)
        out = decode_attention(q, kc, vc, scale=scale, valid=valid)
        new_state = dict(state, k=kc, v=vc)
    else:
        raise ValueError(mode)

    out = out.reshape(B, S, h * hd) @ params["wo"]
    return out, new_state


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(key, cfg: ModelConfig, dtype):
    return init_gqa(key, cfg, dtype)


def cross_attn_forward(
    cfg: ModelConfig,
    params,
    x: Array,                 # (B, S, D) decoder states
    *,
    enc_out: Optional[Array],  # (B, S_src, D) or None when cached
    state=None,               # holds cached xk/xv after prefill
    precompute: bool = False,
):
    B, S, D = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)
    q = (x @ params["wq"]).reshape(B, S, h, hd)
    if precompute or "xk" not in (state or {}):
        assert enc_out is not None
        k = (enc_out @ params["wk"]).reshape(B, -1, kvh, hd)
        v = (enc_out @ params["wv"]).reshape(B, -1, kvh, hd)
        if state is not None:
            state = dict(state, xk=k, xv=v)
    else:
        k, v = state["xk"], state["xv"]
    out = flash_attention(q, k, v, causal=False, scale=scale)
    out = out.reshape(B, S, h * hd) @ params["wo"]
    return out, state


# ---------------------------------------------------------------------------
# MLA (DeepSeek Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype):
    kg = KeyGen(key)
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk, rope, nope, vd = m.qk_head_dim, m.qk_rope_head_dim, m.qk_nope_head_dim, m.v_head_dim
    return {
        "wq_a": dense_init(kg(), d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(kg(), m.q_lora_rank, h * qk, dtype),
        "wkv_a": dense_init(kg(), d, m.kv_lora_rank + rope, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(kg(), m.kv_lora_rank, h * (nope + vd), dtype),
        "wo": dense_init(kg(), h * vd, d, dtype),
    }


def _mla_qkv_latent(cfg, params, x, positions):
    """Shared projections: roped q (split nope/rope), normed latent, roped
    shared key-rope."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    q = (x @ params["wq_a"])
    q = rms_norm(q, params["q_norm"], cfg.rms_norm_eps) @ params["wq_b"]
    q = q.reshape(B, S, h, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ params["wkv_a"]
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.rms_norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(
    cfg: ModelConfig,
    params,
    x: Array,
    *,
    mode: str,
    positions: Array,
    state=None,
    t: Optional[Array] = None,
    window: Optional[int] = None,
    update_cache: bool = False,
    causal: bool = True,
    history: int = 0,
    paged: Optional[PagedDecode] = None,
):
    assert paged is None, \
        "paged decode gathers per-head K/V blocks; the MLA latent cache " \
        "decodes through the absorbed dense path"
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(cfg, params, x, positions)

    if mode == "full":
        # materialized path (prefill/train): expand latent to per-head K/V
        kvb = (c_kv @ params["wkv_b"]).reshape(B, S, h, nope + vd)
        k_nope, v = kvb[..., :nope], kvb[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, h, rope))], -1)
        if history:
            # chunked-prefill continuation: expand the cached latent rows
            # [0, history) (already normed + roped) the same way
            assert state is not None and causal
            ckv_h = state["c_kv"][:, :history].astype(c_kv.dtype)
            krope_h = state["k_rope"][:, :history].astype(k_rope.dtype)
            kvb_h = (ckv_h @ params["wkv_b"]).reshape(B, history, h,
                                                      nope + vd)
            k_h = jnp.concatenate(
                [kvb_h[..., :nope],
                 jnp.broadcast_to(krope_h[:, :, None], (B, history, h, rope))],
                -1)
            k = jnp.concatenate([k_h, k], axis=1)
            v = jnp.concatenate([kvb_h[..., nope:], v], axis=1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = flash_attention(q, k, v, causal=causal, scale=scale,
                              window=window, q_offset=history)
        new_state = state
        if update_cache and state is not None:
            cap = state["c_kv"].shape[1]
            t0 = t if t is not None else jnp.int32(0)
            new_state = dict(state)
            new_state["c_kv"] = ring_write(state["c_kv"], c_kv, t0, cap)
            new_state["k_rope"] = ring_write(state["k_rope"], k_rope, t0, cap)
    elif mode == "decode":
        # absorbed path: score & read in latent space.
        # Sharding (§Perf iteration 5): the latent cache shards its SEQ dim
        # over the model axis (all heads share the latent, so head-sharding
        # it is impossible); q/scores replicate heads for the attention ops
        # and the softmax/read contractions psum tiny partials instead of
        # all-gathering the 2x(B,W,512) cache every layer.
        from repro import sharding as _sh
        assert state is not None and t is not None
        cap = state["c_kv"].shape[1]
        ckv_c = ring_write(state["c_kv"], c_kv, t, cap)
        krope_c = ring_write(state["k_rope"], k_rope, t, cap)
        ckv_c = _sh.constrain(ckv_c, "batch", "model", None)
        krope_c = _sh.constrain(krope_c, "batch", "model", None)
        valid = ring_valid(t + S, cap)
        wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, nope + vd)
        w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
        # absorb W_UK into q: (B,1,H,nope) x (lat,H,nope) -> (B,H,lat)
        q_lat = jnp.einsum("bshn,lhn->bhl", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        q_lat = _sh.constrain(q_lat, "batch", None, None)
        s = jnp.einsum("bhl,bwl->bhw", q_lat, ckv_c.astype(jnp.float32))
        s += jnp.einsum("bshr,bwr->bhw", q_rope.astype(jnp.float32),
                        krope_c.astype(jnp.float32))
        if valid.ndim == 1:
            valid = valid[None]
        s = jnp.where(valid[:, None, :], s * scale, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bhw,bwl->bhl", p, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bhl,lhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
        out = out[:, None].astype(x.dtype)  # (B,1,H,vd)
        new_state = dict(state, c_kv=ckv_c, k_rope=krope_c)
    else:
        raise ValueError(mode)

    out = out.reshape(B, S, h * vd) @ params["wo"]
    return out, new_state


# ---------------------------------------------------------------------------
# Unified entry
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype):
    if cfg.attention_kind == "mla":
        return init_mla(key, cfg, dtype)
    return init_gqa(key, cfg, dtype)


def attention_forward(cfg: ModelConfig, params, x, **kw):
    if cfg.attention_kind == "mla":
        return mla_forward(cfg, params, x, **kw)
    return gqa_forward(cfg, params, x, **kw)
