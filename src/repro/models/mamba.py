"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Full mode runs a ``lax.scan`` over time with the per-step discretization
computed inside the step (never materializing (B, S, d_in, d_state)).
Decode mode advances one step from stored (conv window, ssm state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init

Array = jax.Array


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_in, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype):
    mc, d_in, dt_rank = _dims(cfg)
    kg = KeyGen(key)
    d = cfg.d_model
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(
        jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (d_in, mc.d_state))
    return {
        "in_proj": dense_init(kg(), d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(kg(), (mc.d_conv, d_in), jnp.float32)
                   * mc.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(kg(), d_in, dt_rank + 2 * mc.d_state, dtype),
        "dt_w": dense_init(kg(), dt_rank, d_in, dtype),
        "dt_b": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(kg(), d_in, d, dtype),
    }


def _split_proj(cfg, params, x):
    d_in = cfg.mamba.expand * cfg.d_model
    xz = x @ params["in_proj"]
    return xz[..., :d_in], xz[..., d_in:]


def _causal_conv_full(params, xp: Array, d_conv: int) -> Array:
    """Depthwise causal conv via shifted adds; xp (B, S, d_in)."""
    w = params["conv_w"].astype(jnp.float32)          # (d_conv, d_in)
    acc = jnp.zeros_like(xp, jnp.float32)
    for i in range(d_conv):
        shift = d_conv - 1 - i
        rolled = jnp.pad(xp, ((0, 0), (shift, 0), (0, 0)))[:, : xp.shape[1]]
        acc += rolled.astype(jnp.float32) * w[i]
    return acc + params["conv_b"].astype(jnp.float32)


def _ssm_inputs(cfg, params, x_c, dt_rank):
    mc = cfg.mamba
    dbc = x_c.astype(params["x_proj"].dtype) @ params["x_proj"]
    dt = dbc[..., :dt_rank]
    b_ssm = dbc[..., dt_rank: dt_rank + mc.d_state].astype(jnp.float32)
    c_ssm = dbc[..., dt_rank + mc.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt @ params["dt_w"]).astype(jnp.float32) + params["dt_b"])
    return dt, b_ssm, c_ssm


def _ssm_step(A, D, h, x_t, dt_t, b_t, c_t):
    """One selective-scan step. h (B, d_in, N); x_t/dt_t (B, d_in);
    b_t/c_t (B, N)."""
    dA = jnp.exp(dt_t[..., None] * A)                       # (B, d_in, N)
    dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, c_t) + D * x_t
    return h, y


def mamba_forward(
    cfg: ModelConfig,
    params,
    x: Array,                       # (B, S, D)
    *,
    mode: str,                      # "full" | "decode"
    state=None,
    update_cache: bool = False,
) -> Tuple[Array, Optional[dict]]:
    mc, d_in, dt_rank = _dims(cfg)
    B, S, _ = x.shape
    xp, z = _split_proj(cfg, params, x)
    A = -jnp.exp(params["A_log"])
    D = params["D"]

    if mode == "full":
        x_c = jax.nn.silu(_causal_conv_full(params, xp, mc.d_conv))
        dt, b_ssm, c_ssm = _ssm_inputs(cfg, params, x_c, dt_rank)
        h0 = (state["ssm"] if state is not None
              else jnp.zeros((B, d_in, mc.d_state), jnp.float32))

        from repro.models.attention import _use_pallas
        if _use_pallas() and S % 256 == 0 and d_in % 128 == 0:
            # fused Pallas selective scan: state stays in VMEM across the
            # whole sequence instead of an HBM round-trip per step
            # (§Perf iteration 8)
            from repro.kernels.mamba_scan import mamba_scan_pallas
            y, hT = mamba_scan_pallas(x_c, dt, b_ssm, c_ssm, A, D, h0)
        else:
            def step(h, inp):
                x_t, dt_t, b_t, c_t = inp
                h, yt = _ssm_step(A, D, h, x_t, dt_t, b_t, c_t)
                return h, yt

            hT, ys = jax.lax.scan(
                step, h0,
                (x_c.swapaxes(0, 1), dt.swapaxes(0, 1),
                 b_ssm.swapaxes(0, 1), c_ssm.swapaxes(0, 1)))
            y = ys.swapaxes(0, 1)                            # (B, S, d_in)
        new_state = state
        if update_cache and state is not None:
            tail = xp[:, -mc.d_conv:]
            pad = mc.d_conv - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_state = dict(state, ssm=hT,
                             conv=tail.astype(state["conv"].dtype))
    elif mode == "decode":
        assert state is not None and S == 1
        conv = jnp.concatenate(
            [state["conv"][:, 1:], xp.astype(state["conv"].dtype)], axis=1)
        w = params["conv_w"].astype(jnp.float32)
        x_c = jax.nn.silu(
            jnp.einsum("bkd,kd->bd", conv.astype(jnp.float32), w)
            + params["conv_b"].astype(jnp.float32))[:, None]  # (B,1,d_in)
        dt, b_ssm, c_ssm = _ssm_inputs(cfg, params, x_c, dt_rank)
        h, y = _ssm_step(A, D, state["ssm"], x_c[:, 0], dt[:, 0],
                         b_ssm[:, 0], c_ssm[:, 0])
        y = y[:, None]
        new_state = dict(state, conv=conv, ssm=h)
    else:
        raise ValueError(mode)

    y = (y.astype(x.dtype) * jax.nn.silu(z)).astype(x.dtype)
    return y @ params["out_proj"], new_state
