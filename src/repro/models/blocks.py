"""Layer composition + segment planning.

A model is a sequence of layers; each layer = pre-norm mixer (attention /
mamba / mLSTM / sLSTM) + optional cross-attention + optional FFN (dense or
MoE), with (optionally depth-scaled) residuals.

Heterogeneous stacks (Jamba's 1:7 interleave, xLSTM's 7:1, DeepSeek's
dense-then-MoE) are compiled into *segments*: maximal periodic runs whose
parameters are stacked along a repeat dim and executed under ``lax.scan`` —
keeping the lowered HLO compact for 61–80-layer models.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import (attention_forward, cross_attn_forward,
                                    init_attention, init_cross_attn)
from repro.models.common import KeyGen, rms_norm
from repro.models.ffn import dense_ffn, init_dense_ffn
from repro.models.moe import init_moe, moe_forward
from repro.models.state import cache_capacity, init_layer_state

Array = jax.Array


class LayerSpec(NamedTuple):
    block: str        # attn | mamba | mlstm | slstm
    is_moe: bool
    d_ff: int         # dense-path d_ff (0 = no FFN sublayer)
    cross: bool       # has cross-attention (enc-dec decoder layers)


class Segment(NamedTuple):
    specs: Tuple[LayerSpec, ...]
    repeats: int
    layer_start: int


def layer_specs(cfg: ModelConfig, decoder: bool = True) -> List[LayerSpec]:
    specs = []
    for i, blk in enumerate(cfg.block_pattern):
        is_moe = cfg.layer_is_moe(i) and blk != "mlstm" and blk != "slstm"
        d_ff = cfg.d_ff
        if cfg.moe is not None and i < cfg.moe.first_dense_layers:
            d_ff = cfg.moe.first_dense_d_ff or cfg.d_ff
        if blk in ("mlstm", "slstm"):
            d_ff = 0
        cross = decoder and cfg.is_encoder_decoder and blk == "attn"
        specs.append(LayerSpec(blk, is_moe, d_ff, cross))
    return specs


def plan_segments(specs: List[LayerSpec], max_period: int = 16) -> List[Segment]:
    """Greedy maximal periodic runs (prefers the longest total run)."""
    segs: List[Segment] = []
    i, L = 0, len(specs)
    while i < L:
        best_p, best_r = 1, 1
        for p in range(1, min(max_period, L - i) + 1):
            r = 1
            while (i + (r + 1) * p <= L
                   and specs[i + r * p: i + (r + 1) * p] == specs[i: i + p]):
                r += 1
            if r >= 2 and p * r > best_p * best_r:
                best_p, best_r = p, r
        segs.append(Segment(tuple(specs[i: i + best_p]), best_r, i))
        i += best_p * best_r
    return segs


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    kg = KeyGen(key)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.ones((d,), dtype)}
    if spec.block == "attn":
        p["mixer"] = init_attention(kg(), cfg, dtype)
    elif spec.block == "mamba":
        p["mixer"] = mamba_mod.init_mamba(kg(), cfg, dtype)
    elif spec.block == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(kg(), cfg, dtype)
    elif spec.block == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(kg(), cfg, dtype)
    if spec.cross:
        p["cross_norm"] = jnp.ones((d,), dtype)
        p["cross"] = init_cross_attn(kg(), cfg, dtype)
    if spec.d_ff or spec.is_moe:
        p["norm2"] = jnp.ones((d,), dtype)
        if spec.is_moe:
            p["ffn"] = init_moe(kg(), cfg, dtype)
        else:
            p["ffn"] = init_dense_ffn(kg(), cfg, spec.d_ff, dtype)
    return p


def _sp(x, ctx):
    """Sequence-parallel residual constraint (§Perf iteration 2): between TP
    blocks the residual stream shards its seq dim over the model axis,
    turning each TP boundary all-reduce into reduce-scatter + all-gather
    and sharding the norms. Enabled by the caller when S divides the mesh."""
    if not ctx.get("seq_shard"):
        return x
    from repro import sharding
    return sharding.constrain(x, "batch", "seq", None)


def apply_layer(cfg: ModelConfig, spec: LayerSpec, params, x: Array,
                state, ctx: Dict[str, Any]) -> Tuple[Array, Any, Array]:
    """Returns (x, new_state, aux_loss)."""
    rs = cfg.residual_scale
    aux = jnp.float32(0.0)
    x = _sp(x, ctx)
    h_in = rms_norm(x, params["norm1"], cfg.rms_norm_eps)
    kw = dict(mode=ctx["mode"], state=state, update_cache=ctx["update_cache"])
    if spec.block == "attn":
        h, new_state = attention_forward(
            cfg, params["mixer"], h_in, positions=ctx["positions"],
            t=ctx.get("t"), window=ctx.get("window"),
            causal=ctx.get("causal", True),
            history=ctx.get("history", 0),
            paged=ctx.get("paged"), **kw)
    elif spec.block == "mamba":
        h, new_state = mamba_mod.mamba_forward(cfg, params["mixer"], h_in, **kw)
    elif spec.block == "mlstm":
        h, new_state = xlstm_mod.mlstm_forward(cfg, params["mixer"], h_in, **kw)
    elif spec.block == "slstm":
        h, new_state = xlstm_mod.slstm_forward(cfg, params["mixer"], h_in, **kw)
    else:
        raise ValueError(spec.block)
    x = x + rs * h

    if spec.cross:
        x = _sp(x, ctx)
        cx = rms_norm(x, params["cross_norm"], cfg.rms_norm_eps)
        h, new_state = _apply_cross(cfg, params["cross"], cx, new_state, ctx)
        x = x + rs * h

    if spec.d_ff or spec.is_moe:
        x = _sp(x, ctx)
        f_in = rms_norm(x, params["norm2"], cfg.rms_norm_eps)
        if spec.is_moe:
            h, aux = moe_forward(cfg, params["ffn"], f_in)
        else:
            h = dense_ffn(cfg, params["ffn"], f_in)
        x = x + rs * h
    return x, new_state, aux


def _apply_cross(cfg, params, cx, state, ctx):
    h, new_state = cross_attn_forward(
        cfg, params, cx, enc_out=ctx.get("enc_out"), state=state,
        precompute=ctx.get("precompute_cross", False))
    return h, new_state if new_state is not None else state


# ---------------------------------------------------------------------------
# Segment init / apply (stacked params, lax.scan over repeats)
# ---------------------------------------------------------------------------


def init_segment(key, cfg: ModelConfig, seg: Segment, dtype):
    """Params stacked along the repeat dim for each position-in-period."""
    out = {}
    keys = jax.random.split(key, len(seg.specs))
    for j, spec in enumerate(seg.specs):
        layer_keys = jax.random.split(keys[j], seg.repeats)
        out[f"p{j}"] = jax.vmap(
            lambda k: init_layer(k, cfg, spec, dtype))(layer_keys)
    return out


def init_segment_state(cfg: ModelConfig, seg: Segment, batch: int,
                       capacity: int, dtype, cross_len: Optional[int]):
    out = {}
    for j, spec in enumerate(seg.specs):
        one = init_layer_state(
            cfg, spec.block, batch, capacity, dtype,
            cross_len=cross_len if spec.cross else None)
        out[f"p{j}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (seg.repeats,) + a.shape).copy()
            if seg.repeats > 1 else a[None], one)
    return out


def apply_segment(cfg: ModelConfig, seg: Segment, params, x: Array,
                  seg_state, ctx: Dict[str, Any], remat: bool
                  ) -> Tuple[Array, Any, Array]:
    """Scan the periodic body over the repeat dim."""
    has_state = seg_state is not None

    def body(carry, xs):
        xc, aux = carry
        lp, ls = xs if has_state else (xs, None)
        new_states = {}
        for j, spec in enumerate(seg.specs):
            st_j = ls[f"p{j}"] if has_state else None
            xc, st_new, aux_j = apply_layer(cfg, spec, lp[f"p{j}"], xc, st_j, ctx)
            if has_state:
                new_states[f"p{j}"] = st_new
            aux = aux + aux_j
        return (xc, aux), (new_states if has_state else None)

    if remat:
        body = jax.checkpoint(body)

    xs = (params, seg_state) if has_state else params
    (x, aux), states = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return x, states, aux


# ---------------------------------------------------------------------------
# Whole-stack helpers
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig, dtype, decoder: bool = True):
    specs = layer_specs(cfg, decoder=decoder)
    segs = plan_segments(specs)
    keys = jax.random.split(key, len(segs))
    return segs, [init_segment(k, cfg, s, dtype) for k, s in zip(keys, segs)]


def init_stack_state(cfg: ModelConfig, segs: List[Segment], batch: int,
                     seq_len: int, long_context: bool, dtype,
                     cross_len: Optional[int] = None):
    cap = cache_capacity(cfg, seq_len, long_context)
    return [init_segment_state(cfg, s, batch, cap, dtype, cross_len)
            for s in segs]


def apply_stack(cfg: ModelConfig, segs: List[Segment], seg_params, x: Array,
                states, ctx: Dict[str, Any], remat: bool = False):
    aux_total = jnp.float32(0.0)
    new_states = []
    for i, seg in enumerate(segs):
        st = states[i] if states is not None else None
        x, st_new, aux = apply_segment(cfg, seg, seg_params[i], x, st, ctx, remat)
        new_states.append(st_new)
        aux_total = aux_total + aux
    return x, (new_states if states is not None else None), aux_total
