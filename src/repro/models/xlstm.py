"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
exponential gating), after arXiv:2405.04517.

Both run a stabilized recurrent ``lax.scan`` in full mode (the chunkwise
parallel mLSTM form is an optimization target tracked in EXPERIMENTS.md
§Perf) and single-step recurrence in decode mode.

Faithfulness notes (documented simplifications):
  * q/k/v projections are headwise block-diagonal (LinearHeadwiseExpand in
    the reference code), matching the ~1.3B parameter budget.
  * i/f gates are per-head scalars from the conv features; o gate is an
    elementwise sigmoid on the up-projected stream.
  * sLSTM recurrent gates use headwise block-diagonal recurrent matrices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, gelu
from repro.models.state import xlstm_dims

Array = jax.Array


def _headwise_init(key, heads: int, hd_in: int, hd_out: int, dtype):
    return (jax.random.normal(key, (heads, hd_in, hd_out), jnp.float32)
            * hd_in ** -0.5).astype(dtype)


def _headwise(x: Array, w: Array) -> Array:
    """x (..., H, hd_in) @ w (H, hd_in, hd_out) -> (..., H, hd_out)."""
    return jnp.einsum("...hi,hio->...ho", x, w)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype):
    xc = cfg.xlstm
    d = cfg.d_model
    d_in, hd = xlstm_dims(cfg, "mlstm")
    h = cfg.num_heads
    kg = KeyGen(key)
    return {
        "w_up": dense_init(kg(), d, d_in, dtype),
        "w_z": dense_init(kg(), d, d_in, dtype),
        "conv_w": (jax.random.normal(kg(), (xc.conv1d_kernel_size, d_in),
                                     jnp.float32) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": _headwise_init(kg(), h, hd, hd, dtype),
        "wk": _headwise_init(kg(), h, hd, hd, dtype),
        "wv": _headwise_init(kg(), h, hd, hd, dtype),
        "w_i": (jax.random.normal(kg(), (h, hd), jnp.float32) * 0.01),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": (jax.random.normal(kg(), (h, hd), jnp.float32) * 0.01),
        "b_f": jnp.full((h,), 3.0, jnp.float32),  # forget bias -> remember
        "w_o": jnp.zeros((d_in,), jnp.float32),
        "w_down": dense_init(kg(), d_in, d, dtype),
    }


def _mlstm_step(q_t, k_t, v_t, i_t, f_t, carry):
    """One stabilized mLSTM step, all f32.
    q/k/v (B,H,hd); i/f (B,H); carry (C (B,H,hd,hd), n (B,H,hd), m (B,H))."""
    C, n, m = carry
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        v_t[..., None, :] * k_t[..., :, None])            # C[k-dim, v-dim]
    n = f_p[..., None] * n + i_p[..., None] * k_t
    num = jnp.einsum("bhkv,bhk->bhv", C, q_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)),
                      jnp.exp(-m_new))[..., None]
    return (C, n, m_new), num / den


def _mlstm_qkvif(cfg, params, x, conv_hist=None, serving=False):
    """conv_hist: (B, ksize-1, d_in) previous inputs for decode continuity."""
    xc = cfg.xlstm
    d_in, hd = xlstm_dims(cfg, "mlstm")
    h = cfg.num_heads
    B, S, _ = x.shape
    x_up = x @ params["w_up"]
    z = x @ params["w_z"]
    # causal depthwise conv + silu (optionally continued from history)
    w = params["conv_w"].astype(jnp.float32)
    ks = xc.conv1d_kernel_size
    hist = ks - 1
    if conv_hist is not None:
        x_ext = jnp.concatenate(
            [conv_hist.astype(x_up.dtype), x_up], axis=1)
    else:
        x_ext = jnp.pad(x_up, ((0, 0), (hist, 0), (0, 0)))
    acc = jnp.zeros((B, S, d_in), jnp.float32)
    for i in range(ks):
        acc += x_ext[:, i: i + S].astype(jnp.float32) * w[i]
    x_c = jax.nn.silu(acc + params["conv_b"].astype(jnp.float32))
    x_ch = x_c.reshape(B, S, h, hd)
    x_uh = x_up.astype(jnp.float32).reshape(B, S, h, hd)
    # sharding scheme (§Perf iteration 4): q/k replicated across the model
    # axis, v sharded on its head dim -> the matrix memory C shards its
    # value dim and every in-scan op is local (no per-timestep collectives).
    # SERVING ONLY: under jax.grad the backward scan all-gathers the sharded
    # C per timestep for the dq cotangent (measured 10x regression on
    # train_4k — §Perf iteration 4b), so training keeps GSPMD's choice.
    from repro import sharding
    q = _headwise(x_ch, params["wq"].astype(jnp.float32))
    k = _headwise(x_ch, params["wk"].astype(jnp.float32)) * hd ** -0.5
    v = _headwise(x_uh, params["wv"].astype(jnp.float32))
    if serving:
        q = sharding.constrain(q, "batch", None, None, None)
        k = sharding.constrain(k, "batch", None, None, None)
        v = sharding.constrain(v, "batch", None, None, "model")
    else:
        # batch-only pins (§Perf iteration 7b): GSPMD loses the batch
        # sharding through the chunk scan and replicates the whole global
        # batch per chip; pinning batch is backward-safe (no model-axis
        # cotangent pathology — that came from sharding C's value dim)
        q = sharding.constrain(q, "batch", None, None, None)
        k = sharding.constrain(k, "batch", None, None, None)
        v = sharding.constrain(v, "batch", None, None, None)
    i_pre = jnp.einsum("bshd,hd->bsh", x_ch, params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bshd,hd->bsh", x_ch, params["w_f"]) + params["b_f"]
    f_pre = jax.nn.log_sigmoid(f_pre)
    o = jax.nn.sigmoid(x_up.astype(jnp.float32) * params["w_o"])
    return q, k, v, i_pre, f_pre, o, z


# ---------------------------------------------------------------------------
# Chunkwise-parallel mLSTM (§Perf iteration 7)
#
# The stabilized recurrence is reformulated over chunks of length L: within
# a chunk everything is causal matmuls (the D-masked q·k^T form), and the
# matrix memory C is only touched at chunk boundaries — cutting both the
# sequential depth (S -> S/L) and the HBM traffic on C by a factor of L.
# The carry convention (C_hat = C_true * exp(-m), n_hat, m) is identical to
# the recurrent step, so chunkwise prefill composes with recurrent decode.
# ---------------------------------------------------------------------------


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, carry0, chunk: int):
    """q/k/v (B,S,H,hd) f32; i_pre/f_pre (B,S,H); carry0 (C,n,m).
    Returns (carry, h (B,S,H,hd))."""
    B, S, H, hd = q.shape
    NC = S // chunk
    L = chunk

    def to_chunks(t):
        return t.reshape(B, NC, L, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(to_chunks, (q, k, v, i_pre, f_pre)))

    def chunk_body(carry, xs_c):
        C, n, m_c = carry                        # (B,H,dk,dv),(B,H,dk),(B,H)
        qc, kc, vc, ic, fc = xs_c                # (B,L,H,*)
        b = jnp.cumsum(fc, axis=1)               # (B,L,H) inclusive log-decay
        B_L = b[:, -1]                           # (B,H)
        a = ic - b                               # i~_s - b_s
        M = jax.lax.cummax(a, axis=1)            # running max over s<=t
        m_t = b + jnp.maximum(m_c[:, None], M)   # (B,L,H)
        # intra-chunk: D[t,s] = exp(b_t - m_t + a_s), s <= t
        logD = (b - m_t)[:, :, None, :] + a[:, None, :, :]   # (B,t,s,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(causal[None, :, :, None], jnp.exp(logD), 0.0)
        s_qk = jnp.einsum("blhd,bmhd->blmh", qc, kc)
        intra_num = jnp.einsum("blmh,bmhv->blhv", s_qk * D, vc)
        intra_n = jnp.einsum("blmh,bmhd->blhd", D, kc)
        # inter-chunk: decayed state contribution
        inter_scale = jnp.exp(m_c[:, None] - jnp.maximum(m_c[:, None], M))
        inter_num = jnp.einsum("blhd,bhdv->blhv", qc, C) \
            * inter_scale[..., None]
        n_comb = n[:, None] * inter_scale[..., None] + intra_n
        den = jnp.maximum(
            jnp.abs(jnp.einsum("blhd,blhd->blh", qc, n_comb)),
            jnp.exp(-m_t))
        h = (inter_num + intra_num) / den[..., None]
        # chunk-end state update
        m_new = B_L + jnp.maximum(m_c, M[:, -1])
        w = jnp.exp(a + (B_L - m_new)[:, None])              # (B,L,H)
        decay = jnp.exp(m_c + B_L - m_new)
        C_new = C * decay[..., None, None] + jnp.einsum(
            "blhd,blhv->bhdv", kc * w[..., None], vc)
        n_new = n * decay[..., None] + jnp.einsum("blh,blhd->bhd", w, kc)
        return (C_new, n_new, m_new), h

    carry, hs = jax.lax.scan(chunk_body, carry0, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd)
    return carry, h


MLSTM_CHUNK = 64


def mlstm_forward(cfg: ModelConfig, params, x: Array, *, mode: str,
                  state=None, update_cache: bool = False
                  ) -> Tuple[Array, Optional[dict]]:
    d_in, hd = xlstm_dims(cfg, "mlstm")
    h = cfg.num_heads
    B, S, _ = x.shape
    conv_hist = state["conv"][:, 1:] if (mode == "decode" and state is not None) else None
    serving = update_cache or mode == "decode"
    q, k, v, i_pre, f_pre, o, z = _mlstm_qkvif(cfg, params, x, conv_hist,
                                               serving=serving)

    from repro import sharding as _sh
    if state is not None:
        carry0 = (state["C"], state["n"], state["m"])
    else:
        carry0 = (jnp.zeros((B, h, hd, hd), jnp.float32),
                  jnp.zeros((B, h, hd), jnp.float32),
                  jnp.zeros((B, h), jnp.float32))
    if serving:
        # pin the matrix memory's value-dim sharding for the whole scan
        carry0 = (_sh.constrain(carry0[0], "batch", None, None, "model"),
                  carry0[1], carry0[2])

    if mode == "full":
        if S % MLSTM_CHUNK == 0 and S >= 2 * MLSTM_CHUNK:
            carry, h_seq = _mlstm_chunkwise(q, k, v, i_pre, f_pre, carry0,
                                            MLSTM_CHUNK)
        else:
            def step(carry_, inp):
                q_t, k_t, v_t, i_t, f_t = inp
                carry_, h_t = _mlstm_step(q_t, k_t, v_t, i_t, f_t, carry_)
                return carry_, h_t

            carry, hs = jax.lax.scan(
                step, carry0,
                (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
                 i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1)))
            h_seq = hs.swapaxes(0, 1)                    # (B,S,H,hd)
        new_state = state
        if update_cache and state is not None:
            ks = cfg.xlstm.conv1d_kernel_size
            x_up = (x @ params["w_up"]).astype(jnp.float32)
            tail = x_up[:, -ks:]
            pad = ks - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_state = dict(state, C=carry[0], n=carry[1], m=carry[2],
                             conv=tail)
    elif mode == "decode":
        assert state is not None and S == 1
        carry, h_t = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                 i_pre[:, 0], f_pre[:, 0], carry0)
        h_seq = h_t[:, None]
        x_up1 = (x @ params["w_up"]).astype(jnp.float32)
        conv = jnp.concatenate([state["conv"][:, 1:], x_up1], axis=1)
        new_state = dict(state, C=carry[0], n=carry[1], m=carry[2], conv=conv)
    else:
        raise ValueError(mode)

    out = (h_seq.reshape(B, S, d_in) * o).astype(x.dtype)
    out = out * jax.nn.silu(z)
    return out @ params["w_down"], new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_ff, _ = xlstm_dims(cfg, "slstm")
    h = cfg.num_heads
    hd = d // h
    kg = KeyGen(key)
    return {
        "w_in": dense_init(kg(), d, 4 * d, dtype),          # i,f,z,o from x
        "r": _headwise_init(kg(), h, hd, 4 * hd, dtype),    # recurrent, headwise
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "w_up": dense_init(kg(), d, 2 * d_ff, dtype),       # gated FFN
        "w_down": dense_init(kg(), d_ff, d, dtype),
    }


def _slstm_step(cfg, params, x_t, carry):
    """x_t (B, 4d) pre-activations from input; carry (c, n, h, m) each (B,d)."""
    d = cfg.d_model
    heads = cfg.num_heads
    hd = d // heads
    c, n, h_prev, m = carry
    rec = _headwise(h_prev.reshape(-1, heads, hd),
                    params["r"].astype(jnp.float32)).reshape(-1, 4 * d)
    pre = x_t + rec + params["b"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    f_pre = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_p = jnp.exp(i_pre - m_new)
    f_p = jnp.exp(f_pre + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_pre)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(cfg: ModelConfig, params, x: Array, *, mode: str,
                  state=None, update_cache: bool = False
                  ) -> Tuple[Array, Optional[dict]]:
    d = cfg.d_model
    d_ff, _ = xlstm_dims(cfg, "slstm")
    B, S, _ = x.shape
    from repro import sharding as _sh
    x_pre = (x @ params["w_in"]).astype(jnp.float32)        # (B,S,4d)
    x_pre = _sh.constrain(x_pre, "batch", None, None)  # §Perf iteration 7b

    if state is not None:
        carry0 = (state["c"], state["n"], state["h"], state["m"])
    else:
        z = jnp.zeros((B, d), jnp.float32)
        carry0 = (z, z, z, z)

    if mode == "full":
        def step(carry, x_t):
            return _slstm_step(cfg, params, x_t, carry)
        carry, hs = jax.lax.scan(step, carry0, x_pre.swapaxes(0, 1))
        h_seq = hs.swapaxes(0, 1)
        new_state = state
        if update_cache and state is not None:
            new_state = dict(state, c=carry[0], n=carry[1], h=carry[2],
                             m=carry[3])
    elif mode == "decode":
        assert state is not None and S == 1
        carry, h_t = _slstm_step(cfg, params, x_pre[:, 0], carry0)
        h_seq = h_t[:, None]
        new_state = dict(state, c=carry[0], n=carry[1], h=carry[2], m=carry[3])
    else:
        raise ValueError(mode)

    h_seq = h_seq.astype(x.dtype)
    up = h_seq @ params["w_up"]
    gate, val = jnp.split(up, 2, axis=-1)
    return (gelu(gate) * val) @ params["w_down"], new_state
