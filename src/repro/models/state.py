"""Serving-state pytrees: attention KV caches (dense / ring-buffer / MLA
latent), Mamba states, xLSTM states.

All states are plain dicts (pytrees) so they stack cleanly under the
layer-scan and shard with NamedSharding. Every state dict carries only
arrays; the scalar clock ``t`` lives in the engine, passed per call.

Layout conventions (R = segment repeat dim, added by the model's layer scan):
  attention KV : k,v          (B, W, KVH, HD)    W = cache window capacity
  MLA latent   : c_kv         (B, W, kv_lora_rank)
                 k_rope       (B, W, qk_rope_head_dim)
  mamba        : conv         (B, d_conv, d_in)
                 ssm          (B, d_in, d_state)
  mlstm        : C            (B, H, DK, DV)
                 n            (B, H, DK)
                 m            (B, H)
  slstm        : c,n,h        (B, d_in)
                 m            (B, d_in)
  encoder memory (enc-dec)   : enc_out (B, S_src, D) + per-layer cross K/V
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cache_capacity(cfg: ModelConfig, seq_len: int, long_context: bool) -> int:
    """KV capacity for attention layers: ring-buffer window when the
    long-context sliding-window policy is active, else full seq_len.

    jamba keeps FULL attention KV even at 500k (sharded over the data axis;
    see DESIGN.md §5) because its 9 attention layers make that affordable —
    this exercises the sharded-KV decode-combine path.
    """
    if not long_context:
        if cfg.sliding_window is not None and seq_len > cfg.sliding_window:
            return cfg.sliding_window
        return seq_len
    if cfg.family == "hybrid":
        return seq_len  # jamba: full KV, data-sharded
    win = cfg.sliding_window or cfg.long_context_window
    return min(win, seq_len)


def init_attn_kv(cfg: ModelConfig, batch: int, capacity: int, dtype):
    if cfg.attention_kind == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, capacity, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, capacity, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
    }


def xlstm_dims(cfg: ModelConfig, kind: str):
    xc = cfg.xlstm
    if kind == "mlstm":
        d_in = int(xc.proj_factor_mlstm * cfg.d_model)
    else:
        d_in = int(xc.proj_factor_slstm * cfg.d_model)
    head_dim = d_in // cfg.num_heads
    return d_in, head_dim


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_in, hd = xlstm_dims(cfg, "mlstm")
    h = cfg.num_heads
    k = cfg.xlstm.conv1d_kernel_size
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "conv": jnp.zeros((batch, k, d_in), jnp.float32),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def init_layer_state(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                     dtype, cross_len: Optional[int] = None):
    """State for one layer of the given mixer kind (no repeat dim)."""
    if kind == "attn":
        st = init_attn_kv(cfg, batch, capacity, dtype)
        if cross_len is not None:  # enc-dec decoder layer: cached cross K/V
            st["xk"] = jnp.zeros(
                (batch, cross_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            st["xv"] = jnp.zeros(
                (batch, cross_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        return st
    if kind == "mamba":
        return init_mamba_state(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return init_slstm_state(cfg, batch)
    raise ValueError(kind)


def state_bytes(state) -> int:
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(state))
