"""Shared model-building primitives (pure functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    """Fan-in scaled normal init, shape (in_dim, out_dim)."""
    std = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]                             # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: Array, dim: int) -> Array:
    """Classic transformer sinusoidal embedding; positions (...,) -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: Array) -> Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Key splitting helper
# ---------------------------------------------------------------------------


class KeyGen:
    """Deterministic PRNG splitter: kg = KeyGen(key); kg() -> fresh subkey."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
