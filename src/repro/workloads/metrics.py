"""SLO-centric serving metrics shared by both backends.

TTFT / TBT / JCT percentiles say how fast the cluster is; operators buy
capacity against **SLO attainment** (what fraction of requests met their
latency targets) and **goodput** (how many SLO-compliant requests per
time unit) — the axes the paper's §5 comparisons are really about.  All
functions operate on the shared request record
(:class:`repro.serving.request.Request` or its simulator adapter), in
whatever time unit the backend's :class:`repro.workloads.Clock` reports.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence


@dataclass(frozen=True)
class SLO:
    """Latency targets, in the backend's clock units; ``inf`` = don't care."""
    ttft: float = float("inf")
    tbt: float = float("inf")
    jct: float = float("inf")

    def met_by(self, req) -> bool:
        """True iff ``req`` finished inside every configured target."""
        if req.finish_time is None or req.first_token_time is None:
            return False
        if req.ttft() > self.ttft or req.jct() > self.jct:
            return False
        tbts = req.tbts()
        return not tbts or max(tbts) <= self.tbt


@dataclass
class SLOSummary:
    n_submitted: int
    n_finished: int
    n_unfinished: int
    #: fraction of *submitted* requests meeting every target (unfinished,
    #: shed and aborted requests all count as misses — an overloaded run
    #: that refuses work at the door must not look healthy because the
    #: refusals never completed: a shed request IS an SLO miss)
    attainment: float
    attainment_ttft: float      # fraction of finished meeting the TTFT target
    attainment_tbt: float       # fraction of finished meeting the TBT target
    goodput: float              # SLO-compliant requests per time unit
    unit: str = "units"
    #: admission control refused these (bounded queue / deadline shed)
    n_shed: int = 0
    #: torn down mid-flight (client cancel or KV-pressure abort)
    n_aborted: int = 0

    def describe(self) -> str:
        extra = ""
        if self.n_shed or self.n_aborted:
            extra = f", {self.n_shed} shed, {self.n_aborted} aborted"
        return (f"SLO attainment={self.attainment:.1%} "
                f"(ttft={self.attainment_ttft:.1%}, "
                f"tbt={self.attainment_tbt:.1%}); "
                f"goodput={self.goodput:.3f} req/{self.unit} "
                f"[{self.n_finished} finished, "
                f"{self.n_unfinished} unfinished{extra}]")


def slo_summary(requests: Iterable, slo: SLO, duration: float,
                unit: str = "units") -> SLOSummary:
    """Score a request set (finished or not) against ``slo`` over the run's
    ``duration`` in backend clock units."""
    from repro.serving.request import Phase
    reqs = list(requests)
    finished = [r for r in reqs if r.finish_time is not None]
    n_shed = sum(1 for r in reqs if r.phase is Phase.SHED)
    n_aborted = sum(1 for r in reqs if r.phase is Phase.ABORTED)
    unfinished = len(reqs) - len(finished) - n_shed - n_aborted
    good = ok_ttft = ok_tbt = 0
    for r in finished:
        ttft, tbts = r.ttft(), r.tbts()
        t_ok = ttft is not None and ttft <= slo.ttft
        b_ok = not tbts or max(tbts) <= slo.tbt
        ok_ttft += t_ok
        ok_tbt += b_ok
        good += t_ok and b_ok and r.jct() <= slo.jct
    n = len(reqs)
    nf = len(finished)
    return SLOSummary(
        n_submitted=n, n_finished=nf, n_unfinished=unfinished,
        attainment=good / n if n else math.nan,
        attainment_ttft=ok_ttft / nf if nf else math.nan,
        attainment_tbt=ok_tbt / nf if nf else math.nan,
        goodput=good / duration if duration > 0 else math.nan,
        unit=unit, n_shed=n_shed, n_aborted=n_aborted,
    )


@dataclass(frozen=True)
class TimelinePoint:
    """One observation of cluster state (sampled per iteration on the live
    executor, per event on the simulator)."""
    t: float
    queue_depth: int        # routed-but-not-yet-prefilled + unrouted
    n_prefill: int          # instances running a prefill (or mixed) batch
    n_decode: int           # instances running a decode step
    n_idle: int


def utilization(timeline: Sequence[TimelinePoint],
                n_instances: int) -> Dict[str, float]:
    """Mean fraction of instances in each phase across the timeline."""
    if not timeline or n_instances <= 0:
        return {"prefill": math.nan, "decode": math.nan, "idle": math.nan}
    n = len(timeline) * n_instances
    return {
        "prefill": sum(p.n_prefill for p in timeline) / n,
        "decode": sum(p.n_decode for p in timeline) / n,
        "idle": sum(p.n_idle for p in timeline) / n,
    }


def queue_depth_stats(timeline: Sequence[TimelinePoint]) -> Dict[str, float]:
    if not timeline:
        return {"mean": math.nan, "peak": math.nan}
    depths: List[int] = [p.queue_depth for p in timeline]
    return {"mean": sum(depths) / len(depths), "peak": float(max(depths))}
