"""Backend-agnostic traffic layer: one workload kernel, two clocks.

``WorkloadSpec`` (arrival process × length model × modality extras)
produces a deterministic ``RequestSource`` that both the live executor
(``repro.scheduling.live``) and the discrete-event simulator
(``repro.sim.cluster``) consume unchanged; ``Clock`` maps the stream's
abstract time units onto each backend's time (scheduling iterations vs
modeled seconds).  ``SLO`` / ``slo_summary`` score either backend's
output on attainment and goodput.
"""
from repro.workloads.arrivals import (ArrivalProcess, Batch, Bursty,
                                      ClosedLoop, DiurnalRamp, Poisson,
                                      TraceFileReplay, TraceReplay)
from repro.workloads.clock import Clock, IterationClock, ModeledSecondsClock
from repro.workloads.lengths import (TABLE2, LengthModel, LognormalLengths,
                                     TableLengths, TraceFileLengths,
                                     TraceLengths, UniformLengths)
from repro.workloads.metrics import (SLO, SLOSummary, TimelinePoint,
                                     queue_depth_stats, slo_summary,
                                     utilization)
from repro.workloads.spec import (PrefixReuse, RequestSource, WorkloadSpec,
                                  default_extras, load_trace, save_trace,
                                  table2_spec)

__all__ = [
    "ArrivalProcess", "Batch", "Poisson", "Bursty", "DiurnalRamp",
    "ClosedLoop", "TraceReplay", "TraceFileReplay",
    "LengthModel", "TableLengths", "UniformLengths", "LognormalLengths",
    "TraceLengths", "TraceFileLengths", "TABLE2",
    "Clock", "IterationClock", "ModeledSecondsClock",
    "SLO", "SLOSummary", "TimelinePoint", "slo_summary", "utilization",
    "queue_depth_stats",
    "WorkloadSpec", "RequestSource", "PrefixReuse", "default_extras",
    "save_trace", "load_trace", "table2_spec",
]
