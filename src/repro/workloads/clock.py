"""Clock abstraction: one traffic stream, two notions of time.

Arrival processes emit times in abstract units; each executor advances a
``Clock`` in its own currency and admits requests whose arrival stamp is
due.  The live executor ticks one **scheduling iteration** at a time; the
simulator jumps its clock to each event's **modeled second**.
"""
from __future__ import annotations


class Clock:
    """Monotonic backend time; ``unit`` labels reported latencies."""

    unit = "units"

    def __init__(self, now: float = 0.0):
        self.now = now

    def tick(self, dt: float = 1.0) -> float:
        self.now += dt
        return self.now

    def advance_to(self, t: float) -> float:
        if t > self.now:
            self.now = t
        return self.now

    def __repr__(self):
        return f"{type(self).__name__}(now={self.now:.3f} {self.unit})"


class IterationClock(Clock):
    """Live executor time: one tick per scheduling iteration."""

    unit = "iters"


class ModeledSecondsClock(Clock):
    """Simulator time: modeled wall seconds from the analytic PerfModel."""

    unit = "s"
