"""Length models: *how big* each request is (prompt and decode tokens).

``TABLE2`` holds the paper's Table 2 ranges; ``TableLengths`` is the
single implementation of its uniform sampling (previously duplicated
between ``repro.api.sample_requests`` and ``repro.sim.workload``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

#: name -> ((prompt lo, hi), (decode lo, hi)) — paper Table 2
TABLE2 = {
    "light": ((20, 500), (20, 500)),
    "mixed": ((20, 1000), (20, 1000)),
    "heavy": ((500, 1000), (500, 1000)),
}


class LengthModel:
    """Base class; ``sample`` draws (prompt_len, decode_len) for the
    ``i``-th request of the stream."""

    def sample(self, rng: np.random.Generator, i: int) -> Tuple[int, int]:
        raise NotImplementedError


@dataclass(frozen=True)
class TableLengths(LengthModel):
    """Uniform prompt/decode lengths per the paper's Table 2, optionally
    scaled down (``scale`` < 1) for CPU-sized live engines."""
    workload: str = "mixed"
    scale: float = 1.0
    min_prompt: int = 4
    min_decode: int = 2

    def sample(self, rng, i):
        (plo, phi), (dlo, dhi) = TABLE2[self.workload]
        plen = max(self.min_prompt, int(rng.integers(plo, phi + 1) * self.scale))
        dlen = max(self.min_decode, int(rng.integers(dlo, dhi + 1) * self.scale))
        return plen, dlen


@dataclass(frozen=True)
class UniformLengths(LengthModel):
    """Uniform lengths over explicit inclusive ranges."""
    prompt: Tuple[int, int]
    decode: Tuple[int, int]

    def sample(self, rng, i):
        return (int(rng.integers(self.prompt[0], self.prompt[1] + 1)),
                int(rng.integers(self.decode[0], self.decode[1] + 1)))


@dataclass(frozen=True)
class LognormalLengths(LengthModel):
    """Heavy-tailed lengths (production traces are closer to lognormal
    than to Table 2's uniform ranges — e.g. BurstGPT / Azure traces)."""
    prompt_median: float
    decode_median: float
    prompt_sigma: float = 0.8
    decode_sigma: float = 0.8
    max_prompt: int = 8192
    max_decode: int = 8192

    def sample(self, rng, i):
        plen = int(np.exp(rng.normal(np.log(self.prompt_median),
                                     self.prompt_sigma)))
        dlen = int(np.exp(rng.normal(np.log(self.decode_median),
                                     self.decode_sigma)))
        return (min(max(1, plen), self.max_prompt),
                min(max(1, dlen), self.max_decode))


@dataclass(frozen=True)
class TraceLengths(LengthModel):
    """Replays recorded (prompt_len, decode_len) pairs by stream index."""
    pairs: Sequence[Tuple[int, int]]

    def sample(self, rng, i):
        plen, dlen = self.pairs[i]
        return int(plen), int(dlen)


@dataclass(frozen=True)
class TraceFileLengths(LengthModel):
    """Streams (prompt_len, decode_len) pairs off a JSONL trace file
    (``load_trace(path, stream=True)``) with a forward-only cursor:
    ``RequestSource`` samples indices 0, 1, 2, ... in order, so each line
    is read exactly when needed and the trace never lives in memory.  A
    rewind (a fresh source re-iterating from index 0) re-opens the file."""
    path: str

    def __post_init__(self):
        object.__setattr__(self, "_fh", None)
        object.__setattr__(self, "_next", 0)

    def _reopen(self):
        fh = self.__dict__.get("_fh")
        if fh is not None:
            fh.close()
        object.__setattr__(self, "_fh", open(self.path))
        object.__setattr__(self, "_next", 0)

    def sample(self, rng, i):
        import json
        if self.__dict__.get("_fh") is None or i < self._next:
            self._reopen()
        fh = self._fh
        rec = None
        while self._next <= i:
            line = fh.readline()
            if not line:
                raise IndexError(
                    f"trace {self.path!r} has no record {i}")
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            object.__setattr__(self, "_next", self._next + 1)
        return int(rec["prompt_len"]), int(rec["decode_len"])
