"""Arrival processes: *when* requests hit the cluster.

Every process yields monotonically non-decreasing arrival times in
abstract **time units**; the consuming backend decides what a unit means
(one scheduling iteration for the live executor, one modeled second for
the discrete-event simulator).  All draws come from the caller-supplied
``numpy`` Generator, so a seeded :class:`repro.workloads.RequestSource`
produces the identical stream on both backends.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

import numpy as np


class ArrivalProcess:
    """Base class; subclasses implement :meth:`times`."""

    #: for closed-loop processes: the number of requests kept in flight;
    #: open-loop (timed) processes leave this ``None``
    concurrency: Optional[int] = None

    def times(self, rng: np.random.Generator) -> Iterator[float]:
        raise NotImplementedError


@dataclass(frozen=True)
class Batch(ArrivalProcess):
    """``n`` requests all arriving at ``at`` — the legacy submit-everything
    -up-front pattern, kept as a degenerate arrival process so old callers
    run through the same lifecycle."""
    n: int
    at: float = 0.0

    def times(self, rng):
        for _ in range(self.n):
            yield self.at


@dataclass(frozen=True)
class Poisson(ArrivalProcess):
    """Memoryless arrivals at ``rate`` requests per time unit for
    ``duration`` units (the paper's §5.1 workload driver)."""
    rate: float
    duration: float

    def times(self, rng):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.rate)
            if t >= self.duration:
                return
            yield t


@dataclass(frozen=True)
class Bursty(ArrivalProcess):
    """Markov-modulated on-off Poisson (MMPP): exponential ON phases at
    ``rate_on`` alternating with exponential OFF phases at ``rate_off``.
    The classic bursty-traffic model load balancers are judged under."""
    rate_on: float
    duration: float
    rate_off: float = 0.0
    mean_on: float = 1.0
    mean_off: float = 1.0

    def times(self, rng):
        t, on = 0.0, True
        phase_end = rng.exponential(self.mean_on)
        while t < self.duration:
            rate = self.rate_on if on else self.rate_off
            if rate > 0.0:
                gap = rng.exponential(1.0 / rate)
                # memorylessness makes racing the phase boundary exact
                if t + gap < phase_end:
                    t += gap
                    if t >= self.duration:
                        return
                    yield t
                    continue
            t = phase_end
            on = not on
            phase_end = t + rng.exponential(
                self.mean_on if on else self.mean_off)


@dataclass(frozen=True)
class DiurnalRamp(ArrivalProcess):
    """Non-homogeneous Poisson whose rate ramps sinusoidally from ``low``
    (at t=0) up to ``peak`` (at period/2) and back, via thinning."""
    low: float
    peak: float
    period: float
    duration: float

    def rate_at(self, t: float) -> float:
        frac = 0.5 - 0.5 * math.cos(2.0 * math.pi * t / self.period)
        return self.low + (self.peak - self.low) * frac

    def times(self, rng):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / self.peak)
            if t >= self.duration:
                return
            if rng.random() * self.peak <= self.rate_at(t):
                yield t


@dataclass(frozen=True)
class ClosedLoop(ArrivalProcess):
    """``k`` synthetic users, each firing its next request the moment the
    previous one finishes.  Arrival stamps are assigned at issue time by
    the executor, so :meth:`times` yields placeholders."""
    k: int
    n_requests: int

    @property
    def concurrency(self) -> int:  # type: ignore[override]
        return self.k

    def times(self, rng):
        for _ in range(self.n_requests):
            yield 0.0


@dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replays recorded arrival instants (see
    :func:`repro.workloads.load_trace`); pairs with ``TraceLengths`` so a
    saved stream round-trips exactly."""
    arrivals: Sequence[float]

    def times(self, rng):
        last = 0.0
        for t in self.arrivals:
            if t < last:
                raise ValueError("trace arrivals must be non-decreasing")
            last = t
            yield t


@dataclass(frozen=True)
class TraceFileReplay(ArrivalProcess):
    """Streams arrival instants straight off a JSONL trace file
    (``load_trace(path, stream=True)``): each :meth:`times` call re-opens
    the file and yields one record at a time, so a million-request trace
    never materializes in memory.  Pairs with ``TraceFileLengths``."""
    path: str

    def times(self, rng):
        import json
        last = 0.0
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                t = float(json.loads(line)["arrival"])
                if t < last:
                    raise ValueError("trace arrivals must be non-decreasing")
                last = t
                yield t
