"""WorkloadSpec × RequestSource: the backend-agnostic traffic kernel.

A :class:`WorkloadSpec` is *arrival process × length model × modality
extras*.  ``spec.source(seed)`` returns a :class:`RequestSource` — a
deterministic iterator of timestamped shared request records
(:class:`repro.serving.request.Request`) consumed unchanged by both
``repro.scheduling.live.LiveCluster`` and ``repro.sim.cluster.Simulator``.
The same (spec, seed) therefore drives the identical request stream into
either backend; only the meaning of a time unit differs (iterations vs
modeled seconds — see :mod:`repro.workloads.clock`).

Draw order per request is fixed (arrival gap first, then lengths) from a
single seeded generator, so streams are reproducible and live-vs-sim
comparable by construction.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional

import numpy as np

from repro.serving.request import Request
from repro.workloads.arrivals import (ArrivalProcess, Poisson,
                                      TraceFileReplay, TraceReplay)
from repro.workloads.lengths import (LengthModel, TableLengths,
                                     TraceFileLengths, TraceLengths)

#: extras_fn(cfg, key, i) -> per-request modality payload (or None)
ExtrasFn = Callable[[object, object, int], Optional[dict]]


def default_extras(cfg, key, i: int) -> Optional[dict]:
    """The modality payloads the architectures need: vision prefix patches
    for image front-ends, encoder frames for speech (single home of what
    ``repro.api.sample_requests`` used to duplicate)."""
    import jax

    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        return {"patch_embeds": jax.random.normal(
            jax.random.fold_in(key, 1000 + i),
            (1, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))}
    if cfg.is_encoder_decoder:
        # frames length must equal the encoder memory capacity so the
        # engine can merge the per-request state into its slot
        return {"frames": jax.random.normal(
            jax.random.fold_in(key, 1000 + i),
            (1, cfg.encoder.max_source_positions, cfg.frontend.embed_dim))}
    return None


@dataclass(frozen=True)
class PrefixReuse:
    """Shared-prefix traffic shape (system prompts / multi-turn reuse).

    With probability ``reuse`` a request draws one of ``pool`` prefix
    groups and its prompt head repeats that group's tokens — the
    substrate the prefix cache dedups.  ``growth`` lines are added to a
    group's declared prefix each time it is drawn (conversation history
    accreting onto a shared system prompt), capped at ``max_prefix``
    (default: ``prefix_len``, i.e. no growth).  Declared prefixes are
    always clamped below the request's prompt length.

    Group tokens are generated ONCE per group at ``max_prefix`` length
    from the stream key alone, and reuse draws happen AFTER the length
    draws — so the same (spec, seed) yields bit-identical prompts and
    lengths whether or not a backend's cache is enabled, and
    cache-on/cache-off runs are token-comparable by construction.
    """
    pool: int = 4
    reuse: float = 0.5
    prefix_len: int = 64
    growth: int = 0
    max_prefix: Optional[int] = None

    @property
    def cap(self) -> int:
        return self.max_prefix if self.max_prefix is not None \
            else self.prefix_len


@dataclass(frozen=True)
class WorkloadSpec:
    """Everything that defines the traffic, nothing about the backend."""
    arrival: ArrivalProcess
    lengths: LengthModel
    extras_fn: Optional[ExtrasFn] = None
    name: str = ""
    #: shared-prefix reuse shape (None: every prompt is unique)
    prefix_reuse: Optional[PrefixReuse] = None

    def source(self, seed: int = 0, cfg=None) -> "RequestSource":
        """A fresh deterministic request stream.  Pass the model ``cfg``
        on live backends to materialize prompt tokens and modality extras;
        the simulator needs neither and should omit it."""
        return RequestSource(self, seed=seed, cfg=cfg)

    def describe(self) -> str:
        label = self.name or type(self.arrival).__name__.lower()
        return (f"workload '{label}': arrival={self.arrival!r} "
                f"lengths={self.lengths!r}")


class RequestSource:
    """Iterator of timestamped shared request records.

    * ``rid`` is the stream index (0, 1, ...), identical across backends.
    * ``arrival`` is in abstract time units (see module docstring).
    * With ``cfg``: ``prompt_tokens`` and ``extra`` are materialized for
      real engines; without, records stay array-free for the simulator.
    * ``concurrency`` is non-None for closed-loop specs — executors then
      issue requests on completion instead of by arrival stamp.
    """

    def __init__(self, spec: WorkloadSpec, seed: int = 0, cfg=None):
        self.spec = spec
        self.seed = seed
        self.cfg = cfg

    @property
    def concurrency(self) -> Optional[int]:
        return self.spec.arrival.concurrency

    def __iter__(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        jax = key = None
        if self.cfg is not None:
            import jax
            key = jax.random.PRNGKey(self.seed)
        pr = self.spec.prefix_reuse
        # per-group declared prefix length (grows by pr.growth per draw)
        psize: dict = {}
        gtoks: dict = {}
        for i, t in enumerate(self.spec.arrival.times(rng)):
            plen, dlen = self.spec.lengths.sample(rng, i)
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          arrival=float(t), rid=i)
            if pr is not None and pr.pool > 0:
                # drawn AFTER lengths, unconditionally — the stream stays
                # bit-identical for every consumer of this spec+seed
                hit_draw = rng.random()
                g = int(rng.integers(pr.pool))
                if hit_draw < pr.reuse:
                    cur = psize.setdefault(g, pr.prefix_len)
                    req.prefix_id = g
                    req.prefix_len = min(cur, plen)
                    psize[g] = min(cur + pr.growth, pr.cap)
            if self.cfg is not None:
                req.prompt_tokens = jax.random.randint(
                    jax.random.fold_in(key, i), (1, plen), 0,
                    self.cfg.vocab_size)
                if req.prefix_id is not None and req.prefix_len > 0:
                    # group tokens are a fixed max-length sequence drawn
                    # from the stream key alone: every member of the
                    # group shares the same prompt head, regardless of
                    # draw order or per-request prefix length
                    if req.prefix_id not in gtoks:
                        gtoks[req.prefix_id] = jax.random.randint(
                            jax.random.fold_in(key,
                                               (1 << 20) + req.prefix_id),
                            (1, pr.cap), 0, self.cfg.vocab_size)
                    n = req.prefix_len
                    req.prompt_tokens = req.prompt_tokens.at[0, :n].set(
                        gtoks[req.prefix_id][0, :n])
                extras = self.spec.extras_fn or default_extras
                req.extra = extras(self.cfg, key, i)
            yield req

    def materialize(self) -> List[Request]:
        return list(self)


# ---------------------------------------------------------------------------
# JSONL trace round-trip
# ---------------------------------------------------------------------------


def save_trace(path, requests) -> int:
    """Write a request stream as JSONL ({arrival, prompt_len, decode_len}
    per line); returns the number of records written."""
    n = 0
    with open(path, "w") as fh:
        for r in requests:
            decode_len = getattr(r, "decode_len", None)
            if decode_len is None:
                decode_len = r.max_new_tokens
            fh.write(json.dumps({"arrival": r.arrival,
                                 "prompt_len": r.prompt_len,
                                 "decode_len": decode_len}) + "\n")
            n += 1
    return n


def load_trace(path, name: str = "", stream: bool = False) -> WorkloadSpec:
    """Read a JSONL trace back into a replayable :class:`WorkloadSpec`.

    With ``stream=True`` the spec replays straight off the file
    (``TraceFileReplay`` × ``TraceFileLengths``): nothing is materialized
    up front, so a 10^6-line trace costs O(1) memory — the form
    ``benchmarks/bench_scale.py`` feeds the million-request harness."""
    if stream:
        return WorkloadSpec(arrival=TraceFileReplay(str(path)),
                            lengths=TraceFileLengths(str(path)),
                            name=name or f"trace:{path}")
    arrivals, pairs = [], []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            arrivals.append(float(rec["arrival"]))
            pairs.append((int(rec["prompt_len"]), int(rec["decode_len"])))
    return WorkloadSpec(arrival=TraceReplay(tuple(arrivals)),
                        lengths=TraceLengths(tuple(pairs)),
                        name=name or f"trace:{path}")


def table2_spec(workload: str, rate: float, duration: float,
                scale: float = 1.0) -> WorkloadSpec:
    """The paper's §5.1 setup: Poisson arrivals with Table-2 lengths."""
    return WorkloadSpec(arrival=Poisson(rate=rate, duration=duration),
                        lengths=TableLengths(workload=workload, scale=scale),
                        name=workload)
