"""Scale layer: array-backed scheduler state + vectorized policy kernels.

  state    — ArrayClusterState: numpy struct-of-arrays over the sim's
             request/instance accounting, kept coherent by observing
             container wrappers; serves the ClusterView/InstanceView
             protocols so every kernel runs unchanged
  kernels  — accellm-vec / vllm-vec / splitwise-vec / ulb-vec: the hot
             route/pair/rebalance loops as argmin/argmax over instance
             arrays, bit-identical to their scalar kernels

Imports are lazy (PEP 562): ``repro.scheduling.registry`` pulls
``kernels`` in at its bottom to self-register the vectorized names, and
``kernels`` imports scheduling submodules — a top-level import here
would close that loop while either side is still initializing.
"""
from __future__ import annotations

_EXPORTS = {
    "ArrayClusterState": "repro.scale.state",
    "ArrayClusterView": "repro.scale.state",
    "ArrayInstanceView": "repro.scale.state",
    "VectorAcceLLMScheduler": "repro.scale.kernels",
    "VectorVLLMScheduler": "repro.scale.kernels",
    "VectorSplitwiseScheduler": "repro.scale.kernels",
    "VectorULBScheduler": "repro.scale.kernels",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
