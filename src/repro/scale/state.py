"""Array-backed cluster state: the simulator's hot-path accounting as a
numpy struct-of-arrays (ROADMAP "Beat the scheduler at scale").

The dict-backed views in ``repro.sim.policies`` recompute every byte sum
and per-request mapping with interpreted Python on every scheduling
decision — O(residents) attribute walks per ``mem_free()``, per
``decode_weights()``, per ``request_lines()``.  At production arrival
rates the scheduler, not the accelerator, becomes the bottleneck.

``ArrayClusterState`` keeps the same quantities as incremental arrays:

  * **global request arrays** indexed by rid — ``req_prompt``,
    ``req_gen``, ``req_max_new`` (int64) and ``req_replica`` (int32, -1
    = unmirrored) mirror each ``SimRequest``'s fields and the adapter's
    placement ledger.  They are synced when a request enters a container
    and advanced in bulk by the simulator's decode hook, so a per-token
    loop never touches them one rid at a time.
  * **per-instance role caches** — the rid-sorted member array of each
    decode batch / replica set, its length vector
    (``req_prompt[rids] + req_gen[rids]``), and the byte aggregates
    derived from it.  Membership changes mark the cache dirty (rebuilt
    once, in C, at the next read); token growth only bumps a version
    counter and re-vectorizes the length vector.

Coherence is by *interception*, not by convention: at attach time every
``SimInstance.decode_batch`` / ``replicas`` / ``prefill_queue`` is
wrapped in an observing container, and ``SimInstance.__setattr__``
re-wraps rebinds (``inst.prefill_queue = [...]`` in the fleet paths), so
the ~30 existing mutation sites in ``repro.sim`` keep working unchanged
and cannot silently desynchronize the arrays.

**Bit-identical by construction**: every byte quantity here is an exact
integer (``LineCosts.line_bytes`` and ``fixed_bytes`` are integral, see
``repro.core.kvbytes``), and all sums stay far below 2**53 — so float64
aggregates computed as ``line_bytes * lens.sum() + fixed * n`` equal the
scalar views' per-request Python sums *exactly*, and every argmin /
argmax in ``repro.scale.kernels`` reproduces the dict-backed kernels'
decisions bit for bit (the golden equivalence of tests/test_scale.py).

Scope: the array state is a **simulator** accelerator — it is attached
by ``KernelPolicy.bind`` when the kernel declares ``vectorized = True``.
On the live backend the vector kernels fall back to their scalar
superclass paths (``getattr(cluster, "arrays", None) is None``).
Chunked-prefill kernels (Sarathi) are not vectorized: the queue-token
aggregate assumes whole-prompt prefills (no resumable cursors).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sim.cluster import SimInstance, Simulator
from repro.sim.policies import SimClusterView, SimInstanceView
from repro.stepplan import Planner

__all__ = ["ArrayClusterState", "ArrayClusterView", "ArrayInstanceView"]


# ---------------------------------------------------------------------------
# Observing containers: existing mutation sites keep the arrays coherent
# ---------------------------------------------------------------------------


class _ObsDict(dict):
    """A decode-batch / replica dict that reports membership changes."""

    __slots__ = ("_rec", "_role")

    def __init__(self, data, rec: "_InstRec", role: str):
        super().__init__(data)
        self._rec = rec
        self._role = role
        for rid, r in data.items():
            rec.state._sync_req(rid, r)
        rec.touch(role)

    def __setitem__(self, rid, r):
        super().__setitem__(rid, r)
        self._rec.state._sync_req(rid, r)
        self._rec.touch(self._role)

    def __delitem__(self, rid):
        super().__delitem__(rid)
        self._rec.touch(self._role)

    def pop(self, rid, *default):
        out = super().pop(rid, *default)
        self._rec.touch(self._role)
        return out

    def popitem(self):
        out = super().popitem()
        self._rec.touch(self._role)
        return out

    def clear(self):
        super().clear()
        self._rec.touch(self._role)

    def update(self, *a, **kw):
        super().update(*a, **kw)
        for rid, r in self.items():
            self._rec.state._sync_req(rid, r)
        self._rec.touch(self._role)

    def setdefault(self, rid, default=None):
        out = super().setdefault(rid, default)
        if out is default:
            self._rec.state._sync_req(rid, default)
        self._rec.touch(self._role)
        return out


class _ObsList(list):
    """A prefill queue that maintains its token aggregate.  ``append``
    (the per-arrival hot path) accounts incrementally; every other
    mutator just marks the aggregate dirty for a full recount at the
    next read — queue surgery is rare (fleet kills, compile dequeues)."""

    __slots__ = ("_rec",)

    def __init__(self, data, rec: "_InstRec"):
        super().__init__(data)
        self._rec = rec
        rec.q_dirty = True

    def append(self, r):
        super().append(r)
        rec = self._rec
        if not rec.q_dirty:
            rec.q_tokens += r.prompt_len - (getattr(r, "prefix_hit", 0) or 0)

    def _dirty(self):
        self._rec.q_dirty = True

    def extend(self, it):
        super().extend(it)
        self._dirty()

    def insert(self, i, r):
        super().insert(i, r)
        self._dirty()

    def pop(self, *a):
        out = super().pop(*a)
        self._dirty()
        return out

    def remove(self, r):
        super().remove(r)
        self._dirty()

    def clear(self):
        super().clear()
        self._dirty()

    def __setitem__(self, i, v):
        super().__setitem__(i, v)
        self._dirty()

    def __delitem__(self, i):
        super().__delitem__(i)
        self._dirty()

    def __iadd__(self, it):
        out = super().__iadd__(it)
        self._dirty()
        return out

    def sort(self, *a, **kw):
        super().sort(*a, **kw)
        self._dirty()

    def reverse(self):
        super().reverse()
        self._dirty()


class _ObsPlacement(dict):
    """The adapter's placement ledger, mirroring each rid's replica
    instance into ``req_replica`` (for vectorized mirrored counts)."""

    __slots__ = ("_state",)

    def __init__(self, data, state: "ArrayClusterState"):
        super().__init__(data)
        self._state = state
        for rid, pl in data.items():
            state._sync_replica(rid, pl[1])

    def __setitem__(self, rid, pl):
        super().__setitem__(rid, pl)
        self._state._sync_replica(rid, pl[1])

    def __delitem__(self, rid):
        super().__delitem__(rid)
        self._state._sync_replica(rid, None)

    def pop(self, rid, *default):
        out = super().pop(rid, *default)
        self._state._sync_replica(rid, None)
        return out

    def clear(self):
        for rid in self:
            self._state._sync_replica(rid, None)
        super().clear()

    def update(self, *a, **kw):
        super().update(*a, **kw)
        for rid, pl in self.items():
            self._state._sync_replica(rid, pl[1])


# ---------------------------------------------------------------------------
# Per-instance record: rid-sorted member caches + byte aggregates
# ---------------------------------------------------------------------------

_EMPTY_I64 = np.empty(0, dtype=np.int64)
_EMPTY_F64 = np.empty(0, dtype=np.float64)


class _RoleCache:
    """One role's (decode batch / replica set) cached columns.

    Validity is layered so the per-iteration decode hook never forces a
    recompute: ``mem_key`` bumps on membership rebuilds, ``key`` on any
    content change (keys the derived ``weights``), ``stale`` forces a
    full value recompute at next read, ``vecs_stale`` marks only the
    length *vector* outdated while the byte aggregates were maintained
    incrementally (the replica-side advance path), and ``agg_gen`` is
    the global value-change version the cache was computed against."""

    __slots__ = ("rids", "lens", "bytes", "rem", "key", "mem_key",
                 "agg_gen", "adv_gen", "stale", "vecs_stale",
                 "weights", "weights_key", "mirrored", "mirrored_key")

    def __init__(self):
        self.rids = _EMPTY_I64
        self.lens = _EMPTY_I64
        self.bytes = 0.0
        self.rem = 0
        self.key = 0
        self.mem_key = 0
        self.agg_gen = -1
        self.adv_gen = -1
        self.stale = True
        self.vecs_stale = False
        self.weights = _EMPTY_F64
        self.weights_key = -1
        self.mirrored = 0
        self.mirrored_key = (-1, -1)


class _InstRec:
    """Array-state record for one ``SimInstance``."""

    __slots__ = ("state", "inst", "line_bytes", "fixed_bytes", "capacity",
                 "max_batch", "prim_dirty", "rep_dirty", "prim", "rep",
                 "q_dirty", "q_tokens", "prim_muts", "muts_at_plan")

    def __init__(self, state: "ArrayClusterState", inst: SimInstance):
        self.state = state
        self.inst = inst
        costs = inst.store.costs
        self.line_bytes = float(costs.line_bytes)
        self.fixed_bytes = float(costs.fixed_bytes)
        self.capacity = float(inst.perf.kv_capacity_bytes)
        self.max_batch = inst.max_batch
        self.prim_dirty = True
        self.rep_dirty = True
        self.prim = _RoleCache()
        self.rep = _RoleCache()
        self.q_dirty = True
        self.q_tokens = 0
        # monotonic decode-batch mutation counter + its value when the
        # running plan's lengths were read: equality at decode-done
        # means membership never changed across the span, so the span's
        # survivors are exactly the cached rid array (no per-rid filter)
        self.prim_muts = 0
        self.muts_at_plan = -1

    def touch(self, role: str):
        if role == "prim":
            self.prim_dirty = True
            self.prim_muts += 1
        else:
            self.rep_dirty = True

    # -- cache refresh -------------------------------------------------------
    def _refresh(self, role: str) -> _RoleCache:
        """Aggregates (bytes / rem) current on return; the length vector
        may still be ``vecs_stale`` (use :meth:`_vectors` when it is
        read).  The fast path — nothing changed, or only incremental
        advance updates were applied — is a few flag compares.

        Primaries stay current through :meth:`advance_prim`'s exact
        incremental updates; replica sets (whose lengths grow when their
        *primaries* decode elsewhere) are invalidated wholesale by the
        global advance counter and re-gathered on read — replica reads
        are far rarer than decode events, so lazy loses nothing."""
        state = self.state
        if role == "prim":
            cache, d, dirty = self.prim, self.inst.decode_batch, \
                self.prim_dirty
        else:
            cache, d, dirty = self.rep, self.inst.replicas, self.rep_dirty
            if cache.adv_gen != state.adv_version:
                cache.stale = True
        if dirty:
            n = len(d)
            cache.rids = (np.sort(np.fromiter(d.keys(), np.int64, n))
                          if n else _EMPTY_I64)
            cache.mem_key += 1
            cache.stale = True
            if role == "prim":
                self.prim_dirty = False
            else:
                self.rep_dirty = False
        if cache.stale or cache.agg_gen != state.gen_version:
            rids = cache.rids
            if len(rids):
                lens = state.req_prompt[rids] + state.req_gen[rids]
                cache.lens = lens
                # exact: integral line_bytes x integer line total, < 2**53
                cache.bytes = (self.line_bytes * float(lens.sum())
                               + self.fixed_bytes * len(rids))
                if role == "prim":
                    cache.rem = int(state.req_max_new[rids].sum()
                                    - state.req_gen[rids].sum())
            else:
                cache.lens = _EMPTY_I64
                cache.bytes = 0.0
                cache.rem = 0
            cache.agg_gen = state.gen_version
            cache.adv_gen = state.adv_version
            cache.stale = False
            cache.vecs_stale = False
            cache.key += 1
        return cache

    def _vectors(self, role: str) -> _RoleCache:
        """Like :meth:`_refresh` but with the length vector current too
        (re-gathered only if an incremental advance skipped it)."""
        cache = self._refresh(role)
        if cache.vecs_stale:
            rids = cache.rids
            cache.lens = ((self.state.req_prompt[rids]
                           + self.state.req_gen[rids])
                          if len(rids) else _EMPTY_I64)
            cache.vecs_stale = False
            cache.key += 1
        return cache

    # -- incremental decode-advance updates -----------------------------------
    def advance_prim(self, n_advanced: int, steps: int):
        """Every resident request generated ``steps`` tokens: O(1) byte
        and remaining-token updates plus one vectorized length add —
        exact integer arithmetic, so the values equal a recompute bit
        for bit.  Bails to a lazy recompute when the cache isn't clean
        or a mid-span join means not every member advanced."""
        cache = self.prim
        if self.prim_dirty or cache.stale \
                or cache.agg_gen != self.state.gen_version:
            return
        if n_advanced != len(cache.rids):
            cache.stale = True
            return
        cache.lens += steps          # private array, never aliased out
        cache.bytes += self.line_bytes * (steps * n_advanced)
        cache.rem -= steps * n_advanced
        cache.key += 1

    def role_weights(self, role: str) -> Tuple[np.ndarray, np.ndarray]:
        """(rid-sorted members, per-request state bytes) — the
        ``decode_weights`` / ``replica_weights`` columns."""
        cache = self._vectors(role)
        if cache.weights_key != cache.key:
            cache.weights = (self.line_bytes * cache.lens.astype(np.float64)
                             + self.fixed_bytes)
            cache.weights_key = cache.key
        return cache.rids, cache.weights

    def mirrored_count(self) -> int:
        cache = self._refresh("prim")
        key = (cache.mem_key, self.state.place_version)
        if cache.mirrored_key != key:
            cache.mirrored = (int((self.state.req_replica[cache.rids] >= 0)
                                  .sum()) if len(cache.rids) else 0)
            cache.mirrored_key = key
        return cache.mirrored

    def backlog_tokens(self) -> int:
        if self.q_dirty:
            self.q_tokens = sum(
                r.prompt_len - (getattr(r, "prefix_hit", 0) or 0)
                for r in self.inst.prefill_queue)
            self.q_dirty = False
        return self.q_tokens

    # -- aggregate reads -----------------------------------------------------
    def state_bytes(self) -> float:
        # same fp expression as SimInstance.state_bytes: prim sum + rep
        # sum (both exact integers in float64)
        return self._refresh("prim").bytes + self._refresh("rep").bytes

    def mem_free(self) -> float:
        return self.capacity - self.state_bytes()


# ---------------------------------------------------------------------------
# The cluster state
# ---------------------------------------------------------------------------


class ArrayClusterState:
    """Struct-of-arrays accounting over a :class:`Simulator`, attached by
    ``KernelPolicy.bind`` for ``vectorized`` kernels.  One instance per
    adapter; owns the observable wrappers, the global request arrays and
    the per-instance records, and serves the persistent array views."""

    _TRACKED = ("decode_batch", "replicas", "prefill_queue")

    def __init__(self, sim: Simulator, placement: Dict[int, Tuple[int,
                 Optional[int]]], planner: Optional[Planner] = None):
        self.sim = sim
        self.planner = planner
        cap = 1024
        self.req_prompt = np.zeros(cap, dtype=np.int64)
        self.req_gen = np.zeros(cap, dtype=np.int64)
        self.req_max_new = np.zeros(cap, dtype=np.int64)
        self.req_replica = np.full(cap, -1, dtype=np.int32)
        self.gen_version = 0
        self.adv_version = 0
        self.place_version = 0
        self.fleet_version = 0
        self._usable = np.empty(0, dtype=bool)
        self._usable_version = -1
        self._n_synced = -1
        self.recs: List[_InstRec] = []
        self.placement = _ObsPlacement(placement, self)
        self._view = ArrayClusterView(self)
        for inst in sim.instances:
            self._attach(inst)

    # -- request-array maintenance -------------------------------------------
    def _grow(self, rid: int):
        cap = len(self.req_prompt)
        while cap <= rid:
            cap *= 2
        for name in ("req_prompt", "req_gen", "req_max_new"):
            old = getattr(self, name)
            new = np.zeros(cap, dtype=np.int64)
            new[:len(old)] = old
            setattr(self, name, new)
        old = self.req_replica
        new = np.full(cap, -1, dtype=np.int32)
        new[:len(old)] = old
        self.req_replica = new

    def _sync_req(self, rid: int, r):
        if r is None:
            return
        if rid >= len(self.req_prompt):
            self._grow(rid)
        p, g, m = r.prompt_len, r.generated, r.max_new_tokens
        if (self.req_prompt[rid] != p or self.req_gen[rid] != g
                or self.req_max_new[rid] != m):
            self.req_prompt[rid] = p
            self.req_gen[rid] = g
            self.req_max_new[rid] = m
            # a value actually changed out of band (prefill completion,
            # rollback): conservative global invalidation — this is
            # per-request-rare; the per-iteration path goes through
            # note_decode_advance's targeted updates instead
            self.gen_version += 1

    def _sync_replica(self, rid: int, replica: Optional[int]):
        if rid >= len(self.req_prompt):
            self._grow(rid)
        self.req_replica[rid] = -1 if replica is None else replica
        self.place_version += 1

    def note_decode_advance(self, inst: SimInstance, rids, steps: int):
        """Simulator hook: every rid in ``rids`` (still resident on
        ``inst`` after a decode span) generated exactly ``steps`` tokens.
        One vectorized add replaces per-token bookkeeping, and the
        affected caches — this instance's primaries plus the replica
        sets mirroring them — are updated *incrementally*, so the
        per-iteration path never bumps the global version (no
        cluster-wide recompute churn).  Finished requests left their
        containers through the observable wrappers and need no update."""
        iid = inst.iid
        if iid >= len(self.recs) or self.recs[iid] is None:
            # first sight of a joined instance: _attach syncs req_gen to
            # the already-advanced r.generated, so skip the increment
            self._ensure(inst)
            return
        rec = self.recs[iid]
        if rec.muts_at_plan == rec.prim_muts and not rec.prim_dirty:
            # membership untouched since the plan read its lengths: the
            # survivors ARE the cached rid array — no per-rid filter
            a = rec.prim.rids
            n = len(a)
            if not n:
                return
        else:
            d = inst.decode_batch
            survivors = [rid for rid in rids if rid in d]
            n = len(survivors)
            if not n:
                return
            if max(survivors) >= len(self.req_prompt):
                self._grow(max(survivors))
            a = np.asarray(survivors, dtype=np.int64)
        self.req_gen[a] += steps
        rec.advance_prim(n, steps)
        # replica sets mirroring the advanced primaries grew too: one
        # counter bump lazily invalidates every rep aggregate — readers
        # re-gather on demand, the per-iteration hook stays O(1)+add
        self.adv_version += 1

    # -- instance attach ------------------------------------------------------
    def _attach(self, inst: SimInstance):
        iid = inst.iid
        while len(self.recs) <= iid:
            self.recs.append(None)
        rec = _InstRec(self, inst)
        self.recs[iid] = rec
        # mark BEFORE wrapping: __setattr__ consults _arrays
        inst.__dict__["_arrays"] = self
        object.__setattr__(inst, "decode_batch",
                           _ObsDict(inst.decode_batch, rec, "prim"))
        object.__setattr__(inst, "replicas",
                           _ObsDict(inst.replicas, rec, "rep"))
        object.__setattr__(inst, "prefill_queue",
                           _ObsList(inst.prefill_queue, rec))
        self.fleet_version += 1

    def _ensure(self, inst: SimInstance) -> _InstRec:
        iid = inst.iid
        if iid >= len(self.recs) or self.recs[iid] is None:
            self._attach(inst)
        return self.recs[iid]

    def on_setattr(self, inst: SimInstance, name: str, value):
        """``SimInstance.__setattr__`` interception: rebinds of tracked
        containers re-wrap (fleet kill does ``inst.prefill_queue = []``,
        compile filters the queue by rebinding); fleet-state flips dirty
        the usable mask.  Any other attribute write falls straight
        through — this runs on every ``SimInstance`` setattr."""
        if name == "decode_batch":
            return _ObsDict(value, self._ensure(inst), "prim")
        if name == "replicas":
            return _ObsDict(value, self._ensure(inst), "rep")
        if name == "prefill_queue":
            return _ObsList(value, self._ensure(inst))
        if name == "alive" or name == "draining":
            self.fleet_version += 1
        return value

    # -- cluster-wide vectors --------------------------------------------------
    def _sync_instances(self):
        n = len(self.sim.instances)
        if n != self._n_synced:
            for inst in self.sim.instances:
                self._ensure(inst)
            self._n_synced = n

    def usable_mask(self) -> np.ndarray:
        self._sync_instances()
        if self._usable_version != self.fleet_version or \
                len(self._usable) != len(self.sim.instances):
            self._usable = np.fromiter(
                (i.alive and not i.draining for i in self.sim.instances),
                dtype=bool, count=len(self.sim.instances))
            self._usable_version = self.fleet_version
        return self._usable

    def mem_free_vec(self) -> np.ndarray:
        self._sync_instances()
        return np.fromiter((rec.mem_free() for rec in self.recs),
                           dtype=np.float64, count=len(self.recs))

    def health_vec(self) -> np.ndarray:
        """Per-instance health EWMA (``InstanceView.health``,
        vectorized).  Read straight off the instances — health mutates
        every iteration, so caching would only add invalidation
        traffic."""
        self._sync_instances()
        return np.fromiter((rec.inst.health for rec in self.recs),
                           dtype=np.float64, count=len(self.recs))

    def decode_counts(self) -> np.ndarray:
        self._sync_instances()
        return np.fromiter((len(rec.inst.decode_batch) for rec in self.recs),
                           dtype=np.int64, count=len(self.recs))

    def backlog_counts(self) -> np.ndarray:
        self._sync_instances()
        return np.fromiter((len(rec.inst.prefill_queue) for rec in self.recs),
                           dtype=np.int64, count=len(self.recs))

    def backlog_tokens_vec(self) -> np.ndarray:
        self._sync_instances()
        return np.fromiter((rec.backlog_tokens() for rec in self.recs),
                           dtype=np.int64, count=len(self.recs))

    def rem_sum_vec(self) -> np.ndarray:
        """Per-instance outstanding decode tokens (ULB's work term)."""
        self._sync_instances()
        return np.fromiter((rec._refresh("prim").rem for rec in self.recs),
                           dtype=np.int64, count=len(self.recs))

    def admit_mask(self, req, taking: int = 0) -> np.ndarray:
        """Vector ``can_admit``: the same byte/slot test every scalar
        view runs, over all instances at once."""
        self._sync_instances()
        n = len(self.recs)
        memf = self.mem_free_vec()
        need = np.fromiter(
            (rec.line_bytes * req.prompt_len + rec.fixed_bytes
             for rec in self.recs), dtype=np.float64, count=n)
        slots = np.fromiter(
            (len(rec.inst.decode_batch) + taking < rec.max_batch
             for rec in self.recs), dtype=bool, count=n)
        return (memf >= need) & slots

    # -- per-instance scalar reads (pair-local decisions) ----------------------
    def usable(self, i: int) -> bool:
        inst = self.sim.instances[i]
        return inst.alive and not inst.draining

    def decode_count(self, i: int) -> int:
        return len(self.sim.instances[i].decode_batch)

    def mem_free(self, i: int) -> float:
        return self.recs[i].mem_free()

    def can_admit(self, i: int, req, taking: int = 0) -> bool:
        rec = self.recs[i]
        fits = rec.mem_free() >= (rec.line_bytes * req.prompt_len
                                  + rec.fixed_bytes)
        return fits and len(rec.inst.decode_batch) + taking < rec.max_batch

    def can_hold_replica(self, i: int, req) -> bool:
        rec = self.recs[i]
        return rec.mem_free() >= (rec.line_bytes * req.total_len
                                  + rec.fixed_bytes)

    def is_primary(self, i: int, rid: int) -> bool:
        return rid in self.sim.instances[i].decode_batch

    def cluster_view(self) -> "ArrayClusterView":
        self._sync_instances()
        return self._view


# ---------------------------------------------------------------------------
# Protocol views over the arrays
# ---------------------------------------------------------------------------


class ArrayInstanceView(SimInstanceView):
    """InstanceView answering from the array state.  Scalar kernels (and
    the rare Mapping-returning protocol calls) still work — dicts are
    materialized from the cached arrays in C — while the hot aggregate
    reads (``mem_free``, ``can_admit``, backlog/byte totals) are O(1)
    against the incremental caches."""

    def __init__(self, state: ArrayClusterState, inst: SimInstance,
                 rec: _InstRec):
        super().__init__(inst, state.placement, state.planner)
        self._state = state
        self._rec = rec

    # -- aggregate fast paths --------------------------------------------------
    def mem_free(self) -> float:
        return self._rec.mem_free()

    def primary_bytes(self) -> float:
        return self._rec._refresh("prim").bytes

    def replica_bytes(self) -> float:
        return self._rec._refresh("rep").bytes

    def can_admit(self, req, taking: int = 0) -> bool:
        return self._state.can_admit(self._i.iid, req, taking)

    def can_hold_replica(self, req, resident: bool = False) -> bool:
        return self._state.can_hold_replica(self._i.iid, req)

    def prefill_backlog_tokens(self) -> int:
        # the aggregate assumes whole-prompt prefills; with resumable
        # chunk cursors live (Sarathi) fall back to the exact scalar sum
        if self._planner is not None and self._planner._cursors:
            return super().prefill_backlog_tokens()
        return self._rec.backlog_tokens()

    # -- vectorized Mapping materialization ------------------------------------
    def decode_weights(self) -> Dict[int, float]:
        rids, w = self._rec.role_weights("prim")
        return dict(zip(rids.tolist(), w.tolist()))

    def replica_weights(self) -> Dict[int, float]:
        rids, w = self._rec.role_weights("rep")
        return dict(zip(rids.tolist(), w.tolist()))

    def decode_remaining(self) -> Dict[int, int]:
        cache = self._rec._refresh("prim")
        rids = cache.rids
        if not len(rids):
            return {}
        rem = self._state.req_max_new[rids] - self._state.req_gen[rids]
        return dict(zip(rids.tolist(), rem.tolist()))

    def request_lines(self) -> Dict[int, int]:
        cache = self._rec._vectors("prim")
        return dict(zip(cache.rids.tolist(), cache.lens.tolist()))

    # -- planner fast path -----------------------------------------------------
    def decode_plan_stats(self) -> Tuple[Tuple[int, ...], int]:
        """(rid-ordered lengths, mirrored count) for ``DecodePlan`` —
        exactly ``sorted(request_lines().items())`` + the placements
        scan, without building either dict (consumed by
        ``Planner._decode_plan``)."""
        rec = self._rec
        cache = rec._vectors("prim")
        # stamp the mutation counter: if it is unchanged when this
        # plan's decode span completes, the cached rid array IS the
        # span's survivor set (note_decode_advance's fast path)
        rec.muts_at_plan = rec.prim_muts
        if not len(cache.rids):
            return (), 0
        return tuple(cache.lens.tolist()), self._rec.mirrored_count()


class ArrayClusterView(SimClusterView):
    """Persistent ClusterView over the array state.  ``arrays`` is the
    marker the vectorized kernels dispatch on."""

    def __init__(self, state: ArrayClusterState):
        # deliberately NOT calling super().__init__: views are persistent
        self.arrays = state
        self._state = state
        self._placement = state.placement
        self._views: List[ArrayInstanceView] = []
        self._pairs: List[Tuple[ArrayInstanceView, ArrayInstanceView]] = []

    def instances(self):
        state = self._state
        if len(self._views) != len(state.sim.instances):
            state._sync_instances()
            self._views = [ArrayInstanceView(state, rec.inst, rec)
                           for rec in state.recs]
            self._pairs = [(self._views[i], self._views[i + 1])
                           for i in range(0, len(self._views) - 1, 2)]
        return self._views

    def pairs(self):
        self.instances()
        return self._pairs

    def placements(self) -> Dict[int, Tuple[int, Optional[int]]]:
        return self._state.placement
