"""Vectorized scheduling kernels over the array-backed cluster state.

Each class subclasses its dict-backed kernel and overrides exactly the
hot decision loops — routing, pair selection, rebalance item building,
eviction victim ranking — with argmin/argmax over per-instance arrays
(``repro.scale.state``).  Everything else (role rules, action
construction, mirror bookkeeping, fleet warm-up) is inherited, so the
vectorized variants stay decision-compatible by sharing the code that
defines the decisions.

**Bit identity, not approximation**: every array expression reproduces
the scalar kernel's comparison key exactly — byte quantities are exact
integers in float64 (see ``repro.scale.state``), Splitwise's
``decode_load - mem_free*1e-18`` tiebreak is evaluated with the same
IEEE operations elementwise, and ``np.argmin``/``np.argmax`` return the
*first* extremum, which is precisely Python ``min``/``max`` semantics
under the scalar kernels' ``(key, index)`` tuples.  The golden tests in
``tests/test_scale.py`` assert identical decision traces against the
scalar kernels, event for event.

Backends: the array state only exists on the simulator (attached by
``KernelPolicy.bind``).  On the live executor ``getattr(cluster,
"arrays", None)`` is None and every override falls back to its scalar
superclass — one kernel name runs on both backends, like every other
policy in the registry.

The sim-only shortcuts the vector paths exploit (and the scalar sim
views define): ``can_queue()`` is always True (elastic backlog) and
``can_hold_primary()`` is always True (memory pressure handled by
eviction) — so AcceLLM pair eligibility reduces to "has a usable side"
and the placement swap never re-checks primary headroom.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.balancer import (Item, partition, should_rebalance_agg)
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.actions import (Action, EvictReplica, MirrorSync,
                                      PromoteReplica, StreamState)
from repro.scheduling.baselines import SplitwiseScheduler, VLLMScheduler
from repro.scheduling.ulb import ULBScheduler
from repro.scheduling.views import ClusterView, InstanceView, RequestView

__all__ = ["VectorAcceLLMScheduler", "VectorVLLMScheduler",
           "VectorSplitwiseScheduler", "VectorULBScheduler"]


class VectorVLLMScheduler(VLLMScheduler):
    name = "vllm-vec"
    vectorized = True

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().route(cluster, req)
        u = st.usable_mask()
        if not u.any():
            return None
        pool = u & st.admit_mask(req)
        if not pool.any():
            pool = u          # sim instances always queue (can_queue True)
        key = (st.decode_counts() + st.backlog_counts()).astype(np.float64)
        key[~pool] = np.inf
        target = int(np.argmin(key))   # first min == (key, index) order
        self._note("route", req.rid, target)
        return target


class VectorULBScheduler(ULBScheduler):
    name = "ulb-vec"
    vectorized = True

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().route(cluster, req)
        u = st.usable_mask()
        if not u.any():
            return None
        pool = u & st.admit_mask(req)
        if not pool.any():
            pool = u
        # outstanding work in tokens: prompt tokens still to prefill +
        # decode tokens still to generate (exact integer sums)
        work = (st.backlog_tokens_vec() + st.rem_sum_vec()) \
            .astype(np.float64)
        work[~pool] = np.inf
        target = int(np.argmin(work))
        self._note("route", req.rid, target)
        return target


class VectorSplitwiseScheduler(SplitwiseScheduler):
    name = "splitwise-vec"
    vectorized = True

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().route(cluster, req)
        mask = st.usable_mask()[: self.n_prefill]
        if not mask.any():
            return None
        key = st.backlog_tokens_vec()[: self.n_prefill] \
            .astype(np.float64)
        key[~mask] = np.inf
        target = int(np.argmin(key))
        self._note("route", req.rid, target)
        return target

    def choose_decode_target(self, cluster: ClusterView, req: RequestView
                             ) -> Optional[int]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().choose_decode_target(cluster, req)
        mask = st.usable_mask()[self.n_prefill:]
        if not mask.any():
            return None
        # the scalar kernel's exact float key, elementwise
        key = (st.decode_counts()[self.n_prefill:].astype(np.float64)
               - st.mem_free_vec()[self.n_prefill:] * 1e-18)
        key[~mask] = np.inf
        target = int(np.argmin(key)) + self.n_prefill
        self._note("target", req.rid, target)
        return target


class VectorAcceLLMScheduler(AcceLLMScheduler):
    name = "accellm-vec"
    vectorized = True

    # -- routing (§4.2.2) ---------------------------------------------------
    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().route(cluster, req)
        u = st.usable_mask()
        n_paired = (len(u) // 2) * 2
        if not n_paired:
            return None
        u2 = u[:n_paired].reshape(-1, 2)
        # _pair_can_accept over sim views reduces to "a usable side":
        # can_queue is unconditionally True there
        elig = u2.any(axis=1)
        if not elig.any():
            return None
        memf2 = st.mem_free_vec()[:n_paired].reshape(-1, 2)
        score = (memf2 * u2).sum(axis=1)   # dead side adds +0.0 — exact
        if self.hedging:
            # same arithmetic as the scalar _pair_score: free memory
            # over the pair's worst health (exactly /1.0 when nominal)
            h2 = st.health_vec()[:n_paired].reshape(-1, 2)
            score = score / h2.max(axis=1)
        score[~elig] = -np.inf
        pi = int(np.argmax(score))         # first max == Python max order
        side = self._vec_choose_side(st, pi, req)
        if side is None:
            return None
        target = 2 * pi + side
        self._note("route", req.rid, target)
        return target

    def _vec_choose_side(self, st, pi: int, req) -> Optional[int]:
        """``choose_prefill_side`` against the arrays — same branch
        structure, O(1) reads (including the victim probe's trace
        notes, which the scalar path also emits)."""
        iids = (2 * pi, 2 * pi + 1)
        live = [s for s in (0, 1) if st.usable(iids[s])]
        if not live:
            return None
        open_sides = [s for s in live if st.can_admit(iids[s], req)]
        if not open_sides:
            victims = self._vec_eviction_victims(
                st, [iids[s] for s in live], need=1)
            if victims:
                open_sides = [s for s in live
                              if iids[s] == victims[0].instance]
            else:
                open_sides = live      # sim can_queue: every live side
        if self.hedging:
            # scalar _prefill_cost over the arrays: (load+1) * health
            h = st.health_vec()
            return min(open_sides,
                       key=lambda s: ((st.decode_count(iids[s]) + 1)
                                      * float(h[iids[s]]), s))
        return min(open_sides,
                   key=lambda s: (float(st.decode_count(iids[s])), s))

    # -- graceful degradation (§4.2.5) --------------------------------------
    def evict(self, cluster: ClusterView,
              instances: Sequence[InstanceView], need: int = 1
              ) -> List[EvictReplica]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().evict(cluster, instances, need)
        return self._vec_eviction_victims(
            st, [v.index for v in instances], need)

    def _vec_eviction_victims(self, st, iids, need: int = 1
                              ) -> List[EvictReplica]:
        st._sync_instances()
        rids_all, w_all, inst_all = [], [], []
        for i in iids:
            rids, w = st.recs[i].role_weights("rep")
            if len(rids):
                rids_all.append(rids)
                w_all.append(w)
                inst_all.append(np.full(len(rids), i, dtype=np.int64))
        if not rids_all:
            return []
        rids = np.concatenate(rids_all)
        w = np.concatenate(w_all)
        insts = np.concatenate(inst_all)
        # scalar sort key (-weight, rid): lexsort orders by its LAST key
        # first; byte weights are exact integers so negation is exact
        order = np.lexsort((rids, -w))[:need]
        victims = [EvictReplica(rid=int(rids[k]), instance=int(insts[k]))
                   for k in order]
        for v in victims:
            self._note("evict", v.rid, v.instance)
        return victims

    # -- placement (§4.1.2) -------------------------------------------------
    def place_after_prefill(self, cluster: ClusterView, instance: int,
                            req: RequestView) -> List[Action]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().place_after_prefill(cluster, instance, req)
        views = cluster.instances()
        pi = instance // 2
        iids = (2 * pi, 2 * pi + 1)
        if iids[1] >= len(views):
            return super().place_after_prefill(cluster, instance, req)
        side = 0 if iids[0] == instance else 1

        def load(s: int) -> int:
            # exclude the request being placed if already resident
            i = iids[s]
            return st.decode_count(i) - (1 if st.is_primary(i, req.rid)
                                         else 0)

        dst, rep = 1 - side, side
        if not st.usable(iids[dst]):
            dst, rep = side, 1 - side
        elif load(dst) > load(rep) + self.swap_margin:
            dst, rep = side, 1 - side
        # (the scalar path re-checks can_hold_primary on a swap — that
        # is unconditionally True on sim views, so no test here)

        replica: Optional[int] = None
        if self.redundancy and st.usable(iids[rep]) \
                and st.can_hold_replica(iids[rep], req):
            replica = iids[rep]

        actions: List[Action] = []
        if dst != side:
            actions.append(StreamState(
                req.rid, src=iids[side], dst=iids[dst],
                retain_replica=replica is not None,
                skip_lines=views[iids[dst]].prefix_hit_tokens(req)))
        elif replica is not None:
            actions.append(StreamState(
                req.rid, src=iids[side], dst=replica, as_replica=True,
                skip_lines=views[iids[rep]].prefix_hit_tokens(req)))
        self._note("place", req.rid, iids[dst], replica)
        return actions

    # -- balancing by count + state bytes (§4.1.3) --------------------------
    def rebalance(self, cluster: ClusterView, pair_index: int
                  ) -> List[Action]:
        st = getattr(cluster, "arrays", None)
        if st is None:
            return super().rebalance(cluster, pair_index)
        st._sync_instances()
        iids = (2 * pair_index, 2 * pair_index + 1)
        if not (st.usable(iids[0]) and st.usable(iids[1])):
            return []
        # straggler hedging gates the regular rebalance exactly as in
        # the scalar kernel — the O(1) health test runs first, and the
        # hedge path itself (rare) reuses the scalar implementation so
        # the decisions stay bit-identical
        hedge = self._maybe_hedge(cluster, cluster.pairs()[pair_index])
        if hedge is not None:
            return hedge
        # trigger test from the cached per-side aggregates — the common
        # case (balanced pair) never materializes a single Item
        if not should_rebalance_agg(
                st.decode_count(iids[0]), st.decode_count(iids[1]),
                st.recs[iids[0]]._refresh("prim").bytes,
                st.recs[iids[1]]._refresh("prim").bytes):
            return []
        items = []
        for s in (0, 1):
            partner_idx = iids[1 - s]
            rids, w = st.recs[iids[s]].role_weights("prim")
            if not len(rids):
                continue
            movable = st.req_replica[rids] == partner_idx
            for rid, weight, mv in zip(rids.tolist(), w.tolist(),
                                       movable.tolist()):
                items.append(Item(rid=rid, weight=weight, home=s,
                                  movable=mv))
        _, _, moves = partition(items)
        views = cluster.instances()
        synced_of: dict = {}     # side -> replica_synced(), built once
        lines_of: dict = {}
        actions: List[Action] = []
        promoted = []
        for rid, src, dst in sorted(moves):
            if dst not in synced_of:
                synced_of[dst] = views[iids[dst]].replica_synced()
            synced = synced_of[dst].get(rid, 0)
            if src not in lines_of:
                lines_of[src] = views[iids[src]].request_lines()
            lines = lines_of[src].get(rid, synced)
            if synced < lines:
                actions.append(MirrorSync(rid, iids[src], iids[dst],
                                          from_line=synced, to_line=lines))
            actions.append(PromoteReplica(rid, src=iids[src],
                                          dst=iids[dst]))
            promoted.append((rid, iids[src], iids[dst]))
        if promoted:
            self._note("rebalance", tuple(promoted))
        return actions
