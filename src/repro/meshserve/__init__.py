"""Mesh serving: tensor-parallel paged decode + device-to-device
redundancy collectives (see docs/ARCHITECTURE.md, "Mesh serving").

One host becomes a multi-instance pod: :func:`carve_slices` cuts the
device list into per-instance ``("model",)`` meshes, :func:`shard_params`
/ :func:`shard_store` place an engine's replica and KV pool on its
slice, and the :mod:`collectives` primitives move mirror/stream bytes
between slices device-to-device (counted by :data:`STATS`).
:class:`MeshPlacement` bundles the slices with the heterogeneous
``InstanceSpec``s that price them on both backends.
"""
from repro.meshserve.collectives import (STATS, TransferStats,
                                         device_transfer, same_devices)
from repro.meshserve.placement import MeshPlacement
from repro.meshserve.pool import shard_params, shard_store
from repro.meshserve.topology import MeshError, MeshSlice, carve_slices

__all__ = [
    "MeshError", "MeshPlacement", "MeshSlice", "STATS", "TransferStats",
    "carve_slices", "device_transfer", "same_devices", "shard_params",
    "shard_store",
]
