"""Mesh carving: one host's devices become per-instance TP slices.

An AcceLLM *instance* is n accelerators under tensor parallelism (paper
§4.2.3: 4 accelerators, TP=4, one full model replica per instance).  This
module carves the flat device list into disjoint one-axis ``("model",)``
meshes — one :class:`MeshSlice` per instance — so a single host (or a
CPU test forced to 8 devices via ``--xla_force_host_platform_device_count``)
serves as a multi-instance pod.  Slices may be *heterogeneous*: the
paper's eval mixes H100 and Ascend 910B2 pods, which here become slices
of different widths priced by different ``InstanceSpec``s.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.sharding import Mesh


class MeshError(RuntimeError):
    """Raised when the host cannot back the requested slice shapes."""


@dataclass(frozen=True)
class MeshSlice:
    """One instance's devices as a 1-axis ``("model",)`` mesh."""

    mesh: Mesh
    index: int

    @property
    def tp(self) -> int:
        return int(self.mesh.shape["model"])

    @property
    def devices(self) -> Tuple:
        return tuple(self.mesh.devices.flat)

    def model_axis_for(self, cfg) -> Optional[str]:
        """The mesh axis the model's sharding constraints may use for
        this config — ``None`` when the head count does not divide the
        slice (constraints then replicate; params/state still shard any
        dim that IS divisible, GSPMD reshards around them)."""
        return "model" if cfg.num_heads % self.tp == 0 else None


def carve_slices(shapes: Union[int, Sequence[int]],
                 n_instances: Optional[int] = None,
                 devices: Optional[Sequence] = None) -> Tuple[MeshSlice, ...]:
    """Carve ``devices`` (default: all of ``jax.devices()``) into
    consecutive disjoint slices.  ``shapes`` is one TP width applied to
    every instance (then ``n_instances`` is required) or an explicit
    per-instance width list (heterogeneous pods)."""
    if isinstance(shapes, int):
        if n_instances is None:
            raise MeshError("carve_slices(tp_int) needs n_instances")
        widths: List[int] = [shapes] * n_instances
    else:
        widths = [int(w) for w in shapes]
    if any(w < 1 for w in widths):
        raise MeshError(f"slice widths must be >= 1, got {widths}")
    devs = list(devices if devices is not None else jax.devices())
    need = sum(widths)
    if need > len(devs):
        raise MeshError(
            f"host has {len(devs)} devices but the slices need {need} "
            f"(widths {widths}); force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    out, lo = [], 0
    for i, w in enumerate(widths):
        mesh = Mesh(np.asarray(devs[lo:lo + w]), ("model",))
        out.append(MeshSlice(mesh=mesh, index=i))
        lo += w
    return tuple(out)
