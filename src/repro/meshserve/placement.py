"""MeshPlacement: the pod layout a LiveCluster serves on.

Couples the carved :class:`repro.meshserve.topology.MeshSlice`s with the
per-instance :class:`repro.sim.devices.InstanceSpec`s that price them —
ONE object answers both "which devices run instance i" (live backend)
and "what hardware is instance i" (the spec the policy views expose and
the simulator prices with).  Heterogeneous pods (the paper's H100 vs
Ascend 910B2 eval) are just specs of different widths: each instance's
slice takes ``spec.n_devices`` devices off the host.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.meshserve.topology import MeshSlice, carve_slices
from repro.sim.devices import H100, InstanceSpec


@dataclass(frozen=True)
class MeshPlacement:
    slices: Tuple[MeshSlice, ...]
    specs: Tuple[InstanceSpec, ...]

    def __post_init__(self):
        if len(self.slices) != len(self.specs):
            raise ValueError(
                f"{len(self.slices)} slices vs {len(self.specs)} specs")

    @property
    def n_instances(self) -> int:
        return len(self.slices)

    def slice_for(self, idx: int) -> Optional[MeshSlice]:
        """Instance ``idx``'s slice; ``None`` past the carved pod (an
        autoscaled join lands unsharded on the default device)."""
        return self.slices[idx] if idx < len(self.slices) else None

    def spec_for(self, idx: int) -> Optional[InstanceSpec]:
        return self.specs[idx] if idx < len(self.specs) else None

    @classmethod
    def carve(cls, n_instances: int, tp: int = 1, *,
              specs: Optional[Sequence[InstanceSpec]] = None,
              devices: Optional[Sequence] = None) -> "MeshPlacement":
        """Carve the host into ``n_instances`` slices.  With ``specs``
        each instance's width is its spec's ``n_devices`` (heterogeneous
        pods); otherwise every slice is ``tp`` wide and priced as an
        H100-class instance of that width."""
        if specs is not None:
            specs = tuple(specs)
            if len(specs) != n_instances:
                raise ValueError(
                    f"{len(specs)} specs for {n_instances} instances")
            widths = [s.n_devices for s in specs]
        else:
            widths = [tp] * n_instances
            specs = tuple(InstanceSpec(H100, n_devices=tp)
                          for _ in range(n_instances))
        return cls(slices=carve_slices(widths, devices=devices),
                   specs=specs)
