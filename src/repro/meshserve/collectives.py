"""Device-to-device redundancy transfers between mesh slices.

``MirrorSync`` / ``StreamState`` / the Splitwise handoff move KV state
between *instances*.  When the instances live on disjoint mesh slices,
the bytes must ride the device interconnect — never a host round-trip on
the serving fast path.  The primitives here are the one place that
movement happens:

* gather the rows on the source slice (a jitted slice-local read),
* :func:`device_transfer` them onto the destination slice under a
  ``transfer_guard_device_to_host("disallow")`` — an accidental host
  bounce raises instead of silently serializing the pool,
* scatter them into the destination pool (jitted, destination donated).

Every copy is counted in the module-level :data:`STATS`
(:class:`TransferStats`) so tests can assert the fast path stayed on
device (``host_copies == 0``) and benchmarks can report moved bytes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass
class TransferStats:
    """Counters over every cross-slice transfer since the last reset."""

    d2d_copies: int = 0      #: transfers that stayed device-to-device
    d2d_bytes: int = 0       #: payload bytes of those transfers
    host_copies: int = 0     #: transfers that fell back through the host

    def reset(self) -> None:
        self.d2d_copies = 0
        self.d2d_bytes = 0
        self.host_copies = 0


#: the transfer-guard counter: one per process, like jax's own guards
STATS = TransferStats()


def _replicated_like(sharding):
    """A replicated placement over the same device set as ``sharding``.
    Compiled outputs may carry GSPMD (rank-specific) shardings, so the
    fallback rebuilds a rank-agnostic placement from the device list."""
    if isinstance(sharding, NamedSharding):
        return NamedSharding(sharding.mesh, P())
    devs = getattr(sharding, "_device_assignment", None)
    if devs:
        return NamedSharding(jax.sharding.Mesh(np.asarray(devs), ("slice",)),
                             P())
    return sharding      # single-device placements are already concrete


def device_transfer(x, like):
    """Move ``x`` onto the device set backing array ``like`` (replicated
    there; a following slice-local op reshards as needed).  The transfer
    guard turns a host round-trip into an error — the fallback path is
    counted, not hidden, so the fast-path contract stays testable."""
    dst = getattr(like, "sharding", None)
    if dst is None:
        return x
    src = getattr(x, "sharding", None)
    if src is not None and src.device_set == dst.device_set:
        return x
    target = _replicated_like(dst)
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            out = jax.device_put(x, target)
            out.block_until_ready()
    except Exception:
        STATS.host_copies += 1
        out = jax.device_put(np.asarray(x), target)
    else:
        STATS.d2d_copies += 1
        STATS.d2d_bytes += int(x.size) * x.dtype.itemsize
    return out


# slice-local jitted halves of the cross-slice copies.  The gather runs
# on the source slice, the scatter on the destination (its pool leaf is
# donated so the update is in place); the device_transfer between them
# is the only inter-slice hop.


@jax.jit
def _gather_rows(src, slot, pos):
    return src[:, slot, pos]


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(dst, rows, slot, pos):
    return dst.at[:, slot, pos].set(rows)


@jax.jit
def _gather_entry(src, slot):
    return src[:, slot]


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_entry(dst, rows, slot):
    return dst.at[:, slot].set(rows)


def pull_rows(dst, src, dst_slot: int, src_slot: int, pos):
    """Cross-slice form of the mirror's row copy: ``src``'s KV rows
    ``pos`` of ``src_slot`` land in ``dst``'s ``dst_slot``."""
    rows = _gather_rows(src, jnp.int32(src_slot), pos)
    rows = device_transfer(rows, dst)
    return _scatter_rows(dst, rows, jnp.int32(dst_slot), pos)


def pull_entry(dst, src, dst_slot: int, src_slot: int):
    """Cross-slice form of the constant-size state copy (recurrent
    leaves)."""
    rows = _gather_entry(src, jnp.int32(src_slot))
    rows = device_transfer(rows, dst)
    return _scatter_entry(dst, rows, jnp.int32(dst_slot))


def same_devices(a, b) -> bool:
    """Whether two arrays are backed by the same device set (the gate
    between the slice-local copy jits and the cross-slice pulls)."""
    sa = getattr(a, "sharding", None)
    sb = getattr(b, "sharding", None)
    if sa is None or sb is None:
        return True
    return sa.device_set == sb.device_set
