"""Sharded KV pool + parameter placement for one mesh slice.

``shard_params`` reuses the launch layer's TP rules
(``repro.launch.specs.param_pspecs``) at the slice's width, so serving
shards exactly the dims training would (column-parallel q/k/v and FFN
up, row-parallel output projections, vocab-sharded embed/lm_head).

``shard_store`` places a live :class:`repro.kvstore.PagedStore`'s state
arrays on the slice: the attention K/V leaves — stacked layout
``(R, B, W, KVH, hd)`` — shard their KV-head dim over the ``model`` axis
when divisible (the paged decode kernel gathers per head, so each shard
reads only its own heads' line blocks); everything else replicates.
Block tables stay host-side numpy and are replicated into each dispatch,
exactly as on a single device.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.specs import param_pspecs
from repro.meshserve.topology import MeshSlice

#: store leaves whose dim 3 is the KV head dim of the stacked
#: ``(R, B, W, KVH, hd)`` layout (k/v line caches + enc-dec cross caches)
_HEAD_SHARDED = ("k", "v", "xk", "xv")
_HEAD_DIM = 3


def shard_params(cfg, params, sl: MeshSlice):
    """Place (a copy of) ``params`` on the slice under its TP layout.
    The input pytree is untouched — every engine of a pod shards the
    same host copy onto its own devices."""
    specs = param_pspecs(cfg, params, mode="serve", model_n=sl.tp)
    return jax.tree_util.tree_map(
        lambda p, s: jax.device_put(p, NamedSharding(sl.mesh, s)),
        params, specs)


def shard_store(store, sl: MeshSlice) -> None:
    """Place ``store``'s state arrays on the slice, in place."""
    for i, pj, key, kind in store._paths:
        arr = store.state["layers"][i][pj][key]
        spec = [None] * arr.ndim
        if (key in _HEAD_SHARDED and arr.ndim > _HEAD_DIM + 1
                and arr.shape[_HEAD_DIM] % sl.tp == 0):
            spec[_HEAD_DIM] = "model"
        store.state["layers"][i][pj][key] = jax.device_put(
            arr, NamedSharding(sl.mesh, P(*spec)))
    if "enc_out" in store.state:
        store.state["enc_out"] = jax.device_put(
            store.state["enc_out"], NamedSharding(sl.mesh, P()))
