"""Baseline scheduling kernels from the paper's evaluation (§5.2) and
related work (§2), expressed over the shared policy API so they run on
both the live-engine executor and the simulator.

  VLLMScheduler      — independent instances, continuous batching that
                       co-schedules prefill with decode (the TBT spike of
                       paper Fig. 5 / 16).
  SarathiScheduler   — chunked prefill: bounded prompt tokens per
                       iteration, trading TTFT for TBT.
  SplitwiseScheduler — static disaggregation: dedicated prefill
                       instances; post-prefill KV transfer to a decode
                       instance is on the critical path (Fig. 1 Case B).
"""
from __future__ import annotations

from typing import List, Optional

from repro.scheduling.actions import Action, StreamState
from repro.scheduling.base import (ROLE_DECODE, ROLE_IDLE, ROLE_PREFILL,
                                   SchedulerPolicy)
from repro.scheduling.views import ClusterView, RequestView, usable


class VLLMScheduler(SchedulerPolicy):
    name = "vllm"

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        # dead/draining instances never take new work (repro.fleet)
        insts = [v for v in cluster.instances() if usable(v)]
        ok = [v for v in insts if v.can_admit(req)]
        pool = ok or [v for v in insts if v.can_queue()] or insts
        if not pool:
            return None
        # least loaded instance with memory headroom
        target = min(pool, key=lambda v: (v.decode_load()
                                          + v.prefill_backlog(),
                                          v.index)).index
        self._note("route", req.rid, target)
        return target


class SarathiScheduler(VLLMScheduler):
    """Chunked prefill: the kernel only declares the per-iteration
    prompt-token budget (``chunk_tokens``); the step planner
    (:mod:`repro.stepplan`) spends it — splitting prompts into resumable
    chunks co-scheduled with decode — identically on both backends, so
    a prompt longer than the budget actually chunks on real hardware
    instead of banking admission credit."""
    name = "sarathi"

    def __init__(self, chunk_tokens: int = 512):
        self.chunk_tokens = chunk_tokens


class SplitwiseScheduler(SchedulerPolicy):
    name = "splitwise"
    #: static disaggregation never co-schedules phases on one instance
    allow_mixed = False

    def __init__(self, n_prefill: int = 1):
        self.n_prefill = n_prefill

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        prefillers = [v for v in cluster.instances()[: self.n_prefill]
                      if usable(v)]
        if not prefillers:
            return None          # every prefill instance is down/cordoned
        target = min(prefillers,
                     key=lambda v: (v.prefill_backlog_tokens(),
                                    v.index)).index
        self._note("route", req.rid, target)
        return target

    def choose_roles(self, cluster: ClusterView, instance: int) -> str:
        inst = cluster.instances()[instance]
        if instance < self.n_prefill:
            return ROLE_PREFILL if inst.prefill_backlog() else ROLE_IDLE
        return ROLE_DECODE if inst.decode_load() else ROLE_IDLE

    def choose_decode_target(self, cluster: ClusterView, req: RequestView
                             ) -> Optional[int]:
        decoders = [v for v in cluster.instances()[self.n_prefill:]
                    if usable(v)]
        if not decoders:
            return None          # decode tier down: stay on the prefiller
        # least-loaded decoder, memory headroom as the tiebreaker
        target = min(decoders,
                     key=lambda v: (v.decode_load() - v.mem_free() * 1e-18,
                                    v.index)).index
        self._note("target", req.rid, target)
        return target

    def place_after_prefill(self, cluster: ClusterView, instance: int,
                            req: RequestView) -> List[Action]:
        dst = self.choose_decode_target(cluster, req)
        if dst is None or dst == instance:
            return []
        # whole-state KV transfer on the request's critical path
        return [StreamState(req.rid, src=instance, dst=dst)]
