"""Baseline scheduling kernels from the paper's evaluation (§5.2) and
related work (§2), expressed over the shared policy API so they run on
both the live-engine executor and the simulator.

  VLLMScheduler      — independent instances, continuous batching that
                       co-schedules prefill with decode (the TBT spike of
                       paper Fig. 5 / 16).
  SarathiScheduler   — chunked prefill: bounded prompt tokens per
                       iteration, trading TTFT for TBT.
  SplitwiseScheduler — static disaggregation: dedicated prefill
                       instances; post-prefill KV transfer to a decode
                       instance is on the critical path (Fig. 1 Case B).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.scheduling.actions import Action, StreamState
from repro.scheduling.base import (MAX_PREFILL_BATCH, ROLE_DECODE, ROLE_IDLE,
                                   ROLE_PREFILL, SchedulerPolicy)
from repro.scheduling.views import ClusterView, RequestView


class VLLMScheduler(SchedulerPolicy):
    name = "vllm"

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        insts = cluster.instances()
        ok = [v for v in insts if v.can_admit(req)]
        pool = ok or [v for v in insts if v.can_queue()] or list(insts)
        if not pool:
            return None
        # least loaded instance with memory headroom
        return min(pool, key=lambda v: (v.decode_load() + v.prefill_backlog(),
                                        v.index)).index


class SarathiScheduler(VLLMScheduler):
    name = "sarathi"

    def __init__(self, chunk_tokens: int = 512):
        self.chunk_tokens = chunk_tokens
        self._credit = {}    # instance -> unspent prompt-token budget

    def prefill_batch(self, cluster: ClusterView, instance: int,
                      pending: Sequence[RequestView]) -> int:
        """Admit whole prompts under a per-iteration chunk budget.  The
        simulator adapter models true intra-prompt chunking; on the
        iteration-clocked live executor this budget is the equivalent
        bound on prompt work per iteration: while the queue head is too
        long for the accumulated credit, credit keeps building — the
        iterations a real Sarathi would spend chunking through the
        prompt — so every prompt eventually starts."""
        inst = cluster.instances()[instance]
        credit = self._credit.get(instance, 0) + self.chunk_tokens
        n = 0
        blocked_on_credit = False
        for req in pending:
            if n >= MAX_PREFILL_BATCH or not inst.can_admit(req, taking=n):
                break
            if req.prompt_len > credit:
                blocked_on_credit = True
                break
            credit -= req.prompt_len
            n += 1
        # bank credit only while a prompt is actually waiting on it;
        # otherwise clamp so idle iterations don't accumulate budget
        self._credit[instance] = (credit if blocked_on_credit
                                  else min(credit, self.chunk_tokens))
        return n


class SplitwiseScheduler(SchedulerPolicy):
    name = "splitwise"

    def __init__(self, n_prefill: int = 1):
        self.n_prefill = n_prefill

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        prefillers = cluster.instances()[: self.n_prefill]
        return min(prefillers,
                   key=lambda v: (v.prefill_backlog_tokens(), v.index)).index

    def choose_roles(self, cluster: ClusterView, instance: int) -> str:
        inst = cluster.instances()[instance]
        if instance < self.n_prefill:
            return ROLE_PREFILL if inst.prefill_backlog() else ROLE_IDLE
        return ROLE_DECODE if inst.decode_load() else ROLE_IDLE

    def choose_decode_target(self, cluster: ClusterView, req: RequestView
                             ) -> int:
        decoders = cluster.instances()[self.n_prefill:]
        # least-loaded decoder, memory headroom as the tiebreaker
        return min(decoders,
                   key=lambda v: (v.decode_load() - v.mem_free() * 1e-18,
                                  v.index)).index

    def place_after_prefill(self, cluster: ClusterView, instance: int,
                            req: RequestView) -> List[Action]:
        dst = self.choose_decode_target(cluster, req)
        if dst == instance:
            return []
        # whole-state KV transfer on the request's critical path
        return [StreamState(req.rid, src=instance, dst=dst)]
