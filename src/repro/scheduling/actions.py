"""Declarative scheduling actions (the policy -> executor contract).

A ``SchedulerPolicy`` never mutates engines or simulator state directly: it
*describes* what should happen as a list of actions, and each backend's
executor (``repro.scheduling.live`` for real JAX engines, the adapters in
``repro.sim.policies`` for the discrete-event simulator) interprets them
with its own mechanics and cost model.  Instance references are the global
instance index, which is the same numbering on both backends
(``InstanceEngine.instance_id`` / ``SimInstance.iid``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Union


@dataclass(frozen=True)
class Prefill:
    """Run the prompt of ``rid`` on ``instance``."""
    rid: int
    instance: int


@dataclass(frozen=True)
class Decode:
    """Run one decode iteration over ``instance``'s resident batch."""
    instance: int


@dataclass(frozen=True)
class StreamState:
    """Move or copy a request's serving state between instances
    (AcceLLM §4.1.2 KV streaming; per-layer-overlapped on a real mesh).

    ``as_replica``      — the copy lands on ``dst`` as a *replica*; the
                          primary stays at ``src``.
    ``retain_replica``  — the primary moves to ``dst`` and ``src`` keeps
                          its copy as the replica.
    Neither flag set    — plain primary migration (Splitwise-style
                          post-prefill KV transfer); ``src`` releases.
    """
    rid: int
    src: int
    dst: int
    as_replica: bool = False
    retain_replica: bool = False


@dataclass(frozen=True)
class MirrorSync:
    """Mirror the newly generated KV line(s) of ``rid`` from its primary
    into its replica (AcceLLM §4.1.2)."""
    rid: int
    primary: int
    replica: int


@dataclass(frozen=True)
class PromoteReplica:
    """Zero-cost role flip (AcceLLM §4.1.3): the replica of ``rid`` on
    ``dst`` becomes the primary; the old primary on ``src`` becomes the
    replica."""
    rid: int
    src: int
    dst: int


@dataclass(frozen=True)
class EvictReplica:
    """Drop the replica of ``rid`` held on ``instance`` to free memory
    (graceful degradation, AcceLLM §4.2.5)."""
    rid: int
    instance: int


Action = Union[Prefill, Decode, StreamState, MirrorSync, PromoteReplica,
               EvictReplica]
