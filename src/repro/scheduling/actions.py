"""Declarative scheduling actions (the policy -> executor contract).

A ``SchedulerPolicy`` never mutates engines or simulator state directly: it
*describes* what should happen as a list of actions, and each backend's
executor (``repro.scheduling.live`` for real JAX engines, the adapters in
``repro.sim.policies`` for the discrete-event simulator) interprets them
with its own mechanics and cost model.  Instance references are the global
instance index, which is the same numbering on both backends
(``InstanceEngine.instance_id`` / ``SimInstance.iid``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Union


@dataclass(frozen=True)
class Prefill:
    """Run the prompt of ``rid`` on ``instance``.  Carries the prompt
    length (and, for executors, the request record itself) so the step
    planner can bucket and chunk the work without backend lookups."""
    rid: int
    instance: int
    prompt_len: int = 0
    #: backend request record (live ``Request`` / ``SimRequest``);
    #: excluded from action equality.
    req: object = field(default=None, compare=False, repr=False)


@dataclass(frozen=True)
class Decode:
    """Run one decode iteration over ``instance``'s resident batch."""
    instance: int


@dataclass(frozen=True)
class StreamState:
    """Move or copy a request's serving state between instances
    (AcceLLM §4.1.2 KV streaming).  Executors move it as *per-layer
    chunks* (``PagedStore.stream_slot``), the granularity a real mesh
    overlaps with prefill compute — only the last layer's worth is
    exposed latency (§4.2.4).

    ``as_replica``      — the copy lands on ``dst`` as a *replica*; the
                          primary stays at ``src``.
    ``retain_replica``  — the primary moves to ``dst`` and ``src`` keeps
                          its copy as the replica.
    Neither flag set    — plain primary migration (Splitwise-style
                          post-prefill KV transfer); ``src`` releases.
    """
    rid: int
    src: int
    dst: int
    as_replica: bool = False
    retain_replica: bool = False
    #: head lines already resident in ``dst``'s prefix cache: the stream
    #: (and its pricing) covers only the unique suffix — a shared-prefix
    #: replica costs almost no extra transfer or HBM
    skip_lines: int = 0


@dataclass(frozen=True)
class MirrorSync:
    """Mirror KV lines ``[from_line, to_line)`` of ``rid`` from its
    primary into its replica (AcceLLM §4.1.2: "newly computed KV cache
    lines are transferred back").  Delta semantics: executors copy ONLY
    those lines (plus the constant-size recurrent state) — one line per
    decode step in steady state, O(1) in sequence length, not
    O(kv_capacity).  ``None`` bounds mean "from the replica's synced
    mark" / "to the primary's current lines", resolved against the
    executor's ledger."""
    rid: int
    primary: int
    replica: int
    from_line: Optional[int] = None
    to_line: Optional[int] = None


@dataclass(frozen=True)
class PromoteReplica:
    """Zero-cost role flip (AcceLLM §4.1.3): the replica of ``rid`` on
    ``dst`` becomes the primary; the old primary on ``src`` becomes the
    replica.  ``hedge`` marks a straggler hedge — the flip was taken
    because ``src``'s health EWMA crossed the hedging threshold, not for
    load balance; executors count these separately."""
    rid: int
    src: int
    dst: int
    hedge: bool = False


@dataclass(frozen=True)
class EvictReplica:
    """Drop the replica of ``rid`` held on ``instance``, returning its
    blocks to the instance's pool (graceful degradation, AcceLLM
    §4.2.5)."""
    rid: int
    instance: int


@dataclass(frozen=True)
class AbortRequest:
    """Cancel ``rid`` wherever it is in its lifecycle — queued, mid
    prefill chunk, or decoding.  Executors tear down *all* of its
    serving state: ledger ``free`` of its blocks, prefix-cache unpin,
    replica drop on the mirror, and planner cursor cleanup.  The request
    record survives with ``Phase.ABORTED`` so metrics count it."""
    rid: int


Action = Union[Prefill, Decode, StreamState, MirrorSync, PromoteReplica,
               EvictReplica, AbortRequest]
