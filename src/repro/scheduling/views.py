"""Backend-agnostic read-only views of cluster state.

These Protocols are the *only* state a ``SchedulerPolicy`` may consult, so
the same decision kernel runs over live JAX engines and over the
discrete-event simulator.  Both backends answer from the same ledger
arithmetic (``repro.kvstore``: the live engine's ``PagedStore``, the
simulator's ``SimStore``, both priced by ``LineCosts`` over
``repro.core.kvbytes``): ``mem_free``/``decode_weights`` are state
**bytes**, ``free_blocks`` is block-pool headroom, and
``request_lines``/``replica_synced`` expose the per-request line clocks a
delta ``MirrorSync`` is bounded by — so rankings and deltas agree whenever
both backends describe the same requests at the same lengths.
"""
from __future__ import annotations

from typing import Mapping, Optional, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class RequestView(Protocol):
    """What a policy may know about a request (live ``Request`` and
    ``SimRequest`` both satisfy this structurally)."""
    rid: int
    prompt_len: int

    @property
    def total_len(self) -> int: ...


@runtime_checkable
class InstanceView(Protocol):
    """One serving instance, as the policy sees it."""

    @property
    def index(self) -> int:
        """Global instance index (engine ``instance_id`` / sim ``iid``)."""
        ...

    # -- fleet state --------------------------------------------------------
    def alive(self) -> bool:
        """Whether this instance is serving at all.  Dead instances stay
        in the view sequence (indices are stable across fleet events);
        every kernel decision must skip them."""
        ...

    def draining(self) -> bool:
        """Instance is alive but cordoned: it finishes resident work and
        accepts no new routing, placement or promotion (graceful
        scale-down; see repro.fleet)."""
        ...

    def health(self) -> float:
        """Observed slowdown of this instance: an EWMA of its
        per-iteration step latency, normalized so 1.0 is nominal speed
        and ``k`` means steps are running ~``k``x slow.  Both backends
        update it with the same arithmetic
        (``health += HEALTH_ALPHA * (slowdown - health)``, once per
        scheduling iteration while alive), so golden traces that branch
        on health agree.  Kernels that hedge stragglers read this; the
        health-blind baselines never call it."""
        ...

    # -- capacity -----------------------------------------------------------
    def free_slots(self) -> int:
        """Free request slots (live) or residual batch slack (sim)."""
        ...

    def mem_free(self) -> float:
        """Free serving-state bytes under this backend's accounting."""
        ...

    def free_blocks(self) -> int:
        """Free KV blocks in this instance's pool — the block-granular
        headroom for admission, replica budgeting and eviction.  Both
        backends answer with the same ``repro.kvstore.BlockLedger``
        arithmetic, but pool *size* follows each backend's capacity
        model (live: slots x cache window; sim: modeled HBM), so
        policies should compare headroom within a backend, not across
        them."""
        ...

    def block_lines(self) -> int:
        """KV lines per pool block on this instance — the gather/DMA
        granularity of the paged decode path; the cost model rounds a
        request's resident lines up to it."""
        ...

    def spec(self):
        """Hardware identity of this instance
        (``repro.sim.devices.InstanceSpec`` or None when undeclared).
        Pods may be heterogeneous — H100-class and 910B2-class slices in
        one cluster — so policies that weigh transfer or decode cost
        against hardware should read per-instance ``intra_link_gbps`` /
        ``inter_link_gbps`` / ``n_devices`` here rather than assume one
        device model."""
        ...

    def decode_remaining(self) -> Mapping[int, int]:
        """Remaining token budget per resident decode request — the
        planner's fused-span cap (a fused block never runs past the
        iteration its first request completes)."""
        ...

    def primary_bytes(self) -> float:
        """Ledger bytes of resident decode primaries."""
        ...

    def replica_bytes(self) -> float:
        """Ledger bytes of resident replicas (real memory — counted, not
        ignored, under pressure accounting)."""
        ...

    def can_admit(self, req: RequestView, taking: int = 0) -> bool:
        """Can this instance accept a new prefill, with ``taking`` requests
        already earmarked this iteration?"""
        ...

    def can_hold_primary(self, req: RequestView, resident: bool = False
                         ) -> bool:
        """Can it host ``req`` as a decode primary?  ``resident`` means the
        state is already materialized here (no new capacity needed)."""
        ...

    def can_hold_replica(self, req: RequestView, resident: bool = False
                         ) -> bool:
        """Can it hold a redundant copy of ``req``'s state?"""
        ...

    def can_queue(self) -> bool:
        """Whether admission may overflow into a backlog on this instance
        (the simulator queues; live engines must have a slot)."""
        ...

    # -- load ---------------------------------------------------------------
    def decode_load(self) -> int:
        """Number of resident decode primaries."""
        ...

    def prefill_backlog(self) -> int:
        """Requests routed here but not yet prefilled."""
        ...

    def prefill_backlog_tokens(self) -> int:
        """Total prompt tokens awaiting prefill here."""
        ...

    def decode_weights(self) -> Mapping[int, float]:
        """rid -> state bytes read per decode step (balancer weight)."""
        ...

    def replica_weights(self) -> Mapping[int, float]:
        """rid -> bytes freed if this instance's replica of rid is
        evicted."""
        ...

    # -- mirror ledger --------------------------------------------------------
    def request_lines(self) -> Mapping[int, int]:
        """rid -> KV lines materialized here for resident decode
        primaries (the ``to_line`` of a delta MirrorSync)."""
        ...

    def replica_synced(self) -> Mapping[int, int]:
        """rid -> line up to which this instance's replica of rid has
        been mirrored (the ``from_line`` of a delta MirrorSync)."""
        ...

    # -- prefix cache ---------------------------------------------------------
    def shared_blocks(self) -> int:
        """Distinct pool blocks referenced by more than one holder
        (tables and/or the prefix cache) on this instance — each one is
        HBM the refcounted sharing avoided duplicating."""
        ...

    def prefix_hit_tokens(self, req: RequestView) -> int:
        """Block-aligned prompt-head tokens of ``req`` resident in this
        instance's prefix cache right now (0 without a cache).  A pure
        peek: no LRU touch, no pin — policies use it to pick placements
        (e.g. a replica destination whose cache already holds the
        prefix) before the executor stamps the real hit."""
        ...


#: EWMA smoothing for :meth:`InstanceView.health` — shared by both
#: backends so the health signal (and every decision gated on it) is
#: bit-identical live vs sim.  One degraded iteration at the default
#: ``DegradeInstance.factor`` of 4.0 moves health from 1.0 to 2.5;
#: recovery decays it back under the hedge threshold within two.
HEALTH_ALPHA = 0.5


def step_health(health: float, slowdown: float) -> float:
    """One EWMA update of an instance's health toward its current
    slowdown factor — THE health arithmetic, called by both executors."""
    return health + HEALTH_ALPHA * (slowdown - health)


def usable(view: InstanceView) -> bool:
    """May new work land on this instance?  The single aliveness gate
    every kernel routes/places/promotes through: alive and not
    draining."""
    return view.alive() and not view.draining()


@runtime_checkable
class ClusterView(Protocol):
    """The whole cluster, as the policy sees it."""

    def instances(self) -> Sequence[InstanceView]: ...

    def pairs(self) -> Sequence[Tuple[InstanceView, InstanceView]]:
        """AcceLLM pair structure: (instances[2k], instances[2k+1])."""
        ...

    def placements(self) -> Mapping[int, Tuple[int, Optional[int]]]:
        """rid -> (primary instance index, replica instance index or
        None), for every request currently resident."""
        ...
