"""Universal Load Balancing (ULB) scheduling kernel.

The Universal Load Balancing principle (PAPERS.md): route every new
request to the server with the least *outstanding work*, where work is
measured in the system's actual service units — not the queue length or
the resident count, both of which mispredict completion time when
requests are heterogeneous.  For LLM serving the natural unit is the
token: an instance's outstanding work is

    prefill_backlog_tokens  +  Σ decode_remaining

i.e. every prompt token still to prefill plus every token its resident
decodes have yet to generate.  This prices a queue of short prompts
below one long prompt and a batch of nearly-finished decodes below a
batch of fresh ones — exactly the distinctions ``decode_load() +
prefill_backlog()`` (vLLM-style least-connections) cannot make.

The kernel is deliberately minimal: no pairs, no redundancy, no KV
movement — the same execution mechanics as vLLM continuous batching,
differing *only* in the routing score, so the AcceLLM-vs-ULB shootout
(benchmarks/bench_scale.py) isolates the value of the routing signal
itself.  ``decode_remaining`` uses declared ``max_new_tokens`` as the
work estimate; real deployments would substitute a length predictor —
the principle is the same with any unbiased estimate.
"""
from __future__ import annotations

from typing import Optional

from repro.scheduling.base import SchedulerPolicy
from repro.scheduling.views import ClusterView, InstanceView, RequestView, \
    usable


def outstanding_tokens(view: InstanceView) -> int:
    """The ULB work score: prompt tokens still to prefill + decode
    tokens still to generate on ``view``."""
    return (view.prefill_backlog_tokens()
            + sum(view.decode_remaining().values()))


class ULBScheduler(SchedulerPolicy):
    name = "ulb"

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        insts = [v for v in cluster.instances() if usable(v)]
        ok = [v for v in insts if v.can_admit(req)]
        pool = ok or [v for v in insts if v.can_queue()] or insts
        if not pool:
            return None
        # least outstanding work in tokens; index breaks ties for
        # determinism across backends
        target = min(pool, key=lambda v: (outstanding_tokens(v),
                                          v.index)).index
        self._note("route", req.rid, target)
        return target
