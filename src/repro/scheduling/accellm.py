"""The AcceLLM scheduling kernel (paper §4.1–§4.2) — one implementation
shared by the live-engine executor and the simulator adapter.

Decisions made here, and only here:

  * routing (§4.2.2): new requests go to the pair with the most free
    memory; inside the pair, the less decode-loaded side prefills,
  * dynamic roles (§4.2.3): prefill and decode are never co-scheduled on
    one instance in one iteration,
  * placement (§4.1.2): after prefill the state streams to the partner
    (which becomes the primary decoder) while the prefilling side retains
    its copy as the replica — unless the partner is already markedly more
    loaded, in which case the roles invert,
  * mirroring (§4.1.2): newly generated KV lines sync into replicas,
  * balancing (§4.1.3): decode batches re-split by count + state bytes via
    zero-cost replica promotion,
  * eviction (§4.2.5): under memory pressure the replica freeing the most
    bytes (the longest request's) is dropped first.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.balancer import Item, partition, should_rebalance
from repro.scheduling.actions import (Action, EvictReplica, MirrorSync,
                                      PromoteReplica, StreamState)
from repro.scheduling.base import (ROLE_DECODE, ROLE_IDLE, ROLE_PREFILL,
                                   SchedulerPolicy)
from repro.scheduling.views import (ClusterView, InstanceView, RequestView,
                                    usable)

PairView = Tuple[InstanceView, InstanceView]


class AcceLLMScheduler(SchedulerPolicy):
    name = "accellm"
    requires_pairs = True
    requeue_unplaced = True
    #: §4.2.3: prefill and decode are never co-scheduled on one
    #: instance — the step planner raises on any mixed plan.
    allow_mixed = False

    def __init__(self, redundancy: bool = True, swap_margin: int = 1,
                 hedging: bool = True, hedge_threshold: float = 1.5):
        self.redundancy = redundancy
        #: the partner only loses the primary role when it is more than
        #: ``swap_margin`` requests ahead of the prefilling side
        self.swap_margin = swap_margin
        #: straggler hedging: when one pair side's health EWMA crosses
        #: ``hedge_threshold`` (1.0 = nominal speed) and the other side
        #: holds synced mirrors, decode routes to the mirrors via
        #: zero-cost role flips — the paper's redundancy cashed in as a
        #: tail-latency hedge.  Requires ``redundancy``.
        self.hedging = hedging
        self.hedge_threshold = hedge_threshold
        # decision log: inherited ``trace``/``_note`` (SchedulerPolicy)

    # -- routing (§4.2.2) ---------------------------------------------------
    def admissions_per_step(self, cluster: ClusterView) -> int:
        return 1

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        eligible = [p for p in cluster.pairs() if self._pair_can_accept(p, req)]
        if not eligible:
            return None
        pair = max(eligible, key=self._pair_score)
        side = self.choose_prefill_side(pair, req)
        if side is None:
            return None
        target = pair[side].index
        self._note("route", req.rid, target)
        return target

    def _pair_score(self, pair: PairView) -> float:
        """Pair attractiveness for new admissions: free memory, scaled
        down by the pair's worst health EWMA when hedging is on.  At
        nominal health the division is by exactly 1.0, so the ranking
        (and every golden trace without degradations) is unchanged; a
        pair nursing a straggler stops soaking up new work just because
        hedging freed its memory."""
        free = sum(v.mem_free() for v in pair if usable(v))
        if not self.hedging:
            return float(free)
        return free / max(self._health(pair[0]), self._health(pair[1]))

    def _prefill_cost(self, view: InstanceView) -> float:
        """Prefill-side preference: decode load, stretched by health
        when hedging is on — a straggler only wins the prefill role if
        preempting the healthy side's decode would cost more than
        running the prompt ``health``x slow.  ``(load+1) * 1.0`` is
        monotone in load, so nominal-health decisions are identical."""
        load = view.decode_load()
        if not self.hedging:
            return float(load)
        return (load + 1) * self._health(view)

    def _pair_can_accept(self, pair: PairView, req: RequestView) -> bool:
        sides = [v for v in pair if usable(v)]
        if not sides:
            return False
        if any(v.can_admit(req) for v in sides):
            return True
        # memory pressure: a replica can be evicted to make room (§4.2.5)
        if any(v.replica_weights() for v in sides):
            return True
        return any(v.can_queue() for v in sides)

    # -- dynamic roles (§4.2.3) ---------------------------------------------
    def choose_prefill_side(self, pair: PairView, req: RequestView
                            ) -> Optional[int]:
        live_sides = [s for s in (0, 1) if usable(pair[s])]
        if not live_sides:
            return None
        open_sides = [s for s in live_sides if pair[s].can_admit(req)]
        if not open_sides:
            victims = self._eviction_victims(
                [pair[s] for s in live_sides], need=1)
            if victims:
                open_sides = [s for s in live_sides
                              if pair[s].index == victims[0].instance]
            elif any(pair[s].can_queue() for s in live_sides):
                open_sides = [s for s in live_sides if pair[s].can_queue()]
            else:
                return None
        return min(open_sides, key=lambda s: (self._prefill_cost(pair[s]), s))

    def choose_roles(self, cluster: ClusterView, instance: int) -> str:
        inst = cluster.instances()[instance]
        if inst.prefill_backlog():
            return ROLE_PREFILL          # never co-scheduled with decode
        return ROLE_DECODE if inst.decode_load() else ROLE_IDLE

    def prefill_batch(self, cluster: ClusterView, instance: int,
                      pending: Sequence[RequestView]) -> int:
        inst = cluster.instances()[instance]
        if pending and not inst.can_admit(pending[0]) \
                and inst.replica_weights():
            # memory pressure (§4.2.5): admit one request anyway — the
            # executor frees its slot by evicting this instance's most
            # expensive replica first
            return 1
        return super().prefill_batch(cluster, instance, pending)

    # -- placement (§4.1.2) -------------------------------------------------
    def place_after_prefill(self, cluster: ClusterView, instance: int,
                            req: RequestView) -> List[Action]:
        pair = next(p for p in cluster.pairs()
                    if instance in (p[0].index, p[1].index))
        side = 0 if pair[0].index == instance else 1

        def load(s: int) -> int:
            # exclude the request being placed (backends differ on whether
            # it is already counted as resident at this point)
            v = pair[s]
            return v.decode_load() - (1 if req.rid in v.decode_weights()
                                      else 0)

        dst, rep = 1 - side, side
        if not usable(pair[dst]):
            # partner down/draining: the request stays where it
            # prefilled and serves unmirrored until the fleet recovers
            dst, rep = side, 1 - side
        elif load(dst) > load(rep) + self.swap_margin:
            dst, rep = side, 1 - side
        if dst != side and not pair[dst].can_hold_primary(req):
            dst, rep = side, 1 - side

        replica: Optional[int] = None
        if self.redundancy and usable(pair[rep]) \
                and pair[rep].can_hold_replica(req, resident=(rep == side)):
            replica = pair[rep].index

        def _hit(view) -> int:
            # lines the destination's prefix cache already holds never
            # cross the wire: the stream (and its pricing on both
            # backends) covers only the unique suffix.  getattr: bare
            # test doubles predate the prefix-cache view fields.
            peek = getattr(view, "prefix_hit_tokens", None)
            return peek(req) if peek is not None else 0

        actions: List[Action] = []
        if dst != side:
            actions.append(StreamState(req.rid, src=pair[side].index,
                                       dst=pair[dst].index,
                                       retain_replica=replica is not None,
                                       skip_lines=_hit(pair[dst])))
        elif replica is not None:
            actions.append(StreamState(req.rid, src=pair[side].index,
                                       dst=replica, as_replica=True,
                                       skip_lines=_hit(pair[rep])))
        self._note("place", req.rid, pair[dst].index, replica)
        return actions

    # -- mirroring (§4.1.2) -------------------------------------------------
    def sync(self, cluster: ClusterView) -> List[Action]:
        """Delta mirror maintenance: for every (primary, replica) pair,
        emit a MirrorSync bounded to exactly the lines the replica is
        missing — the ledger's ``replica_synced`` mark up to the
        primary's ``request_lines``.  Replicas that are already current
        produce no action (and no traffic)."""
        if not self.redundancy:
            return []
        insts = cluster.instances()
        lines_of: dict = {}      # instance -> request_lines(), built once
        synced_of: dict = {}
        actions: List[Action] = []
        for rid, (primary, replica) in sorted(cluster.placements().items()):
            if replica is None:
                continue
            if primary not in lines_of:
                lines_of[primary] = insts[primary].request_lines()
            lines = lines_of[primary].get(rid)
            if lines is None:       # primary not decoding (e.g. finished)
                continue
            if replica not in synced_of:
                synced_of[replica] = insts[replica].replica_synced()
            synced = synced_of[replica].get(rid, 0)
            if synced >= lines:
                continue
            actions.append(MirrorSync(rid, primary, replica,
                                      from_line=synced, to_line=lines))
        return actions

    # -- fleet: warm scale-up (repro.fleet) ---------------------------------
    def warm_on_join(self, cluster: ClusterView, instance: int
                     ) -> List[Action]:
        """A joined instance warms up by hosting replicas of its
        partner's unmirrored primaries (StreamState as_replica) before
        any new arrival routes to it — redundancy is re-established
        first, then the rebalancer can shift load via promotion."""
        if not self.redundancy:
            return []
        pair = next((p for p in cluster.pairs()
                     if instance in (p[0].index, p[1].index)), None)
        if pair is None:
            return []            # unpaired appendee: nothing to warm from
        joined = pair[0] if pair[0].index == instance else pair[1]
        partner = pair[1] if pair[0].index == instance else pair[0]
        if not usable(partner):
            return []
        placements = cluster.placements()
        budget = joined.free_slots()
        actions: List[Action] = []
        for rid in sorted(partner.decode_weights()):
            if budget <= 0:
                break
            if placements.get(rid, (None, None))[1] is not None:
                continue         # already mirrored somewhere
            actions.append(StreamState(rid, src=partner.index,
                                       dst=instance, as_replica=True))
            self._note("warm", rid, partner.index, instance)
            budget -= 1
        return actions

    # -- straggler hedging (redundancy as a tail hedge) ----------------------
    @staticmethod
    def _health(view: InstanceView) -> float:
        # getattr: bare test doubles predate the health view method
        h = getattr(view, "health", None)
        return h() if h is not None else 1.0

    def _maybe_hedge(self, cluster: ClusterView, pair: PairView
                     ) -> Optional[List[Action]]:
        """Health-gated pair balancing.  Returns None when both sides
        are nominal (the regular count+bytes rebalance applies); with a
        straggler in the pair it returns the hedge actions — every
        primary on the sick side whose mirror lives on the healthy side
        flips roles there (catch-up delta first if the mirror lags) —
        and the regular rebalance is suppressed so load balancing never
        migrates work back onto the straggler."""
        if not (self.hedging and self.redundancy):
            return None
        h0, h1 = self._health(pair[0]), self._health(pair[1])
        if max(h0, h1) < self.hedge_threshold:
            return None
        if min(h0, h1) >= self.hedge_threshold:
            return []            # both degraded: no healthy side to hedge to
        sick = 0 if h0 > h1 else 1
        well = 1 - sick
        placements = cluster.placements()
        synced = pair[well].replica_synced()
        lines = pair[sick].request_lines()
        actions: List[Action] = []
        hedged = []
        for rid in sorted(pair[sick].decode_weights()):
            if placements.get(rid, (None, None))[1] != pair[well].index:
                continue         # no mirror on the healthy side: must stall
            s = synced.get(rid, 0)
            ln = lines.get(rid, s)
            if s < ln:
                actions.append(MirrorSync(rid, pair[sick].index,
                                          pair[well].index,
                                          from_line=s, to_line=ln))
            actions.append(PromoteReplica(rid, src=pair[sick].index,
                                          dst=pair[well].index, hedge=True))
            hedged.append((rid, pair[sick].index, pair[well].index))
        if hedged:
            self._note("hedge", tuple(hedged))
        return actions

    # -- balancing by count + state bytes (§4.1.3) --------------------------
    def rebalance(self, cluster: ClusterView, pair_index: int
                  ) -> List[Action]:
        pair = cluster.pairs()[pair_index]
        if not (usable(pair[0]) and usable(pair[1])):
            # promotion shifts work between the sides; with one side
            # dead or cordoned there is nothing to balance against
            return []
        hedge = self._maybe_hedge(cluster, pair)
        if hedge is not None:
            return hedge
        placements = cluster.placements()
        items = []
        for side, view in enumerate(pair):
            partner_idx = pair[1 - side].index
            for rid, weight in sorted(view.decode_weights().items()):
                replica = placements.get(rid, (None, None))[1]
                items.append(Item(rid=rid, weight=weight, home=side,
                                  movable=replica == partner_idx))
        if not should_rebalance(items):
            return []
        _, _, moves = partition(items)
        actions: List[Action] = []
        promoted = []
        for rid, src, dst in sorted(moves):
            # a replica may only take the primary role at the primary's
            # line count: if its synced mark lags (a sync was skipped or
            # raced a fleet event), emit the catch-up delta FIRST —
            # serving from a stale copy would corrupt the request
            synced = pair[dst].replica_synced().get(rid, 0)
            lines = pair[src].request_lines().get(rid, synced)
            if synced < lines:
                actions.append(MirrorSync(rid, pair[src].index,
                                          pair[dst].index,
                                          from_line=synced, to_line=lines))
            actions.append(PromoteReplica(rid, src=pair[src].index,
                                          dst=pair[dst].index))
            promoted.append((rid, pair[src].index, pair[dst].index))
        if promoted:
            self._note("rebalance", tuple(promoted))
        return actions

    # -- graceful degradation (§4.2.5) --------------------------------------
    def evict(self, cluster: ClusterView,
              instances: Sequence[InstanceView], need: int = 1
              ) -> List[Action]:
        return self._eviction_victims(instances, need)

    def _eviction_victims(self, instances: Sequence[InstanceView],
                          need: int = 1) -> List[EvictReplica]:
        candidates = [(weight, rid, view.index)
                      for view in instances
                      for rid, weight in view.replica_weights().items()]
        # most bytes freed first (the longest request's replica); ties
        # break toward the lowest rid for determinism across backends
        candidates.sort(key=lambda c: (-c[0], c[1]))
        victims = [EvictReplica(rid=rid, instance=idx)
                   for _, rid, idx in candidates[:need]]
        for v in victims:
            self._note("evict", v.rid, v.instance)
        return victims
