"""Policy registry: name -> SchedulerPolicy factory.

``repro.api.serve`` and the launchers resolve ``--policy accellm|vllm|
splitwise|sarathi`` here; registering a new policy makes it available to
both the live cluster and the simulator front-ends.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, List

from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.base import SchedulerPolicy
from repro.scheduling.baselines import (SarathiScheduler, SplitwiseScheduler,
                                        VLLMScheduler)

_REGISTRY: Dict[str, Callable[..., SchedulerPolicy]] = {}


def register_policy(name: str, factory: Callable[..., SchedulerPolicy]):
    if name in _REGISTRY:
        raise ValueError(f"policy {name!r} already registered")
    _REGISTRY[name] = factory


def get_policy(name: str, **kwargs) -> SchedulerPolicy:
    return policy_factory(name)(**kwargs)


def policy_factory(name: str) -> Callable[..., SchedulerPolicy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; "
                       f"known: {', '.join(policy_names())}") from None


def policy_accepts(name: str, param: str) -> bool:
    """Whether the policy's factory takes a keyword named ``param``
    (used to forward optional spec fields like ``redundancy`` without
    special-casing policy names)."""
    try:
        sig = inspect.signature(policy_factory(name))
    except (TypeError, ValueError):
        return False
    p = sig.parameters.get(param)
    return (p is not None and p.kind in (p.POSITIONAL_OR_KEYWORD,
                                         p.KEYWORD_ONLY)) \
        or any(q.kind is q.VAR_KEYWORD for q in sig.parameters.values())


def policy_names() -> List[str]:
    return sorted(_REGISTRY)


register_policy("accellm", AcceLLMScheduler)
register_policy("vllm", VLLMScheduler)
register_policy("splitwise", SplitwiseScheduler)
register_policy("sarathi", SarathiScheduler)

# The ULB kernel and the vectorized variants (repro.scale) are imported
# at the bottom so the base names above are registered even while those
# modules are mid-import (scale.kernels itself imports this package).
from repro.scheduling.ulb import ULBScheduler  # noqa: E402
from repro.scale.kernels import (  # noqa: E402
    VectorAcceLLMScheduler, VectorSplitwiseScheduler, VectorULBScheduler,
    VectorVLLMScheduler)

register_policy("ulb", ULBScheduler)
register_policy("accellm-vec", VectorAcceLLMScheduler)
register_policy("vllm-vec", VectorVLLMScheduler)
register_policy("splitwise-vec", VectorSplitwiseScheduler)
register_policy("ulb-vec", VectorULBScheduler)
