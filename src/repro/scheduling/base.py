"""The backend-agnostic scheduling policy interface.

A ``SchedulerPolicy`` is the single home of a serving system's *decisions*
— routing, role selection, post-prefill placement, rebalancing, eviction —
expressed over :mod:`repro.scheduling.views` and emitted as declarative
:mod:`repro.scheduling.actions`.  Executors supply the mechanics:

  * ``repro.scheduling.live.LiveCluster`` drives real ``InstanceEngine``s
    on the iteration clock,
  * the adapters in ``repro.sim.policies`` drive the discrete-event
    simulator with its analytic cost model.

Adding a new policy = subclassing this in one file; it then runs on both
backends and is selectable by name through ``repro.scheduling.registry``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.scheduling.actions import Action
from repro.scheduling.views import ClusterView, InstanceView, RequestView

#: Shared admission cap: max prompts batched into one prefill iteration.
MAX_PREFILL_BATCH = 4

# Roles an instance can take for one scheduling iteration.
ROLE_PREFILL = "prefill"   # exclusive prefill (never co-batched with decode)
ROLE_DECODE = "decode"
ROLE_MIXED = "mixed"       # vLLM-style prefill+decode co-batching
ROLE_IDLE = "idle"


class SchedulerPolicy:
    name = "base"
    #: Policy requires the AcceLLM pair structure (even instance count).
    requires_pairs = False
    #: Live executor: return unplaced requests to the global queue each
    #: iteration (policies that re-route every step) instead of leaving
    #: them in the per-instance backlog.
    requeue_unplaced = False
    #: May prefill and decode be co-scheduled on one instance in one
    #: iteration?  The step planner (repro.stepplan) enforces this: with
    #: ``False`` it raises instead of building a MixedPlan — the home of
    #: the AcceLLM §4.2.3 invariant.
    allow_mixed = True
    #: Per-iteration prompt-token budget for chunked prefill
    #: (Sarathi-style); ``None`` disables chunking.  Consumed by the
    #: step planner, which keeps the resumable chunk cursors.
    chunk_tokens: Optional[int] = None
    #: optional decision log (golden-trace consistency tests; the
    #: vectorized-kernel equivalence proof in tests/test_scale.py).
    #: Assign a list to start recording.
    trace: Optional[list] = None

    def _note(self, *entry):
        if self.trace is not None:
            self.trace.append(entry)

    # -- routing ------------------------------------------------------------
    def admissions_per_step(self, cluster: ClusterView) -> int:
        """How many queued requests the live executor may route per
        iteration."""
        return len(cluster.instances())

    def route(self, cluster: ClusterView, req: RequestView) -> Optional[int]:
        """Target instance index for a new request, or None to keep it
        queued."""
        raise NotImplementedError

    # -- roles --------------------------------------------------------------
    def choose_roles(self, cluster: ClusterView, instance: int) -> str:
        """Role of ``instance`` for this iteration."""
        inst = cluster.instances()[instance]
        if inst.prefill_backlog():
            return ROLE_MIXED
        return ROLE_DECODE if inst.decode_load() else ROLE_IDLE

    def prefill_batch(self, cluster: ClusterView, instance: int,
                      pending: Sequence[RequestView]) -> int:
        """How many of ``pending`` (FIFO) to prefill this iteration."""
        inst = cluster.instances()[instance]
        n = 0
        for req in pending:
            if n >= MAX_PREFILL_BATCH or not inst.can_admit(req, taking=n):
                break
            n += 1
        return n

    # -- placement / redundancy --------------------------------------------
    def place_after_prefill(self, cluster: ClusterView, instance: int,
                            req: RequestView) -> List[Action]:
        """Where the freshly prefilled ``req`` should live (StreamState
        actions); empty means it stays on the prefilling instance."""
        return []

    def sync(self, cluster: ClusterView) -> List[Action]:
        """Per-iteration replica maintenance (MirrorSync actions)."""
        return []

    # -- fleet events -------------------------------------------------------
    def warm_on_join(self, cluster: ClusterView, instance: int
                     ) -> List[Action]:
        """Warm a freshly joined ``instance`` before new arrivals route
        to it (StreamState actions — e.g. re-establishing replicas of
        resident requests).  Baselines have nothing to warm with."""
        return []

    # -- balancing / memory pressure ---------------------------------------
    def rebalance(self, cluster: ClusterView, pair_index: int
                  ) -> List[Action]:
        """Re-split a pair's decode work (PromoteReplica actions,
        preceded by catch-up MirrorSyncs for any lagging replica)."""
        return []

    def evict(self, cluster: ClusterView,
              instances: Sequence[InstanceView], need: int = 1
              ) -> List[Action]:
        """Free memory on ``instances`` (EvictReplica actions)."""
        return []
