"""Backend-agnostic AcceLLM scheduling: one policy kernel, two executors.

  views      — ClusterView / InstanceView protocols (state the policy sees)
  actions    — declarative actions the policy emits
  base       — the SchedulerPolicy interface
  accellm    — the paper's policy kernel (§4.1–§4.2)
  baselines  — vLLM / Sarathi / Splitwise kernels
  registry   — name -> policy factory for CLIs and repro.api
  live       — executor over real InstanceEngines

The simulator-side executor lives in ``repro.sim.policies`` (adapters that
map the same kernels onto the discrete-event cost model).
"""
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.actions import (Action, Decode, EvictReplica,
                                      MirrorSync, Prefill, PromoteReplica,
                                      StreamState)
from repro.scheduling.base import MAX_PREFILL_BATCH, SchedulerPolicy
from repro.scheduling.baselines import (SarathiScheduler, SplitwiseScheduler,
                                        VLLMScheduler)
from repro.scheduling.live import LiveCluster, Placement
from repro.scheduling.registry import get_policy, policy_names, register_policy
from repro.scheduling.views import ClusterView, InstanceView, RequestView

__all__ = [
    "Action", "Prefill", "Decode", "StreamState", "MirrorSync",
    "PromoteReplica", "EvictReplica",
    "ClusterView", "InstanceView", "RequestView",
    "SchedulerPolicy", "MAX_PREFILL_BATCH",
    "AcceLLMScheduler", "VLLMScheduler", "SplitwiseScheduler",
    "SarathiScheduler",
    "LiveCluster", "Placement",
    "get_policy", "policy_names", "register_policy",
]
