"""Live-engine executor: drives real ``InstanceEngine``s under any
``SchedulerPolicy``.

This replaces the scheduling logic that used to be hardwired into the
retired ``AcceLLMCluster`` facade: the executor owns the mechanics
(engines, slots, the iteration clock, placement bookkeeping) and asks the
policy kernel for every decision, applying the declarative actions it
returns.  The same kernel object drives the discrete-event simulator via
the adapters in ``repro.sim.policies``.

The clock is the scheduling iteration (one decode step per active
instance per iteration); latency metrics are reported in iterations.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.scheduling.actions import (AbortRequest, Action, Decode,
                                      EvictReplica, MirrorSync, Prefill,
                                      PromoteReplica, StreamState)
from repro.scheduling.base import (ROLE_IDLE, ROLE_MIXED, ROLE_PREFILL,
                                   SchedulerPolicy)
from repro.scheduling.views import step_health
from repro.serving.engine import InstanceEngine
from repro.serving.request import Phase, Request
from repro.stepplan import (Planner, PrefillPlan, decode_part,
                            prefill_part)
from repro.workloads import IterationClock, TimelinePoint
from repro.workloads.spec import RequestSource

if TYPE_CHECKING:                 # runtime import stays lazy: repro.fleet
    from repro.fleet import FleetController  # imports this module's package


@dataclass
class Placement:
    """Where a request's state lives: (instance index, slot)."""
    primary: Tuple[int, int]
    replica: Optional[Tuple[int, int]] = None


class LiveInstanceView:
    """InstanceView over one live engine (see repro.scheduling.views)."""

    def __init__(self, cluster: "LiveCluster", index: int):
        self._c = cluster
        self._eng = cluster.engines[index]
        self._index = index

    @property
    def index(self) -> int:
        return self._index

    # -- fleet state ---------------------------------------------------------
    def alive(self) -> bool:
        return self._c.alive[self._index]

    def draining(self) -> bool:
        return self._c.draining[self._index]

    def health(self) -> float:
        return self._c.health[self._index]

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._eng.free_slots())

    def mem_free(self) -> float:
        # single source of truth: the engine's PagedStore ledger (which
        # counts primaries AND replicas, line-exact)
        return self._eng.store.free_bytes()

    def free_blocks(self) -> int:
        return self._eng.store.free_blocks()

    def block_lines(self) -> int:
        return self._eng.store.block_lines

    def spec(self):
        # hardware identity of this instance's mesh slice (None when
        # the cluster runs unplaced / the instance joined past the pod)
        specs = self._c.instance_specs
        return specs[self._index] if self._index < len(specs) else None

    def primary_bytes(self) -> float:
        store = self._eng.store
        return sum(store.used_bytes_of(req.rid)
                   for req in self._eng.slot_req.values())

    def replica_bytes(self) -> float:
        store = self._eng.store
        return sum(store.used_bytes_of(store.slot_rid[s])
                   for s in self._eng.replica_of)

    def can_admit(self, req, taking: int = 0) -> bool:
        return self.free_slots() > taking

    def can_hold_primary(self, req, resident: bool = False) -> bool:
        return resident or self.free_slots() > 0

    def can_hold_replica(self, req, resident: bool = False) -> bool:
        return resident or self.free_slots() > 0

    def can_queue(self) -> bool:
        return False

    # -- load ----------------------------------------------------------------
    def decode_load(self) -> int:
        return len(self._eng.slot_req)

    def prefill_backlog(self) -> int:
        # in-progress chunked prompts count: they still demand prefill
        # iterations (the policy keeps the instance in a prefill role)
        return (len(self._c._pending[self._index])
                + len(self._c._chunking[self._index]))

    def prefill_backlog_tokens(self) -> int:
        # planner feedback: chunk cursors shrink the remaining backlog,
        # and a stamped prefix-cache hit starts the count past the hit
        planner = self._c.planner
        return (sum(req.prompt_len - (req.prefix_hit or 0)
                    for req, _ in self._c._pending[self._index])
                + sum(req.prompt_len - max(planner.cursor(req.rid),
                                           req.prefix_hit or 0)
                      for req in self._c._chunking[self._index]))

    def decode_weights(self) -> Dict[int, float]:
        # decode_read_bytes == ledger bytes at the request's lines
        store = self._eng.store
        return {req.rid: store.used_bytes_of(req.rid)
                for req in self._eng.slot_req.values()
                if req.phase is Phase.DECODE}

    def replica_weights(self) -> Dict[int, float]:
        store = self._eng.store
        return {store.slot_rid[s]: store.used_bytes_of(store.slot_rid[s])
                for s in self._eng.replica_of}

    def decode_remaining(self) -> Dict[int, int]:
        return {req.rid: req.max_new_tokens - req.generated
                for req in self._eng.slot_req.values()}

    # -- mirror ledger --------------------------------------------------------
    def request_lines(self) -> Dict[int, int]:
        store = self._eng.store
        return {req.rid: store.lines(req.rid)
                for req in self._eng.slot_req.values()}

    def replica_synced(self) -> Dict[int, int]:
        store = self._eng.store
        return {store.slot_rid[s]: store.synced_line(store.slot_rid[s])
                for s in self._eng.replica_of}

    # -- prefix cache ---------------------------------------------------------
    def shared_blocks(self) -> int:
        return self._eng.store.ledger.shared_blocks_count()

    def prefix_hit_tokens(self, req) -> int:
        eng = self._eng
        if eng.prefix_cache is None:
            return 0
        key = eng._prefix_key(req)
        if not key:
            return 0
        return len(eng.prefix_cache.peek_blocks(key)) * eng.store.block_lines


class LiveClusterView:
    """ClusterView over a LiveCluster (see repro.scheduling.views)."""

    def __init__(self, cluster: "LiveCluster"):
        self._c = cluster
        self._views = [LiveInstanceView(cluster, i)
                       for i in range(len(cluster.engines))]

    def instances(self):
        return self._views

    def pairs(self):
        return [(self._views[i], self._views[i + 1])
                for i in range(0, len(self._views) - 1, 2)]

    def placements(self) -> Dict[int, Tuple[int, Optional[int]]]:
        return {rid: (pl.primary[0],
                      pl.replica[0] if pl.replica is not None else None)
                for rid, pl in self._c.placements.items()}


class LiveCluster:
    """Policy-driven orchestrator over real InstanceEngines."""

    def __init__(self, cfg: ModelConfig, params, n_instances: int,
                 num_slots: int, kv_capacity: int,
                 policy: Union[SchedulerPolicy, str], *,
                 temperature: float = 0.0, eos_token: Optional[int] = None,
                 block_lines: Optional[int] = None,
                 fuse_decode_steps: int = 1,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None,
                 fleet: Optional["FleetController"] = None,
                 mesh=None, timeline_stride: int = 1,
                 max_queue: Optional[int] = None,
                 shed_deadline: Optional[float] = None,
                 degrade_dispatch_s: float = 0.0):
        if isinstance(policy, str):
            from repro.scheduling.registry import get_policy
            policy = get_policy(policy)
        if policy.requires_pairs and n_instances % 2 != 0:
            raise ValueError(
                f"{policy.name} organizes instances in pairs: got "
                f"{n_instances} instances (need an even count)")
        self.cfg = cfg
        self.policy = policy
        self._params = params
        #: pod layout (repro.meshserve.MeshPlacement): carves the host's
        #: devices into per-instance TP slices and carries the — possibly
        #: heterogeneous — InstanceSpecs the views expose.  ``None`` runs
        #: every engine on the default device, as before.
        self.mesh = mesh
        if mesh is not None and mesh.n_instances != n_instances:
            raise ValueError(
                f"mesh placement has {mesh.n_instances} slices for "
                f"{n_instances} instances")
        #: per-instance hardware spec visible through the policy views
        #: (``InstanceView.spec()``); None where nothing was declared
        self.instance_specs: List[Optional[object]] = [
            mesh.spec_for(i) if mesh is not None else None
            for i in range(n_instances)]
        # join events build replacement engines with the original shape
        self._engine_kwargs = dict(
            num_slots=num_slots, kv_capacity=kv_capacity,
            temperature=temperature, eos_token=eos_token,
            block_lines=block_lines, prefix_cache=prefix_cache,
            prefix_cache_blocks=prefix_cache_blocks)
        self.engines = [
            InstanceEngine(cfg, params, num_slots, kv_capacity,
                           instance_id=i, temperature=temperature,
                           eos_token=eos_token, block_lines=block_lines,
                           prefix_cache=prefix_cache,
                           prefix_cache_blocks=prefix_cache_blocks,
                           mesh=mesh.slice_for(i) if mesh is not None
                           else None)
            for i in range(n_instances)
        ]
        #: fleet state per instance index (repro.fleet); dead engines
        #: stay in the list so indices remain stable
        self.alive: List[bool] = [True] * n_instances
        self.draining: List[bool] = [False] * n_instances
        #: partial-failure state (repro.fleet DegradeInstance): modeled
        #: compute slowdown factor (1.0 = nominal) and link slowdown for
        #: transfers touching this instance
        self.degrade: List[float] = [1.0] * n_instances
        self.link_degrade: List[float] = [1.0] * n_instances
        #: health EWMA the policy views expose — THE shared arithmetic
        #: (repro.scheduling.views.step_health), updated once per
        #: scheduling iteration for every alive instance so hedging
        #: decisions replay bit-identically on the simulator
        self.health: List[float] = [1.0] * n_instances
        #: optional calibrated injection: each decode dispatch on a
        #: degraded instance sleeps (factor-1) * this many wall seconds,
        #: making the slowdown physically observable.  0.0 (default)
        #: keeps CI and golden traces timing-free.
        self.degrade_dispatch_s = degrade_dispatch_s
        #: admission control: reject new arrivals once the backlog holds
        #: this many requests (None = unbounded), and shed queued
        #: requests whose wait already exceeds this many iterations
        #: (None = never) — a request that cannot meet its TTFT deadline
        #: is refused early instead of serving dead-on-arrival work
        self.max_queue = max_queue
        self.shed_deadline = shed_deadline
        self.shed: List[Request] = []
        self.aborted: List[Request] = []
        self.fleet = fleet
        self.queue: List[Tuple[Request, Optional[dict]]] = []
        self._pending: List[List[Tuple[Request, Optional[dict]]]] = [
            [] for _ in range(n_instances)]
        #: shared step planner: buckets/chunks prefill work and enforces
        #: the policy's phase-mixing contract (§4.2.3).  No max_bucket
        #: clamp — plans must match the simulator's bit for bit; the
        #: engine clamps scratch to its cache window at execution time.
        self.planner = Planner.for_policy(policy)
        # the live executor runs plans, it never prices them: skip the
        # per-iteration decode ledger summaries unless a trace wants them
        self.planner.decode_details = False
        #: fused decode ceiling: >1 lets idle open-loop stretches run up
        #: to N decode iterations as one jitted scan (the planner still
        #: keeps mirror-bound decode at one step per MirrorSync)
        self.planner.max_fuse_steps = max(1, fuse_decode_steps)
        #: iterations until the next source arrival (set by run();
        #: fusing never runs past an admission point)
        self._arrival_horizon: Optional[int] = None
        #: run() is pumping a closed-loop source (refills fire on
        #: completions, which bound fusing when EOS makes them
        #: unforeseeable)
        self._closed_loop = False
        if not self.engines[0].supports_chunked_prefill:
            # recurrent/enc-dec/modality stacks cannot resume a prompt
            # mid-chunk (state continuation is not implemented): the
            # chunk budget degrades to a whole-prompt admission
            # throttle instead of crashing mid-serve
            self.planner.chunk_execution = False
        #: per-instance requests mid-chunked-prefill (slot held, cursor
        #: tracked by the planner)
        self._chunking: List[List[Request]] = [[] for _ in range(n_instances)]
        self._extras: Dict[int, Optional[dict]] = {}
        self.placements: Dict[int, Placement] = {}
        self._reqs: Dict[int, Request] = {}
        self.clock = IterationClock()
        self.finished: List[Request] = []
        self._submitted: List[Request] = []
        self.undelivered = 0     # source requests never admitted (max_steps)
        self.timeline: List[TimelinePoint] = []
        #: sample the timeline every N scheduling iterations (1 = every
        #: iteration) — same knob as the simulator's, so a million-step
        #: replay keeps O(n/stride) observability memory
        self.timeline_stride = max(1, timeline_stride)
        #: wall-clock seconds spent in scheduling decisions (policy +
        #: planner), excluding engine execution — the live counterpart
        #: of ``Simulator.sched_time_s``
        self.sched_time_s = 0.0
        self.n_iterations = 0
        self._sched_t0: Optional[float] = None
        self.stats = {"prefills": 0, "decode_steps": 0, "rebalances": 0,
                      "replica_promotions": 0, "replica_evictions": 0,
                      "mirror_syncs": 0, "mirror_bytes": 0.0,
                      "stream_bytes": 0.0, "evicted_blocks": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "stream_skipped_lines": 0,
                      "sheds": 0, "aborts": 0, "hedges": 0,
                      "pressure_aborts": 0}

    @property
    def now(self) -> float:
        return self.clock.now

    @property
    def sched_us_per_iter(self) -> float:
        """Mean scheduler overhead per iteration, microseconds."""
        return self.sched_time_s * 1e6 / max(1, self.n_iterations)

    def _sched_begin(self):
        import time
        self._sched_t0 = time.perf_counter()

    def _sched_end(self):
        import time
        if self._sched_t0 is not None:
            self.sched_time_s += time.perf_counter() - self._sched_t0
            self._sched_t0 = None

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request, extra: Optional[dict] = None, *,
               stamp_arrival: bool = True):
        """Enqueue a request.  Open-loop sources pass
        ``stamp_arrival=False`` to preserve the traffic layer's arrival
        time (which may fall between scheduling iterations)."""
        if stamp_arrival:
            req.arrival = self.now
        if extra is None:
            extra = req.extra
        if any(r.rid == req.rid for r in self._submitted
               if r.finish_time is None
               and r.phase not in (Phase.SHED, Phase.ABORTED)):
            # placements/_reqs are keyed by rid; mixing source streams
            # (rids 0,1,...) with hand-built Requests (global counter)
            # must fail loudly, not corrupt another live request's state
            raise ValueError(f"request id {req.rid} is already in flight")
        self._submitted.append(req)
        if self.max_queue is not None \
                and self.backlog_depth() >= self.max_queue:
            # bounded admission: a full backlog sheds the arrival at the
            # door (a deliberate, counted SLO miss — not a silent drop)
            self._shed(req)
            return
        self.queue.append((req, extra))

    def backlog_depth(self) -> int:
        """Requests accepted but not yet fully prefilled — the admission
        queue the ``max_queue`` bound applies to.  Mid-chunk prompts
        count (the simulator keeps them in ``prefill_queue`` until the
        final chunk, so both backends bound the same quantity)."""
        return (len(self.queue) + sum(len(p) for p in self._pending)
                + sum(len(c) for c in self._chunking))

    def _shed(self, req: Request):
        req.phase = Phase.SHED
        self.shed.append(req)
        self._extras.pop(req.rid, None)
        self.stats["sheds"] += 1
        ctrl = self._fleet_ctrl()
        ctrl.note("shed", req.rid)
        ctrl.stats["sheds"] += 1

    def _shed_overdue(self):
        """Deadline-aware shedding: queued requests whose wait already
        exceeds ``shed_deadline`` iterations cannot meet their TTFT SLO
        — refuse them now rather than burn prefill compute on
        dead-on-arrival work.  Requests mid-chunk are executing and are
        never shed."""
        deadline = self.shed_deadline
        keep: List[Tuple[Request, Optional[dict]]] = []
        for req, extra in self.queue:
            if self.now - req.arrival > deadline:
                self._shed(req)
            else:
                keep.append((req, extra))
        self.queue = keep
        for idx, pending in enumerate(self._pending):
            if not pending:
                continue
            keep = []
            for req, extra in pending:
                if (self.now - req.arrival > deadline
                        and self.planner.cursor(req.rid) == 0):
                    if req.prefix_hit is not None:
                        self.engines[idx].prefix_abandon(req)
                    self._shed(req)
                else:
                    keep.append((req, extra))
            self._pending[idx] = keep

    # -- abort lifecycle ------------------------------------------------------
    def abort(self, rid: int) -> Optional[Request]:
        """Cancel ``rid`` wherever it is in its lifecycle — queued,
        routed, mid prefill chunk, or decoding — and tear down all of
        its serving state: ledger blocks freed, prefix pin dropped,
        replica released on the mirror, planner cursor forgotten.  The
        request record survives with ``Phase.ABORTED`` so metrics count
        it.  Returns the request, or None if ``rid`` is unknown."""
        found: Optional[Request] = None
        keep: List[Tuple[Request, Optional[dict]]] = []
        for req, extra in self.queue:
            if req.rid == rid:
                found = req
            else:
                keep.append((req, extra))
        self.queue = keep
        for idx, pending in enumerate(self._pending):
            keep = []
            for req, extra in pending:
                if req.rid == rid:
                    found = req
                    if req.prefix_hit is not None:
                        self.engines[idx].prefix_abandon(req)
                else:
                    keep.append((req, extra))
            self._pending[idx] = keep
        for idx, chunking in enumerate(self._chunking):
            for req in list(chunking):
                if req.rid != rid:
                    continue
                found = req
                chunking.remove(req)
                eng = self.engines[idx]
                for slot, r in list(eng.prefilling.items()):
                    if r.rid == rid:
                        eng.release(slot)
                if req.prefix_hit is not None:
                    eng.prefix_abandon(req)
        pl = self.placements.pop(rid, None)
        if pl is not None:
            p_idx, p_slot = pl.primary
            eng = self.engines[p_idx]
            req = eng.slot_req.get(p_slot)
            if req is not None and req.rid == rid:
                found = req
                eng.release(p_slot)
            if pl.replica is not None:
                r_idx, r_slot = pl.replica
                self.engines[r_idx].release(r_slot)
        found = self._reqs.pop(rid, found) or found
        self._extras.pop(rid, None)
        self.planner.forget(rid)
        if found is not None:
            found.phase = Phase.ABORTED
            self.aborted.append(found)
            self.stats["aborts"] += 1
            ctrl = self._fleet_ctrl()
            ctrl.note("abort", rid)
            ctrl.stats["aborts"] += 1
        return found

    # -- decode fusing --------------------------------------------------------
    def _fuse_budget(self) -> int:
        """Iterations of decode the planner may fuse this step: only
        idle open-loop stretches qualify — no queued/pending/mid-chunk
        prefill work anywhere (a role could flip), capped by the arrival
        horizon and by the shortest remaining token budget (so a fused
        block ends exactly when its first request completes and
        finish-time stamps stay iteration-exact).  Per-instance
        mirror-bound exclusion lives in ``Planner._fuse_steps``."""
        n = self.planner.max_fuse_steps
        if n <= 1:
            return 1
        if self.queue or any(self._pending) or any(self._chunking):
            return 1
        # one shared iteration clock: if ANY request is mirrored, every
        # instance stays at one step per iteration — otherwise a clean
        # instance would fuse ahead while its mirror-bound pair ticks
        # per-step, and the two would disagree about what "now" means
        if any(pl.replica is not None for pl in self.placements.values()):
            return 1
        # closed-loop refills fire on completions; the budget cap makes
        # those predictable EXCEPT when an eos_token can end a request
        # mid-span — then a fused block would idle the freed slot until
        # span end, delaying the replacement request vs per-step decode
        if self._closed_loop and self.engines[0].eos_token is not None:
            return 1
        if self._arrival_horizon is not None:
            n = min(n, self._arrival_horizon)
        if self.fleet is not None:
            # a fused span must not scan past a scheduled fleet event
            nxt = self.fleet.next_time()
            if nxt is not None:
                n = min(n, max(1, math.ceil(nxt - self.now)))
        rem = [r.max_new_tokens - r.generated
               for r in self._reqs.values() if r.phase is Phase.DECODE]
        if rem:
            n = min(n, min(rem))
        return max(1, n)

    # -- one scheduling iteration ---------------------------------------------
    def step(self):
        self.clock.tick()
        # fleet events apply between scheduler iterations: the view the
        # policy reads below already reflects kills/joins/drains
        if self.fleet is not None:
            for ev in self.fleet.due(self.now):
                self._apply_fleet_event(ev)
        if any(self.draining):
            self._settle_drains()
        # health EWMA: one update per alive instance per iteration, the
        # same cadence the simulator uses, so hedging decisions gated on
        # health replay bit-identically on both backends
        for i in range(len(self.engines)):
            if self.alive[i]:
                self.health[i] = step_health(self.health[i],
                                             self.degrade[i])
        if self.shed_deadline is not None:
            self._shed_overdue()
        if self.planner.max_fuse_steps > 1:
            self.planner.fuse_horizon = self._fuse_budget()
        view = LiveClusterView(self)

        # scheduling decisions (routing, roles, admission, plan compile)
        # are timed; engine execution below is not — the same split the
        # simulator's sched_us_per_iter uses
        self._sched_begin()

        # 1. routing: policy assigns queued requests to instances
        admitted = 0
        limit = self.policy.admissions_per_step(view)
        while self.queue and admitted < limit:
            req, extra = self.queue[0]
            target = self.policy.route(view, req)
            if target is None:
                break
            self.queue.pop(0)
            self._pending[target].append((req, extra))
            admitted += 1

        # 2. roles -> declarative step actions; the planner compiles them
        # into per-instance plans (bucketing, chunk cursors, and the
        # §4.2.3 no-mixing invariant all live there, not here)
        roles = {i: (self.policy.choose_roles(view, i) if self.alive[i]
                     else ROLE_IDLE)
                 for i in range(len(self.engines))}
        actions: List[Action] = []
        taken_now: Dict[int, List[Tuple[Request, Optional[dict]]]] = {}
        for idx, eng in enumerate(self.engines):
            if not self.alive[idx]:
                continue
            pf_actions: List[Action] = []
            if roles[idx] in (ROLE_PREFILL, ROLE_MIXED):
                for req in self._chunking[idx]:
                    pf_actions.append(Prefill(req.rid, idx, req.prompt_len,
                                              req=req))
                if self._pending[idx]:
                    n = self.policy.prefill_batch(
                        view, idx, [r for r, _ in self._pending[idx]])
                    for _ in range(n):
                        if not self._pending[idx]:
                            break
                        req, extra = self._pending[idx][0]
                        # everyone admitted this iteration takes a slot
                        # at execution, so capacity is free MINUS the
                        # batch so far — a prefix-cache pin can also
                        # wall off a slot region mid-loop, so re-count
                        # every admission rather than trusting n
                        taken = len(taken_now.get(idx, ()))
                        if len(eng.free_slots()) <= taken:
                            for act in self.policy.evict(
                                    view, [view.instances()[idx]]):
                                self._apply(act)
                        if len(eng.free_slots()) <= taken:
                            break
                        hit = 0
                        if eng.prefix_cache is not None:
                            hit = eng.prefix_stamp(req)
                            if hit and len(eng.free_slots()) <= taken:
                                # the pin froze the last free slot's
                                # region: admit without the hit instead
                                # of overcommitting the batch
                                eng.prefix_abandon(req)
                                hit = 0
                        if hit:
                            self.stats["prefix_hits"] += 1
                            self.stats["prefix_hit_tokens"] += hit
                        self._pending[idx].pop(0)
                        taken_now.setdefault(idx, []).append((req, extra))
                        self._extras[req.rid] = extra
                        pf_actions.append(Prefill(req.rid, idx,
                                                  req.prompt_len, req=req))
            actions.extend(pf_actions)
            # an instance only forgoes decode when it actually prefills
            # under an exclusive-prefill role (§4.2.3); the decode batch
            # membership is resolved at execution time — a request
            # streamed in after prefill decodes this same iteration
            if roles[idx] != ROLE_PREFILL or not pf_actions:
                actions.append(Decode(idx))
        plans = self.planner.compile(actions, view)
        self._sched_end()

        # chunk budget may not have reached every admitted request this
        # iteration: return the unplanned ones to the head of the backlog
        planned_rids = set()
        for plan in plans:
            pf = prefill_part(plan)
            if pf is not None:
                planned_rids.update(it.rid for it in pf.items)
        for idx, taken in taken_now.items():
            unplanned = [(r, e) for r, e in taken
                         if r.rid not in planned_rids]
            if unplanned:
                self._pending[idx][:0] = unplanned

        # 3. execute the plans in the executor's phase order: all
        # prefills, then post-prefill placement, then all decodes — so a
        # request streamed to its decode primary still joins that
        # instance's decode batch within the same iteration
        prefilled = set()
        decoded = set()
        newly: List[Tuple[int, Request]] = []
        for plan in plans:
            pf = prefill_part(plan)
            if pf is not None:
                self._execute_prefill(pf, newly, prefilled)

        # 4. post-prefill placement (§4.1.2 streaming / Splitwise
        # transfer), wrapped into transfer plans
        for idx, req in newly:
            self._sched_begin()
            try:
                acts = self.policy.place_after_prefill(view, idx, req)
            finally:
                self._sched_end()
            self._apply_transfers(acts, view)

        ran_steps = 1
        for plan in plans:
            dc = decode_part(plan)
            if dc is None or not self.engines[dc.instance].slot_req:
                continue
            eng = self.engines[dc.instance]
            # graceful-degradation ladder (§4.2.5) before the step can
            # allocate: evict replicas, then abort least-progress work
            self._relieve_pressure(dc.instance, view)
            if not eng.slot_req:
                continue
            if self.degrade_dispatch_s > 0.0 \
                    and self.degrade[dc.instance] > 1.0:
                # calibrated physical injection: a degraded instance's
                # dispatch really takes (factor-1) x the knob longer
                import time
                time.sleep((self.degrade[dc.instance] - 1.0)
                           * self.degrade_dispatch_s)
            live = {s: eng.slot_req[s] for s in eng.active_slots()}
            out = eng.decode_multi(dc)
            if out:
                # account the span actually executed: EOS can end a
                # fused block before dc.steps (the budget cap cannot
                # foresee a sampled eos_token)
                ran = max(len(toks) for toks in out.values())
                self.stats["decode_steps"] += ran
                ran_steps = max(ran_steps, ran)
                decoded.add(dc.instance)
            for slot, toks in out.items():
                req = live[slot]
                for j in range(len(toks)):
                    req.token_times.append(self.now + j)
                if (dc.steps > 1 and req.phase is Phase.DONE
                        and req.finish_time is None):
                    # died mid-span (EOS): stamp the iteration it really
                    # finished, not the end of the fused block
                    req.finish_time = self.now + len(toks) - 1
                    self.finished.append(req)
        if ran_steps > 1:
            # a fused block IS ran_steps scheduling iterations: advance
            # the clock so latencies stay comparable to per-step decode
            self.clock.tick(ran_steps - 1)

        # 5. release placements of finished requests
        self._release_finished()

        # 6. mirror newly generated lines into replicas (§4.1.2)
        self._sched_begin()
        try:
            sync_acts = self.policy.sync(view)
        finally:
            self._sched_end()
        self._apply_transfers(sync_acts, view)

        # 7. pair-level load balancing via replica promotion (§4.1.3)
        if self.policy.requires_pairs:
            for pair_index in range(len(self.engines) // 2):
                self._sched_begin()
                try:
                    acts = self.policy.rebalance(view, pair_index)
                finally:
                    self._sched_end()
                self._apply_transfers(acts, view)
                if acts:
                    self.stats["rebalances"] += 1

        # 8. policies that re-route every iteration reclaim their backlog
        if self.policy.requeue_unplaced:
            stranded = [item for pending in self._pending for item in pending]
            if stranded:
                # a stamped hit is instance-local: releasing the backlog
                # for re-routing must drop the pin (it re-stamps wherever
                # it lands next iteration)
                for idx, pending in enumerate(self._pending):
                    for req, _ in pending:
                        if req.prefix_hit is not None:
                            self.engines[idx].prefix_abandon(req)
                    pending.clear()
                self.queue[:0] = stranded

        # 9. observability: queue depth + per-phase utilization this iteration
        # (a fused block IS ran_steps scheduling iterations: the one
        # scheduling pass amortizes over all of them)
        self.n_iterations += ran_steps
        if (self.n_iterations - 1) % self.timeline_stride >= ran_steps:
            return
        n = len(self.engines)
        busy = prefilled | decoded
        self.timeline.append(TimelinePoint(
            t=self.now,
            # mid-chunk prompts count as queued (the simulator keeps
            # them in prefill_queue until the final chunk, so the two
            # backends report comparable queue depths under chunking)
            queue_depth=(len(self.queue) + sum(len(p) for p in self._pending)
                         + sum(len(c) for c in self._chunking)),
            n_prefill=len(prefilled),
            n_decode=len(decoded - prefilled),
            n_idle=n - len(busy)))

    # -- fleet mechanics (repro.fleet) ---------------------------------------
    def _fleet_ctrl(self) -> "FleetController":
        if self.fleet is None:
            # direct-driven fleet ops (tests, interactive kills) still
            # need the shared decision planner + trace/stats home
            from repro.fleet import FleetController
            self.fleet = FleetController()
        return self.fleet

    def _apply_fleet_event(self, ev):
        from repro.fleet import (DegradeInstance, Drain, JoinInstance,
                                 KillInstance, RecoverInstance)
        if isinstance(ev, KillInstance):
            self.fleet_kill(ev.instance)
        elif isinstance(ev, JoinInstance):
            self.fleet_join(ev.instance)
        elif isinstance(ev, Drain):
            self.fleet_drain(ev.instance)
        elif isinstance(ev, DegradeInstance):
            self.fleet_degrade(ev.instance, ev.factor, ev.link_factor)
        elif isinstance(ev, RecoverInstance):
            self.fleet_recover(ev.instance)
        else:
            raise ValueError(f"unknown fleet event {ev!r}")

    def fleet_degrade(self, instance: int, factor: float = 4.0,
                      link_factor: float = 1.0):
        """Partial failure: the instance keeps serving but ``factor``x
        slower (thermal throttling, a flapping NIC, a noisy neighbor).
        Nothing is torn down — the health EWMA drifts up and hedging
        kernels route decode around it."""
        if instance >= len(self.engines) or not self.alive[instance]:
            return
        self.degrade[instance] = float(factor)
        self.link_degrade[instance] = float(link_factor)
        ctrl = self._fleet_ctrl()
        ctrl.note("degrade", instance, float(factor), float(link_factor))
        ctrl.stats["degrades"] += 1

    def fleet_recover(self, instance: int):
        """The degraded instance returns to nominal speed; its health
        EWMA decays back under the hedge threshold over the next
        iterations."""
        if instance >= len(self.engines) or not self.alive[instance]:
            return
        self.degrade[instance] = 1.0
        self.link_degrade[instance] = 1.0
        ctrl = self._fleet_ctrl()
        ctrl.note("recover", instance)
        ctrl.stats["recoveries"] += 1

    def fleet_kill(self, instance: int):
        """Abrupt instance failure: every resident byte is gone.  The
        shared controller plans what survives — primaries with a warm
        replica flip roles via the existing promotion machinery (rolled
        back to the replica's synced line); everything else re-queues
        for a full re-prefill."""
        if instance >= len(self.engines) or not self.alive[instance]:
            return
        from repro.fleet import reset_for_reprefill, rollback_tokens
        ctrl = self._fleet_ctrl()
        ctrl.note("kill", instance)
        ctrl.stats["kills"] += 1
        plan = ctrl.plan_failover(LiveClusterView(self), instance)
        dead = self.engines[instance]
        # 1. promotions: the warm replica takes over at its synced line;
        # the unsynced tail of decode tokens re-generates there
        for pr in plan.promotions:
            pl = self.placements[pr.rid]
            r_idx, r_slot = pl.replica
            req = self._reqs[pr.rid]
            if pr.lost_lines:
                rollback_tokens(req, pr.lost_lines)
                ctrl.stats["lost_lines"] += pr.lost_lines
            self.engines[r_idx].promote_replica(r_slot, req)
            pl.primary = (r_idx, r_slot)
            pl.replica = None
            ctrl.note("promote", pr.rid, pr.src, pr.dst, pr.lost_lines)
            ctrl.stats["promotions"] += 1
            self.stats["replica_promotions"] += 1
        # 2. truly lost state: back to the queue head, full re-prefill
        # (original arrival stamp kept — the TTFT damage is the metric)
        requeued: List[Tuple[Request, Optional[dict]]] = []
        for rid in plan.requeues:
            req = self._reqs.pop(rid)
            ctrl.note("requeue", rid)
            ctrl.stats["requeues"] += 1
            ctrl.stats["lost_decode_tokens"] += req.generated
            ctrl.stats["reprefill_tokens"] += reset_for_reprefill(req)
            self.planner.forget(rid)
            del self.placements[rid]
            requeued.append((req, self._extras.pop(rid, req.extra)))
        # 3. replicas this instance hosted for surviving primaries
        for rid in plan.dropped_replicas:
            self.placements[rid].replica = None
            ctrl.note("drop_replica", rid)
        # 4. routed-but-unprefilled backlog re-routes (no tokens re-run)
        for req, extra in self._pending[instance]:
            ctrl.note("requeue", req.rid)
            ctrl.stats["requeue_backlog"] += 1
            requeued.append((req, extra))
        self._pending[instance] = []
        # 5. prompts mid-chunk lose their partial prefill work
        for req in self._chunking[instance]:
            ctrl.note("requeue", req.rid)
            ctrl.stats["requeues"] += 1
            ctrl.stats["reprefill_tokens"] += self.planner.cursor(req.rid)
            self.planner.forget(req.rid)
            reset_for_reprefill(req)
            requeued.append((req, self._extras.pop(req.rid, req.extra)))
        self._chunking[instance] = []
        # a stamped hit referred to the dead instance's cache; the
        # re-prefill starts clean and re-stamps wherever it lands
        for req, _ in requeued:
            if req.prefix_hit is not None:
                dead.prefix_abandon(req)
        self.queue[:0] = requeued
        # 6. teardown: free every slot; the dead engine object stays in
        # the list so instance indices remain stable.  The prefix cache
        # dies with the HBM it indexed — a rejoin at this rank starts
        # cold.
        for slot in (list(dead.slot_req) + list(dead.replica_of)
                     + list(dead.prefilling)):
            dead.release(slot)
        if dead.prefix_cache is not None:
            dead.prefix_cache.release_all()
        self.alive[instance] = False
        self.draining[instance] = False
        # partial-failure state dies with the instance: replacement
        # hardware at this rank starts nominal
        self.degrade[instance] = 1.0
        self.link_degrade[instance] = 1.0
        self.health[instance] = 1.0

    def fleet_join(self, instance: Optional[int] = None) -> int:
        """Register a fresh instance (revive a dead index, or append a
        new one with ``None``), then let the kernel warm it with
        replicas of resident requests BEFORE any new arrival routes
        there."""
        ctrl = self._fleet_ctrl()
        if instance is not None and instance < len(self.engines):
            if self.alive[instance]:
                return instance           # join of a live index: no-op
            idx = instance
            # replacement hardware at the same rank: the torn-down
            # engine (every slot freed at kill) is the fresh instance
            self.alive[idx] = True
            self.draining[idx] = False
            self.degrade[idx] = 1.0
            self.link_degrade[idx] = 1.0
            self.health[idx] = 1.0
        else:
            idx = len(self.engines)
            # autoscaled joins land past the carved pod: unsharded,
            # default hardware (MeshPlacement.slice_for returns None there)
            sl = self.mesh.slice_for(idx) if self.mesh is not None else None
            self.engines.append(
                InstanceEngine(self.cfg, self._params, instance_id=idx,
                               mesh=sl, **self._engine_kwargs))
            self.instance_specs.append(
                self.mesh.spec_for(idx) if self.mesh is not None else None)
            self._pending.append([])
            self._chunking.append([])
            self.alive.append(True)
            self.draining.append(False)
            self.degrade.append(1.0)
            self.link_degrade.append(1.0)
            self.health.append(1.0)
        ctrl.note("join", idx)
        ctrl.stats["joins"] += 1
        view = LiveClusterView(self)
        acts = self.policy.warm_on_join(view, idx)
        if acts:
            self._apply_transfers(acts, view)
            ctrl.stats["warm_streams"] += len(acts)
        return idx

    def fleet_drain(self, instance: int):
        """Cordon: no new work routes here (``draining`` in the views);
        the instance leaves the fleet once its residents complete."""
        if instance >= len(self.engines) or not self.alive[instance] \
                or self.draining[instance]:
            return
        ctrl = self._fleet_ctrl()
        self.draining[instance] = True
        ctrl.note("drain", instance)
        ctrl.stats["drains"] += 1
        self._settle_drains()

    def _settle_drains(self):
        for idx, draining in enumerate(self.draining):
            if not (draining and self.alive[idx]):
                continue
            eng = self.engines[idx]
            if eng.slot_req or eng.prefilling or self._pending[idx] \
                    or self._chunking[idx]:
                continue
            # only replicas remain: the primaries live elsewhere, so the
            # copies are surrendered and the instance leaves the fleet
            for slot in list(eng.replica_of):
                rid = eng.store.slot_rid[slot]
                eng.release(slot)
                pl = self.placements.get(rid)
                if pl is not None and pl.replica is not None \
                        and pl.replica[0] == idx:
                    pl.replica = None
            self.alive[idx] = False
            self.draining[idx] = False
            self._fleet_ctrl().note("drained", idx)

    # -- plan execution -------------------------------------------------------
    def _execute_prefill(self, pf: PrefillPlan,
                         newly: List[Tuple[int, Request]], prefilled: set):
        eng = self.engines[pf.instance]
        completed = eng.prefill_batch(pf, extras=self._extras)
        # chunk bookkeeping: items still mid-prompt hold their slots
        self._chunking[pf.instance] = [it.req for it in pf.items
                                       if it.rid not in completed]
        prefilled.add(pf.instance)
        for it in pf.items:
            slot = completed.get(it.rid)
            if slot is None:
                continue
            req = it.req
            self._extras.pop(req.rid, None)
            # engines may complete ahead of the cursor (whole-prompt
            # degrade for non-chunkable prompts): drop any stale cursor
            self.planner.forget(req.rid)
            req.first_token_time = self.now
            req.token_times.append(self.now)
            self.placements[req.rid] = Placement(primary=(pf.instance, slot))
            self._reqs[req.rid] = req
            self.stats["prefills"] += 1
            if req.done:          # degenerate max_new_tokens == 1
                req.phase = Phase.DONE
                eng.release(slot)
                continue
            newly.append((pf.instance, req))

    def _apply_transfers(self, acts: List[Action], view):
        """Execute policy-emitted movement actions.  The live backend
        moves real bytes, so it applies the actions directly; only the
        simulator needs them wrapped into priced ``TransferPlan``s
        (``Planner._wrap_transfer``) — wrapping here would rebuild the
        per-request ledger dicts every mirror step for a result the
        executor never reads."""
        for act in acts:
            self._apply(act)

    # -- action interpreter ---------------------------------------------------
    def _apply(self, act: Action):
        if isinstance(act, StreamState):
            self._apply_stream(act)
        elif isinstance(act, MirrorSync):
            self._apply_mirror(act)
        elif isinstance(act, PromoteReplica):
            self._apply_promote(act)
        elif isinstance(act, EvictReplica):
            self._apply_evict(act)
        elif isinstance(act, AbortRequest):
            self.abort(act.rid)
        else:
            raise ValueError(f"live executor cannot apply {act!r}")

    def _apply_stream(self, act: StreamState):
        pl = self.placements.get(act.rid)
        if pl is None or pl.primary[0] != act.src:
            return
        if not self.alive[act.dst] or self.draining[act.dst]:
            return                       # destination left the fleet
        src_idx, src_slot = pl.primary
        src = self.engines[src_idx]
        dst = self.engines[act.dst]
        free = dst.free_slots()
        if not free:
            return                       # capacity raced away; stay put
        dst_slot = free[0]
        req = src.slot_req[src_slot]
        # per-layer streamed transfer (§4.2.4): the state moves one
        # layer chunk at a time — the unit a mesh overlaps with prefill
        chunks, length, last_tok, lines = src.export_stream(src_slot)
        if act.as_replica:
            # primary stays at src; dst hosts a redundant copy
            dst.import_stream(dst_slot, chunks, length, last_tok, lines,
                              req, as_replica_of=(src.instance_id, src_slot))
            pl.replica = (act.dst, dst_slot)
        else:
            dst.import_stream(dst_slot, chunks, length, last_tok, lines, req)
            if act.retain_replica:
                src.demote_to_replica(src_slot,
                                      of=(dst.instance_id, dst_slot))
                pl.replica = (src_idx, src_slot)
            else:
                src.release(src_slot)
            pl.primary = (act.dst, dst_slot)
        # head lines already resident in dst's prefix cache are adopted,
        # not moved: charge only the unique suffix (planner prices the
        # same subtraction via StreamState.skip_lines)
        skip = min(lines, dst.store.shared_head_lines(act.rid))
        self.stats["stream_skipped_lines"] += skip
        self.stats["stream_bytes"] += (src.store.costs.bytes_at(lines)
                                       - skip * src.store.costs.line_bytes)

    def _apply_mirror(self, act: MirrorSync):
        pl = self.placements.get(act.rid)
        if pl is None or pl.replica is None:
            return
        p_idx, p_slot = pl.primary
        r_idx, r_slot = pl.replica
        src = self.engines[p_idx]
        if p_slot not in src.slot_req:
            return
        moved = self.engines[r_idx].sync_replica_from(
            src, p_slot, r_slot, from_line=act.from_line,
            to_line=act.to_line)
        self.stats["mirror_syncs"] += 1
        self.stats["mirror_bytes"] += moved

    def _apply_promote(self, act: PromoteReplica):
        pl = self.placements.get(act.rid)
        if pl is None or pl.replica is None or pl.primary[0] != act.src:
            return
        p_idx, p_slot = pl.primary
        r_idx, r_slot = pl.replica
        src = self.engines[p_idx]
        dst = self.engines[r_idx]
        req = src.slot_req[p_slot]
        # executor backstop for the kernel's catch-up contract: a stale
        # replica must absorb the unsynced tail before taking the
        # primary role — promotion itself moves no bytes
        if dst.store.synced_line(req.rid) < src.store.lines(req.rid):
            moved = dst.sync_replica_from(src, p_slot, r_slot)
            self.stats["mirror_syncs"] += 1
            self.stats["mirror_bytes"] += moved
        # zero-cost migration: promote replica, demote primary
        dst.promote_replica(r_slot, req)
        src.demote_to_replica(p_slot, of=(dst.instance_id, r_slot))
        pl.primary = (r_idx, r_slot)
        pl.replica = (p_idx, p_slot)
        self.stats["replica_promotions"] += 1
        if act.hedge:
            # straggler hedge, not a load-balance flip: counted apart so
            # reports can tell redundancy-as-insurance from rebalancing
            self.stats["hedges"] += 1
            if self.fleet is not None:
                self.fleet.stats["hedges"] += 1

    def _relieve_pressure(self, idx: int, view):
        """KV-pressure relief ladder (AcceLLM §4.2.5): before a decode
        step, make sure the block pool can absorb one new line per
        resident primary.  Rung 1 drops replicas hosted here (redundancy
        is insurance, not an entitlement); rung 2 aborts the
        least-progressed primaries — a deliberate, counted casualty
        instead of an allocation failure mid-step."""
        eng = self.engines[idx]
        store = eng.store

        def shortfall() -> int:
            need = sum(1 for req in eng.slot_req.values()
                       if store.lines(req.rid) % store.block_lines == 0)
            return need - store.free_blocks()

        if shortfall() <= 0:
            return
        iv = view.instances()[idx]
        while shortfall() > 0 and eng.replica_of:
            before = len(eng.replica_of)
            for act in self.policy.evict(view, [iv]):
                self._apply(act)
            if len(eng.replica_of) == before:
                # the policy won't name a victim: drop the heaviest
                # replica directly rather than fail the decode step
                slot = max(eng.replica_of,
                           key=lambda s: store.used_bytes_of(
                               store.slot_rid[s]))
                rid = store.slot_rid[slot]
                freed = eng.release(slot)
                pl = self.placements.get(rid)
                if pl is not None and pl.replica is not None \
                        and pl.replica[0] == idx:
                    pl.replica = None
                self.stats["replica_evictions"] += 1
                self.stats["evicted_blocks"] += freed
        while shortfall() > 0 and len(eng.slot_req) > 1:
            victim = min(eng.slot_req.values(),
                         key=lambda r: (r.generated, r.rid))
            self.abort(victim.rid)
            self.stats["pressure_aborts"] += 1

    def _apply_evict(self, act: EvictReplica):
        pl = self.placements.get(act.rid)
        if pl is None or pl.replica is None or pl.replica[0] != act.instance:
            return
        r_idx, r_slot = pl.replica
        freed = self.engines[r_idx].release(r_slot)
        pl.replica = None
        self.stats["replica_evictions"] += 1
        self.stats["evicted_blocks"] += freed

    # -- bookkeeping ----------------------------------------------------------
    def _release_finished(self):
        for rid, pl in list(self.placements.items()):
            p_idx, p_slot = pl.primary
            req = self.engines[p_idx].slot_req.get(p_slot)
            if req is None or req.rid != rid:     # finished & released
                if pl.replica is not None:
                    r_idx, r_slot = pl.replica
                    self.engines[r_idx].release(r_slot)
                del self.placements[rid]
                self._reqs.pop(rid, None)

    # -- driver ---------------------------------------------------------------
    def pending(self) -> int:
        live = len(self.queue) + len(self.placements)
        live += sum(len(p) for p in self._pending)
        live += sum(len(c) for c in self._chunking)
        return live

    def run(self, max_steps: int = 10_000,
            source: Optional[RequestSource] = None) -> List[Request]:
        """Drive the cluster to completion.

        Without a ``source`` this is the classic closed-batch driver over
        previously :meth:`submit`-ted requests.  With a ``source`` the
        lifecycle is **open-loop**: each iteration first admits every
        request whose arrival stamp is due on the iteration clock (one
        traffic time unit == one iteration), idling through gaps between
        arrivals.  Closed-loop sources (``source.concurrency`` set)
        instead keep that many requests in flight, issuing the next one
        the moment a previous one finishes.
        """
        it = iter(source) if source is not None else None
        concurrency = source.concurrency if source is not None else None
        self._closed_loop = bool(concurrency)
        exhausted = it is None
        next_req: Optional[Request] = None
        issued = 0
        steps = 0
        while steps < max_steps:
            if it is not None and not exhausted:
                if concurrency:
                    # closed loop: top in-flight back up to `concurrency`
                    # (shed and aborted requests are terminal, not in
                    # flight — they must not wedge the pump)
                    while (len(self._submitted) - len(self.finished)
                           - len(self.shed) - len(self.aborted)
                           < concurrency):
                        req = next(it, None)
                        if req is None:
                            exhausted = True
                            break
                        self.submit(req)
                        issued += 1
                else:
                    # open loop: admit everything due by the current clock
                    while True:
                        if next_req is None:
                            next_req = next(it, None)
                            if next_req is None:
                                exhausted = True
                                break
                        if next_req.arrival > self.now:
                            break
                        self.submit(next_req, stamp_arrival=False)
                        issued += 1
                        next_req = None
                    # fusing may not run past the next admission point
                    self._arrival_horizon = (
                        None if next_req is None
                        else max(1, math.ceil(next_req.arrival - self.now)))
            if exhausted and not self.pending():
                break
            self.step()
            # stamp finish times for anything that completed this iteration
            # (including requests that finish in their very first step)
            for req in self._submitted:
                if req.phase is Phase.DONE and req.finish_time is None:
                    req.finish_time = self.now
                    self.finished.append(req)
            steps += 1
        if not exhausted:
            # max_steps elapsed with traffic still in the source: count the
            # requests that were never even offered, so reports can't claim
            # a healthy run over a silently truncated stream.  Count on a
            # token-free replay of the stream (same spec + seed, no cfg)
            # rather than draining `it`, which would materialize prompt
            # arrays and modality extras just to throw them away.
            total = sum(1 for _ in source.spec.source(seed=source.seed))
            self.undelivered += total - issued
        return self.finished
