"""Config system: model architecture configs + input-shape registry.

Every assigned architecture is expressed as a ``ModelConfig``. One dataclass
covers all six families (dense / moe / ssm / hybrid / vlm / audio) via a
per-layer ``block_pattern`` and optional sub-configs (MoE, MLA, Mamba, xLSTM,
encoder-decoder, modality frontend stubs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0       # DeepSeek-style always-on shared expert(s)
    shared_d_ff: int = 0              # d_ff of the shared expert
    dense_residual_d_ff: int = 0      # Arctic-style dense MLP in parallel w/ MoE
    router_aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25
    # layers whose index % period != offset fall back to a dense FFN
    moe_layer_period: int = 1
    moe_layer_offset: int = 0
    first_dense_layers: int = 0       # DeepSeek-V3: first k layers are dense
    first_dense_d_ff: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 SSM block configuration (Jamba interleave)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (sLSTM + mLSTM)."""

    # mLSTM: matrix memory C in R^{heads x dk x dv}; sLSTM: scalar memory.
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333
    conv1d_kernel_size: int = 4
    # within each group of ``slstm_every`` blocks, one is sLSTM (xLSTM[7:1])
    slstm_every: int = 8


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder (audio) architectures."""

    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    # frontend stub: precomputed frame embeddings of shape (B, frames, d_model)
    max_source_positions: int = 4096


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: input_specs() provides precomputed
    patch/frame embeddings of this shape instead of raw pixels/waveforms."""

    kind: str                 # "vision" | "audio"
    num_prefix_tokens: int    # patches per image / frames per utterance
    embed_dim: int            # dimension of the precomputed embeddings


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

VALID_BLOCKS = ("attn", "mamba", "mlstm", "slstm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    source: str                # citation (arXiv id or model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // num_heads
    # per-layer block pattern; entry i gives the mixer of layer i.
    # empty -> all-attention.
    block_pattern: Tuple[str, ...] = ()
    # attention details
    attention_kind: str = "gqa"          # "gqa" | "mla"
    rope_theta: float = 10_000.0
    use_rope: bool = True
    abs_pos: str = "none"              # "none" | "sinusoidal" (added at embed)
    sliding_window: Optional[int] = None  # architecture's own native window
    # long-context decode policy: window applied only for the long_500k shape
    long_context_window: int = 8192
    # norm / activation
    rms_norm_eps: float = 1e-5
    activation: str = "swiglu"           # "swiglu" | "gelu" | "gelu_mlp"
    tie_embeddings: bool = False
    residual_scale: float = 1.0          # MiniCPM depth-scaled residuals
    logit_scale: float = 1.0             # MiniCPM mup-style logit scaling
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendStub] = None
    # multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0
    dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            object.__setattr__(
                self, "block_pattern", tuple(["attn"] * self.num_layers)
            )
        # user-supplied configuration is validated with real exceptions,
        # not asserts: it must fail loudly under ``python -O`` too
        if len(self.block_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: block_pattern len {len(self.block_pattern)} "
                f"!= num_layers {self.num_layers}")
        for b in self.block_pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block kind {b!r}")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: num_heads {self.num_heads} not divisible "
                f"by num_kv_heads {self.num_kv_heads}")

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    @property
    def attn_layer_ids(self) -> Tuple[int, ...]:
        return tuple(i for i, b in enumerate(self.block_pattern) if b == "attn")

    def layer_is_moe(self, i: int) -> bool:
        m = self.moe
        if m is None:
            return False
        if i < m.first_dense_layers:
            return False
        return i % m.moe_layer_period == m.moe_layer_offset

    # -- parameter counting (used for rooflines & memory estimates) ---------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count. active_only counts only routed
        experts that fire per token (top_k of num_experts)."""
        d, l = self.d_model, self.num_layers
        n = 2 * self.vocab_size * d if not self.tie_embeddings else self.vocab_size * d
        for i, blk in enumerate(self.block_pattern):
            n += 2 * d  # norms
            if blk == "attn":
                n += self._attn_params()
            elif blk == "mamba":
                n += self._mamba_params()
            elif blk in ("mlstm", "slstm"):
                n += self._xlstm_params(blk)
            if blk in ("mlstm", "slstm"):
                continue  # xLSTM blocks have no separate FFN (d_ff == 0)
            n += self._ffn_params(i, active_only)
        if self.encoder is not None:
            e = self.encoder
            per = 4 * e.d_model * e.d_model + 3 * e.d_model * e.d_ff + 2 * e.d_model
            n += e.num_layers * per
            # cross-attention in each decoder layer
            n += l * 4 * d * d
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention_kind == "mla":
            m = self.mla
            assert m is not None
            qk = m.qk_head_dim
            n = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d
            return n
        hd = self.head_dim
        return (
            d * self.num_heads * hd
            + 2 * d * self.num_kv_heads * hd
            + self.num_heads * hd * d
        )

    def _ffn_params(self, i: int, active_only: bool) -> int:
        d = self.d_model
        m = self.moe
        if m is None or not self.layer_is_moe(i):
            dff = self.d_ff
            if m is not None and i < m.first_dense_layers and m.first_dense_d_ff:
                dff = m.first_dense_d_ff
            if dff == 0:
                return 0
            mult = 3 if self.activation == "swiglu" else 2
            return mult * d * dff
        mult = 3 if self.activation == "swiglu" else 2
        n_experts = m.top_k if active_only else m.num_experts
        n = n_experts * mult * d * m.expert_d_ff + d * m.num_experts  # router
        if m.num_shared_experts:
            n += m.num_shared_experts * mult * d * (m.shared_d_ff or m.expert_d_ff)
        if m.dense_residual_d_ff:
            n += mult * d * m.dense_residual_d_ff
        return n

    def _mamba_params(self) -> int:
        mc = self.mamba or MambaConfig()
        d = self.d_model
        d_in = mc.expand * d
        dt_rank = mc.dt_rank or -(-d // 16)
        n = d * d_in * 2                     # in_proj (x and z)
        n += d_in * mc.d_conv                # conv1d
        n += d_in * (dt_rank + 2 * mc.d_state)  # x_proj
        n += dt_rank * d_in + d_in           # dt_proj
        n += d_in * mc.d_state + d_in        # A_log, D
        n += d_in * d                        # out_proj
        return n

    def _xlstm_params(self, kind: str) -> int:
        xc = self.xlstm or XLSTMConfig()
        d = self.d_model
        h = self.num_heads
        if kind == "mlstm":
            d_in = int(xc.proj_factor_mlstm * d)
            n = 2 * d * d_in                 # up-proj (x, z)
            n += 3 * d_in * d_in // h        # q,k,v headwise (block-diagonal)
            n += 3 * d_in                    # i,f,o gate projections (per-dim)
            n += d_in * mc_conv(xc)          # causal conv
            n += d_in * d                    # down proj
            return n
        d_in = int(xc.proj_factor_slstm * d)
        n = 4 * d * d // h + 4 * d * d       # recurrent (headwise) + input gates
        n += d * d_in * 2 + d_in * d         # gated FFN up/down
        return n

    # -- reduced variant for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant (<=2 layers, d_model<=512, <=4 experts)
        that runs a real forward/train step on CPU."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        head_dim = 64
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        if n_heads % n_kv:
            n_kv = 1
        n_layers = min(self.num_layers, 2)
        pattern = self._reduced_pattern(n_layers)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=128,
                shared_d_ff=128 if self.moe.num_shared_experts else 0,
                dense_residual_d_ff=128 if self.moe.dense_residual_d_ff else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                first_dense_d_ff=256 if self.moe.first_dense_d_ff else 0,
                moe_layer_period=1,
                moe_layer_offset=0,
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(
                q_lora_rank=64, kv_lora_rank=64,
                qk_nope_head_dim=32, qk_rope_head_dim=32, v_head_dim=64,
            )
            head_dim = 64
        encoder = None
        if self.encoder is not None:
            encoder = dataclasses.replace(
                self.encoder, num_layers=2, d_model=d_model,
                num_heads=n_heads, d_ff=256, max_source_positions=16,
            )
        frontend = None
        if self.frontend is not None:
            frontend = dataclasses.replace(
                self.frontend, num_prefix_tokens=8, embed_dim=d_model
            )
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            block_pattern=pattern,
            moe=moe,
            mla=mla,
            encoder=encoder,
            frontend=frontend,
            mtp_depth=0,
            dtype="float32",
        )

    def _reduced_pattern(self, n_layers: int) -> Tuple[str, ...]:
        kinds = []
        seen = []
        for b in self.block_pattern:  # keep one of each distinct kind, in order
            if b not in seen:
                seen.append(b)
        while len(kinds) < n_layers:
            kinds.extend(seen)
        return tuple(kinds[:n_layers])


def mc_conv(xc: XLSTMConfig) -> int:
    return xc.conv1d_kernel_size


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
