"""SeamlessM4T-large-v2 [arXiv:2308.11596] — encoder-decoder, multimodal
(speech/text). Backbone only: 24L text decoder (d=1024, 16H, d_ff=8192,
GeLU MLP) with cross-attention over a 24L encoder.

The speech frontend (mel-spectrogram + w2v-BERT conv feature extractor) is a
stub: ``input_specs()`` provides precomputed frame embeddings
(B, frames, 1024) consumed directly by the encoder stack.
"""
from repro.configs.base import EncoderConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    use_rope=False,  # learned/sinusoidal positions in the original; we use
                     # absolute sinusoidal embeddings for the backbone.
    abs_pos="sinusoidal",
    activation="gelu_mlp",
    encoder=EncoderConfig(
        num_layers=24, d_model=1024, num_heads=16, d_ff=8192,
        max_source_positions=4096,
    ),
    frontend=FrontendStub(kind="audio", num_prefix_tokens=4096, embed_dim=1024),
)
