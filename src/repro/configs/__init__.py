"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

The 10 assigned architectures plus the paper's own eval model (llama2-70b).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    INPUT_SHAPES,
    EncoderConfig,
    FrontendStub,
    InputShape,
    MambaConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    XLSTMConfig,
)

_ARCH_MODULES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "internvl2-1b": "internvl2_1b",
    "minicpm-2b": "minicpm_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "starcoder2-3b": "starcoder2_3b",
    "arctic-480b": "arctic_480b",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "starcoder2-7b": "starcoder2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama2-70b": "llama2_70b",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "llama2-70b")

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _cache:
        if arch not in _ARCH_MODULES:
            raise KeyError(
                f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}"
            )
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
        _cache[arch] = mod.CONFIG
    return _cache[arch]


def list_archs(include_extra: bool = False) -> List[str]:
    return list(_ARCH_MODULES) if include_extra else list(ASSIGNED_ARCHS)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
