"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention with a
1:7 attn:mamba interleave and 16-expert top-2 MoE on every other layer.

72 layers = 9 Jamba blocks of 8 layers; the attention layer sits at offset 4
of each block (as in the Jamba paper). MoE FFN on odd layers, dense FFN
(d_ff=24576 as assigned) on even layers.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

_N = 72
_PATTERN = tuple("attn" if i % 8 == 4 else "mamba" for i in range(_N))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=_N,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    use_rope=False,  # Jamba uses no positional embeddings (mamba provides order)
    block_pattern=_PATTERN,
    activation="swiglu",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        expert_d_ff=24576,
        moe_layer_period=2,
        moe_layer_offset=1,
    ),
)
