"""Llama-2-70B [arXiv:2307.09288] — the paper's own evaluation model
(AcceLLM §5.2). Used by the simulator and as an 11th selectable config."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-70b",
    family="dense",
    source="arXiv:2307.09288",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    activation="swiglu",
)
