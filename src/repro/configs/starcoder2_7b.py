"""StarCoder2-7B [arXiv:2402.19173] — dense, GQA kv=4, RoPE, native sliding
window 4096."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    activation="gelu",
)
