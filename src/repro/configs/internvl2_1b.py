"""InternVL2-1B [arXiv:2404.16821] — VLM: InternViT-300M (stubbed frontend)
feeding a Qwen2-0.5B-style LM backbone (24L, d=896, 14H GQA kv=2).

Per the carve-out, the vision encoder is a stub: ``input_specs()`` provides
precomputed patch embeddings of shape (B, num_prefix_tokens, embed_dim);
the projector (MLP embed_dim -> d_model) and LM backbone are implemented.
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    frontend=FrontendStub(kind="vision", num_prefix_tokens=256, embed_dim=1024),
)
