"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: every layer has a dense residual MLP in parallel with a 128-expert
top-2 MoE (expert d_ff = 4864)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_d_ff=4864,
        dense_residual_d_ff=4864,  # Arctic's dense residual MLP
    ),
)
