"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA kv=2, RoPE, native sliding
window 4096."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=999_999.0,
    sliding_window=4096,
    activation="gelu",  # starcoder2 uses gelu MLP (c_fc/c_proj)
)
