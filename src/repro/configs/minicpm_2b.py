"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense, MHA (kv=36), WSD
schedule (implemented in repro.training.schedules), mup-style depth-scaled
residuals and logit scaling."""
import math

from repro.configs.base import ModelConfig

_NUM_LAYERS = 40

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    num_layers=_NUM_LAYERS,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    activation="swiglu",
    tie_embeddings=True,
    # MiniCPM: residual branch scaled by 1.4/sqrt(num_layers); logits by
    # 1/(d_model / 256) (mup base width 256).
    residual_scale=1.4 / math.sqrt(_NUM_LAYERS),
    logit_scale=256.0 / 2304.0,
)
