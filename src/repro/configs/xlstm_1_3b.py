"""xLSTM-1.3B [arXiv:2405.04517] — attention-free SSM-style stack of mLSTM
(matrix memory) and sLSTM (scalar memory) blocks, ratio 7:1 (xLSTM[7:1]).
d_ff=0: blocks carry their own up/down projections, no separate FFN."""
from repro.configs.base import ModelConfig, XLSTMConfig

_N = 48
_XC = XLSTMConfig(slstm_every=8)
# xLSTM[7:1]: within each group of 8 blocks, one sLSTM (placed mid-group).
_PATTERN = tuple(
    "slstm" if i % _XC.slstm_every == _XC.slstm_every // 2 else "mlstm"
    for i in range(_N)
)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=_N,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,  # mLSTM head dim = d_inner / heads (set at block level)
    d_ff=0,
    vocab_size=50304,
    use_rope=False,
    block_pattern=_PATTERN,
    xlstm=_XC,
    tie_embeddings=True,
)
