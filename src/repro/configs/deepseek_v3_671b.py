"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA attention (latent KV),
1 shared + 256 routed experts top-8, first 3 layers dense, MTP head.

MLA means the serving state is the compressed latent c_kv (512) + rope key
(64) per token — ~14x smaller than full 128-head KV. This makes AcceLLM's
redundant-KV copies especially cheap (see DESIGN.md §4).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: all heads share the latent; kept for bookkeeping
    head_dim=128,
    d_ff=2048,         # routed expert intermediate size
    vocab_size=129280,
    attention_kind="mla",
    activation="swiglu",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        shared_d_ff=2048,
        first_dense_layers=3,
        first_dense_d_ff=18432,
    ),
    mtp_depth=1,
)
