"""Fleet events: *when* instances die, join, or drain.

The fleet analogue of :mod:`repro.workloads.arrivals` — a
:class:`FleetSchedule` yields monotonically non-decreasing fleet events
in abstract **time units** (one scheduling iteration on the live
executor, one modeled second in the simulator), drawn from a seeded
``numpy`` Generator so the identical event stream hits both backends.
Schedules come in the same three shapes as traffic: fixed instants
(:class:`FixedFleet`), a seeded stochastic process
(:class:`PoissonFailures` — exponential inter-failure gaps, the MTBF
model), and JSONL trace replay (:func:`load_fleet_trace`).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np


@dataclass(frozen=True)
class KillInstance:
    """Instance ``instance`` fails abruptly at ``t``: every byte of its
    serving state (primaries, replicas, prefill backlog) is lost."""
    t: float
    instance: int
    kind = "kill"


@dataclass(frozen=True)
class JoinInstance:
    """A fresh instance comes up at ``t``.  ``instance`` names a dead
    index to revive (replacement hardware at the same rank); ``None``
    appends a brand-new index — warm autoscaling."""
    t: float
    instance: Optional[int] = None
    kind = "join"


@dataclass(frozen=True)
class Drain:
    """Instance ``instance`` stops taking new work at ``t`` and leaves
    the fleet once its resident requests complete — graceful scale-down
    (the k8s cordon+drain shape)."""
    t: float
    instance: int
    kind = "drain"


@dataclass(frozen=True)
class DegradeInstance:
    """Instance ``instance`` turns into a straggler at ``t`` — still
    alive, still correct, just slow (thermal throttle, a noisy
    neighbor, a browned-out link).  ``factor`` scales its compute step
    times and ``link_factor`` its transfer times until a matching
    :class:`RecoverInstance` lands."""
    t: float
    instance: int
    factor: float = 4.0
    link_factor: float = 1.0
    kind = "degrade"


@dataclass(frozen=True)
class RecoverInstance:
    """Instance ``instance`` returns to full speed at ``t``."""
    t: float
    instance: int
    kind = "recover"


FleetEvent = Union[KillInstance, JoinInstance, Drain, DegradeInstance,
                   RecoverInstance]


class FleetSchedule:
    """Base class; subclasses implement :meth:`events`."""

    def events(self, rng: np.random.Generator) -> Iterator[FleetEvent]:
        raise NotImplementedError

    def stream(self, seed: int = 0) -> List[FleetEvent]:
        """The full event list for one run, time-sorted (stable, so
        same-instant events keep their emission order)."""
        evs = list(self.events(np.random.default_rng(seed)))
        return sorted(evs, key=lambda e: e.t)

    def describe(self) -> str:
        return f"fleet schedule: {self!r}"


@dataclass(frozen=True)
class FixedFleet(FleetSchedule):
    """A literal event list — the fleet analogue of ``TraceReplay``, and
    the deterministic form every other schedule reduces to via
    :meth:`FleetSchedule.stream`."""
    fleet_events: Tuple[FleetEvent, ...] = ()

    def events(self, rng):
        yield from self.fleet_events


@dataclass(frozen=True)
class PoissonFailures(FleetSchedule):
    """Seeded memoryless failures: exponential gaps with mean ``mtbf``
    over ``duration`` time units, each killing a uniformly chosen
    instance.  With ``recovery`` set, replacement hardware revives the
    same index ``recovery`` units after each kill (the kill/join churn
    of a preemptible fleet)."""
    mtbf: float
    duration: float
    n_instances: int
    recovery: Optional[float] = None

    def events(self, rng):
        t = 0.0
        while True:
            t += rng.exponential(self.mtbf)
            if t >= self.duration:
                return
            victim = int(rng.integers(self.n_instances))
            yield KillInstance(t, victim)
            if self.recovery is not None:
                yield JoinInstance(t + self.recovery, victim)


@dataclass(frozen=True)
class PoissonDegradations(FleetSchedule):
    """Seeded memoryless *partial* failures — the straggler analogue of
    :class:`PoissonFailures`.  Exponential gaps with mean ``mtbf`` over
    ``duration`` time units, each degrading a uniformly chosen instance
    by ``factor`` (and its links by ``link_factor``); with ``recovery``
    set the instance returns to full speed ``recovery`` units later."""
    mtbf: float
    duration: float
    n_instances: int
    recovery: Optional[float] = None
    factor: float = 4.0
    link_factor: float = 1.0

    def events(self, rng):
        t = 0.0
        while True:
            t += rng.exponential(self.mtbf)
            if t >= self.duration:
                return
            victim = int(rng.integers(self.n_instances))
            yield DegradeInstance(t, victim, self.factor, self.link_factor)
            if self.recovery is not None:
                yield RecoverInstance(t + self.recovery, victim)


# ---------------------------------------------------------------------------
# JSONL trace round-trip (mirrors repro.workloads.spec.save_trace)
# ---------------------------------------------------------------------------


def save_fleet_trace(path, events: Sequence[FleetEvent]) -> int:
    """Write a fleet event stream as JSONL ({t, event, instance} per
    line); returns the number of records written."""
    n = 0
    with open(path, "w") as fh:
        for ev in events:
            rec = {"t": ev.t, "event": ev.kind, "instance": ev.instance}
            if ev.kind == "degrade":
                rec["factor"] = ev.factor
                rec["link_factor"] = ev.link_factor
            fh.write(json.dumps(rec) + "\n")
            n += 1
    return n


def _parse_fleet_record(rec) -> FleetEvent:
    kinds = {"kill": KillInstance, "join": JoinInstance, "drain": Drain,
             "degrade": DegradeInstance, "recover": RecoverInstance}
    cls = kinds[rec["event"]]
    instance = rec.get("instance")
    if instance is not None:
        instance = int(instance)
    elif cls is not JoinInstance:
        raise ValueError(f"{rec['event']} event needs an instance")
    if cls is DegradeInstance:
        return cls(float(rec["t"]), instance,
                   float(rec.get("factor", 4.0)),
                   float(rec.get("link_factor", 1.0)))
    return cls(float(rec["t"]), instance)


@dataclass(frozen=True)
class FleetTraceReplay(FleetSchedule):
    """Streams fleet events straight off a JSONL trace file
    (``load_fleet_trace(path, stream=True)``) — the fleet analogue of
    ``TraceFileReplay``: each :meth:`events` call re-opens the file and
    yields one record at a time, never holding the trace in memory."""
    path: str

    def events(self, rng):
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                yield _parse_fleet_record(json.loads(line))


def load_fleet_trace(path, stream: bool = False):
    """Read a JSONL fleet trace back into a replayable schedule.  With
    ``stream=True`` the schedule replays lazily off the file
    (:class:`FleetTraceReplay`) instead of materializing an event tuple."""
    if stream:
        return FleetTraceReplay(str(path))
    events: List[FleetEvent] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            events.append(_parse_fleet_record(json.loads(line)))
    return FixedFleet(tuple(events))
