"""Fleet controller: one failover/scale decision layer, two executors.

The controller owns everything about a fleet event that is *not*
backend mechanics: pacing the schedule against the executor's clock
(:meth:`FleetController.due`), deciding which of a dead instance's
requests survive (:meth:`plan_failover`), and keeping the decision
trace + counters both backends must agree on (golden-trace fleet
tests compare ``controller.trace`` entry for entry, the same contract
``AcceLLMScheduler.trace`` carries for scheduling decisions).

The failover contract:

  * a resident primary whose replica lives on a usable instance is
    **promoted** there — the AcceLLM payoff: the warm copy becomes the
    primary via the existing ``PromoteReplica`` role-flip machinery,
    paying only the unsynced tail (``Promotion.lost_lines`` decode
    tokens are rolled back and re-generated, never the prompt);
  * a resident primary with no usable replica is **re-queued**: its
    lifecycle resets to ``QUEUED`` and the whole prompt re-prefills —
    what every baseline kernel pays for each resident request;
  * replicas *of other instances' primaries* hosted on the dead
    instance are dropped (the primary survives unmirrored until the
    kernel re-establishes redundancy).

Re-queued requests keep their original ``arrival`` stamp, so the
re-prefill shows up as the TTFT/SLO damage it really is, and they are
never re-submitted — each rid stays single-counted in
``sim.metrics.summarize`` / ``workloads.metrics.slo_summary``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.fleet.events import FleetEvent, FleetSchedule
from repro.serving.request import Phase


@dataclass(frozen=True)
class Promotion:
    """Promote the replica of ``rid`` (on ``dst``) to primary after
    ``src`` died; the replica was synced to ``synced`` of the primary's
    ``lines``."""
    rid: int
    src: int
    dst: int
    synced: int
    lines: int

    @property
    def lost_lines(self) -> int:
        """Decode tokens beyond the replica's synced mark — rolled back
        and re-generated on the promoted copy."""
        return max(0, self.lines - self.synced)


@dataclass
class FailoverPlan:
    """What survives instance ``dead``: deterministic (rid-sorted), so
    both executors apply the identical plan in the identical order."""
    dead: int
    promotions: List[Promotion] = field(default_factory=list)
    requeues: List[int] = field(default_factory=list)
    dropped_replicas: List[int] = field(default_factory=list)


class FleetController:
    """Paces a :class:`FleetSchedule` against an executor's clock and
    records the fleet decisions both backends must share."""

    STATS = ("kills", "joins", "drains", "promotions", "requeues",
             "requeue_backlog", "reprefill_tokens", "lost_lines",
             "lost_decode_tokens", "warm_streams",
             "degrades", "recoveries", "hedges", "sheds", "aborts")

    def __init__(self, schedule: Optional[FleetSchedule] = None,
                 seed: int = 0):
        self.schedule = schedule
        self.events: List[FleetEvent] = (
            schedule.stream(seed) if schedule is not None else [])
        self._next = 0
        #: decision log, compared entry-for-entry live-vs-sim
        self.trace: List[tuple] = []
        self.stats = {k: 0 for k in self.STATS}

    def note(self, *entry):
        self.trace.append(entry)

    def due(self, now: float) -> List[FleetEvent]:
        """Events whose time has come on the caller's clock (consumed —
        each event fires exactly once)."""
        out: List[FleetEvent] = []
        while self._next < len(self.events) \
                and self.events[self._next].t <= now:
            out.append(self.events[self._next])
            self._next += 1
        return out

    def exhausted(self) -> bool:
        return self._next >= len(self.events)

    def drain_all(self) -> List[FleetEvent]:
        """Hand the whole remaining stream to an event-heap executor
        (the simulator schedules fleet events as heap entries instead of
        polling :meth:`due` each iteration); marks them consumed."""
        out = self.events[self._next:]
        self._next = len(self.events)
        return out

    def next_time(self) -> Optional[float]:
        """Time of the next unfired event (None when exhausted) — the
        executors' fused-decode bound: a multi-iteration scan must not
        run past a fleet event."""
        if self._next >= len(self.events):
            return None
        return self.events[self._next].t

    # -- the shared failover decision ---------------------------------------
    def plan_failover(self, cluster_view, dead: int) -> FailoverPlan:
        """Split instance ``dead``'s resident requests into promotions
        (usable replica exists) and re-queues (state truly lost), from
        the same :class:`~repro.scheduling.views.ClusterView` protocol
        the scheduling kernels read — so live engines and the simulator
        produce the identical plan."""
        insts = cluster_view.instances()
        plan = FailoverPlan(dead=dead)
        dead_lines = insts[dead].request_lines()
        synced_of: dict = {}
        for rid, (primary, replica) in sorted(
                cluster_view.placements().items()):
            if primary == dead:
                target = None
                if replica is not None and replica != dead:
                    rv = insts[replica]
                    if rv.alive() and not rv.draining():
                        target = replica
                if target is None:
                    plan.requeues.append(rid)
                    continue
                if target not in synced_of:
                    synced_of[target] = insts[target].replica_synced()
                lines = dead_lines.get(rid, 0)
                plan.promotions.append(Promotion(
                    rid=rid, src=dead, dst=target,
                    synced=synced_of[target].get(rid, 0), lines=lines))
            elif replica == dead:
                plan.dropped_replicas.append(rid)
        return plan


# ---------------------------------------------------------------------------
# request lifecycle helpers shared by both executors
# ---------------------------------------------------------------------------


def reset_for_reprefill(req) -> int:
    """Roll a request all the way back to un-prefilled (its state died
    with its instance); returns the prompt tokens that must re-run.
    The original ``arrival`` stamp is kept on purpose: the re-prefill
    is TTFT/SLO damage, not a fresh request."""
    req.phase = Phase.QUEUED
    req.generated = 0
    req.output_tokens.clear()
    req.token_times.clear()
    req.first_token_time = None
    return req.prompt_len


def rollback_tokens(req, lost: int):
    """Roll a promoted request back to its replica's synced line: the
    last ``lost`` decode tokens were never mirrored and re-generate on
    the promoted copy."""
    if lost <= 0:
        return
    req.generated = max(0, req.generated - lost)
    del req.output_tokens[len(req.output_tokens) - min(
        lost, len(req.output_tokens)):]
    del req.token_times[len(req.token_times) - min(
        lost, len(req.token_times)):]
