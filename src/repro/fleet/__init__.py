"""Fleet layer: one deterministic event schedule, two executors.

``FleetSchedule`` (fixed / Poisson-MTBF / JSONL replay) yields the
identical kill/join/drain stream for the live cluster's iteration clock
and the simulator's modeled seconds; ``FleetController`` paces it,
plans failover from the shared scheduling views, and records the
decision trace both backends must agree on.
"""
from repro.fleet.controller import (FailoverPlan, FleetController, Promotion,
                                    reset_for_reprefill, rollback_tokens)
from repro.fleet.events import (DegradeInstance, Drain, FixedFleet,
                                FleetEvent, FleetSchedule, FleetTraceReplay,
                                JoinInstance, KillInstance,
                                PoissonDegradations, PoissonFailures,
                                RecoverInstance, load_fleet_trace,
                                save_fleet_trace)

__all__ = [
    "KillInstance", "JoinInstance", "Drain", "DegradeInstance",
    "RecoverInstance", "FleetEvent",
    "FleetSchedule", "FixedFleet", "FleetTraceReplay", "PoissonFailures",
    "PoissonDegradations",
    "save_fleet_trace", "load_fleet_trace",
    "FleetController", "FailoverPlan", "Promotion",
    "reset_for_reprefill", "rollback_tokens",
]
