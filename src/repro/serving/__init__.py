from repro.serving.engine import InstanceEngine
from repro.serving.request import Phase, Request
from repro.serving.sampling import sample

__all__ = ["InstanceEngine", "Request", "Phase", "sample"]
