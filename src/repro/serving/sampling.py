"""Token sampling.

Decode determinism contract (ISSUE 5): every sampling site — single
prefill, padded batched prefill, chunk resume, dense decode and the
fused paged decode scan — draws exactly ONE subkey per iteration from
the engine key (:func:`decode_keys`) and then derives a per-request key
by folding in the request's *slot* (:func:`sample_slots`).  The sampled
token for a slot therefore depends only on (iteration, slot), never on
how the batch happens to be composed — compacted vs full-batch decode,
fused vs sequential steps, and live-vs-sim golden traces all stay
token-identical when batch membership changes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """logits (B, V) -> (B,) int32.  One key for the whole batch — the
    drawn tokens depend on batch composition; prefer
    :func:`sample_slots` anywhere batches can be compacted."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(logits: jax.Array, key, slots: jax.Array,
                 temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """Per-slot sampling: row i draws with ``fold_in(key, slots[i])``.

    logits (B, V), slots (B,) int32 -> (B,) int32.  Because each row's
    randomness is keyed by its slot (not its row index), the token drawn
    for a slot is invariant to batch compaction — a decode batch holding
    only the active primary slots samples exactly what the full-batch
    path would have sampled at those slots."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -1e30, logits)
    keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(slots)
    return jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg))(logits, keys
                                                     ).astype(jnp.int32)


def decode_keys(key, steps: int) -> Tuple[list, jax.Array]:
    """Split ``key`` exactly as ``steps`` sequential decode iterations
    would (one split per iteration); returns ``(chain, subs)`` where
    ``chain[i]`` is the engine-key state after ``i`` splits
    (``chain[-1]`` = fully advanced) and ``subs`` is stacked
    ``(steps, ...)`` for a ``lax.scan``.  The chain lets a fused span
    that ends early (EOS emptied the batch after ``ran < steps``
    iterations) leave the engine key at ``chain[ran]`` — the state the
    per-step path would have reached, since sequential decode stops
    splitting once the batch is empty.  That keeps fused and sequential
    token streams bit-identical across request boundaries too."""
    chain = [key]
    subs = []
    for _ in range(steps):
        key, sub = jax.random.split(key)
        chain.append(key)
        subs.append(sub)
    return chain, jnp.stack(subs)
