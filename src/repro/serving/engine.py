"""InstanceEngine: the real-execution serving instance.

One engine = one AcceLLM *instance* (paper: 4 accelerators under TP; here:
a JAX device set / submesh, or a single CPU device in the examples). It owns

  * the model params (full replica per instance — AcceLLM §4.2),
  * a slot-based continuous batch: fixed ``num_slots`` requests in flight,
  * a :class:`repro.kvstore.PagedStore` holding the serving state (KV
    caches / SSM states) for all slots behind a block-table ledger,
  * per-slot clocks (lengths) — decode runs with per-request ``t``.

Redundancy primitives used by the AcceLLM core:
  export_slot / import_slot    — whole per-request state; ``export_stream``
                                 yields it as per-layer chunks (prefill-time
                                 KV streaming; on a TPU mesh this is the
                                 per-layer ppermute described in DESIGN.md §3)
  sync_replica_from            — the per-decode-step mirror update: ONLY the
                                 new KV lines since the replica's synced
                                 mark move (constant-size state copy for
                                 SSMs) — O(delta), not O(kv_capacity)

All line/byte accounting (primaries AND replicas) flows through the
store's ledger, the same arithmetic the simulator's ``SimStore`` runs.

The engine never batches prefill with decode (AcceLLM §4.2.3: vLLM modified
so prefill and decode are never co-scheduled on one instance).
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kvstore import PagedStore
from repro.models import decode_step, init_state, prefill
from repro.models.state import state_bytes
from repro.serving.request import Phase, Request
from repro.serving.sampling import sample


class InstanceEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 kv_capacity: int, instance_id: int = 0,
                 temperature: float = 0.0, eos_token: Optional[int] = None,
                 seed: int = 0, block_lines: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.kv_capacity = kv_capacity
        self.instance_id = instance_id
        self.temperature = temperature
        self.eos_token = eos_token
        self.store = PagedStore(cfg, num_slots, kv_capacity,
                                block_lines=block_lines)
        self.lengths = np.zeros((num_slots,), np.int32)
        self.last_tokens = np.zeros((num_slots,), np.int32)
        self.slot_req: Dict[int, Request] = {}
        # replica slots: requests whose primary lives on the paired instance
        self.replica_of: Dict[int, Tuple[int, int]] = {}  # slot -> (inst, slot)
        self._key = jax.random.PRNGKey(seed + instance_id)
        self._jit_decode = jax.jit(
            functools.partial(decode_step, cfg), donate_argnums=(2,))
        self._jit_prefill = jax.jit(functools.partial(prefill, cfg))

    @property
    def state(self):
        return self.store.state

    @state.setter
    def state(self, value):
        self.store.state = value

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        used = set(self.slot_req) | set(self.replica_of)
        return [s for s in range(self.num_slots) if s not in used]

    def active_slots(self) -> List[int]:
        return sorted(self.slot_req)

    @property
    def batch_size(self) -> int:
        return len(self.slot_req)

    def primary_kv_tokens(self) -> int:
        return int(sum(self.store.lines(r.rid)
                       for r in self.slot_req.values()))

    def replica_kv_tokens(self) -> int:
        return int(sum(self.store.lines(self.store.slot_rid[s])
                       for s in self.replica_of))

    def total_kv_tokens(self) -> int:
        """KV lines resident on this instance — primaries AND replicas
        (replica bytes are real HBM; the balancer must see them)."""
        return self.primary_kv_tokens() + self.replica_kv_tokens()

    def state_bytes(self) -> int:
        """Physical bytes of the allocated state arrays."""
        return state_bytes(self.store.state)

    def used_bytes(self) -> float:
        """Ledger bytes of resident requests (primaries + replicas)."""
        return self.store.used_bytes()

    def free_blocks(self) -> int:
        return self.store.free_blocks()

    def _rid_at(self, slot: int) -> int:
        return self.store.slot_rid[slot]

    # -- prefill --------------------------------------------------------------
    def prefill_request(self, req: Request, extra: Optional[dict] = None
                        ) -> int:
        """Run the prompt through the model into a free slot; returns the
        slot."""
        free = self.free_slots()
        assert free, f"instance {self.instance_id} has no free slot"
        slot = free[0]
        batch = {"tokens": req.prompt_tokens}
        if extra:
            batch.update(extra)
        fresh = init_state(self.cfg, 1, self.kv_capacity)
        logits, fresh = self._jit_prefill(self.params, batch, fresh)
        self._key, sub = jax.random.split(self._key)
        tok = int(sample(logits, sub, self.temperature)[0])
        self.store.merge_slot(slot, fresh)
        self.lengths[slot] = req.prompt_len
        self.last_tokens[slot] = tok
        self.slot_req[slot] = req
        req.phase = Phase.DECODE
        req.generated += 1
        req.output_tokens.append(tok)
        # ledger: prompt lines + the reserved line for the sampled token
        self.store.alloc(req.rid, slot, lines=req.total_len)
        return slot

    # -- decode ----------------------------------------------------------------
    def decode(self) -> Dict[int, int]:
        """One decode iteration over all active slots; returns slot->token."""
        if not self.slot_req:
            return {}
        tokens = jnp.asarray(self.last_tokens)[:, None]
        t = jnp.asarray(self.lengths)
        logits, self.store.state = self._jit_decode(
            self.params, tokens, self.store.state, t)
        self._key, sub = jax.random.split(self._key)
        next_tokens = np.asarray(sample(logits, sub, self.temperature))
        out = {}
        for slot, req in list(self.slot_req.items()):
            tok = int(next_tokens[slot])
            self.lengths[slot] += 1
            self.last_tokens[slot] = tok
            req.generated += 1
            req.output_tokens.append(tok)
            self.store.append_line(req.rid)
            out[slot] = tok
            if req.done or (self.eos_token is not None
                            and tok == self.eos_token):
                req.phase = Phase.DONE
                self.release(slot)
        return out

    # -- slot management --------------------------------------------------------
    def release(self, slot: int) -> int:
        """Free the slot; returns the number of blocks returned to the
        pool."""
        self.slot_req.pop(slot, None)
        self.replica_of.pop(slot, None)
        freed = self.store.free_slot(slot)
        self.lengths[slot] = 0
        return freed

    # -- redundancy primitives ---------------------------------------------------
    def export_slot(self, slot: int):
        """Per-request state + clocks, for replication to the pair
        partner (whole-state form; :meth:`export_stream` is the
        per-layer-chunk form a real mesh overlaps with prefill)."""
        return (self.store.extract_slot(slot), int(self.lengths[slot]),
                int(self.last_tokens[slot]), self.store.lines(self._rid_at(slot)))

    def export_stream(self, slot: int):
        """Per-layer streamed export: ``(chunk_iter, length, last_token,
        lines)``."""
        return (self.store.stream_slot(slot), int(self.lengths[slot]),
                int(self.last_tokens[slot]),
                self.store.lines(self._rid_at(slot)))

    def import_slot(self, slot: int, exported, req: Request,
                    as_replica_of: Optional[Tuple[int, int]] = None):
        sub_state, length, last_tok, lines = exported
        self.store.alloc(req.rid, slot, lines=lines)
        self.store.merge_slot(slot, sub_state)
        self._install(slot, length, last_tok, req, as_replica_of)

    def import_stream(self, slot: int, chunks: Iterable, length: int,
                      last_tok: int, lines: int, req: Request,
                      as_replica_of: Optional[Tuple[int, int]] = None):
        """Install a per-layer streamed export chunk by chunk."""
        self.store.alloc(req.rid, slot, lines=lines)
        for path, chunk in chunks:
            self.store.import_chunk(slot, path, chunk)
        self._install(slot, length, last_tok, req, as_replica_of)

    def _install(self, slot: int, length: int, last_tok: int, req: Request,
                 as_replica_of: Optional[Tuple[int, int]]):
        self.lengths[slot] = length
        self.last_tokens[slot] = last_tok
        if as_replica_of is not None:
            self.replica_of[slot] = as_replica_of
        else:
            self.slot_req[slot] = req

    def promote_replica(self, slot: int, req: Request):
        """Instant role-flip enabled by redundancy (AcceLLM §4.1.2): a
        replica slot becomes the primary with zero data movement."""
        assert slot in self.replica_of
        del self.replica_of[slot]
        self.slot_req[slot] = req
        self.store.mark_synced(req.rid)

    def demote_to_replica(self, slot: int, of: Tuple[int, int]):
        assert slot in self.slot_req
        rid = self.slot_req[slot].rid
        del self.slot_req[slot]
        self.replica_of[slot] = of
        # an ex-primary's copy is current by definition
        self.store.mark_synced(rid)

    def sync_replica_from(self, src: "InstanceEngine", src_slot: int,
                          dst_slot: int, from_line: Optional[int] = None,
                          to_line: Optional[int] = None) -> float:
        """Mirror the partner's newly generated KV line(s) into our
        replica slot (AcceLLM §4.1.2 'newly computed KV cache lines are
        transferred back'): copies ONLY lines ``[from_line, to_line)``
        (default: our ledger's synced mark up to the primary's current
        lines) plus the constant-size recurrent states.  Returns the
        bytes moved — one KV line per decode step in steady state."""
        rid = src._rid_at(src_slot)
        if to_line is None:
            to_line = src.store.lines(rid)
        if from_line is None:
            from_line = self.store.synced_line(rid)
        moved = self.store.copy_lines(src.store, src_slot, dst_slot,
                                      from_line, to_line)
        self.lengths[dst_slot] = src.lengths[src_slot]
        self.last_tokens[dst_slot] = src.last_tokens[src_slot]
        self.store.set_lines(rid, to_line)
        self.store.mark_synced(rid, to_line)
        return moved
