"""InstanceEngine: the real-execution serving instance.

One engine = one AcceLLM *instance* (paper: 4 accelerators under TP; here:
a JAX device set / submesh, or a single CPU device in the examples). It owns

  * the model params (full replica per instance — AcceLLM §4.2),
  * a slot-based continuous batch: fixed ``num_slots`` requests in flight,
  * the serving state (KV caches / SSM states) for all slots,
  * per-slot clocks (lengths) — decode runs with per-request ``t``.

Redundancy primitives used by the AcceLLM core:
  export_slot / import_slot  — whole per-request state (prefill-time KV
                               streaming; on a TPU mesh this is the
                               per-layer ppermute described in DESIGN.md §3)
  copy_kv_line               — the per-decode-step mirror update of one new
                               KV line (constant-size state copy for SSMs)

The engine never batches prefill with decode (AcceLLM §4.2.3: vLLM modified
so prefill and decode are never co-scheduled on one instance).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_state, prefill
from repro.models.state import state_bytes
from repro.serving.request import Phase, Request
from repro.serving.sampling import sample


def _merge_slot(dst, src, slot: int, src_slot: int = 0):
    """Copy src's per-request state (batch dim 1 at index src_slot) into
    dst's batch dim at index ``slot``. Batch is dim 1 for layer states
    (dim 0 is the segment repeat dim) and dim 0 for ``enc_out``."""

    def merge_layers(d, s):
        return d.at[:, slot].set(s[:, src_slot])

    out = dict(dst)
    out["layers"] = jax.tree_util.tree_map(merge_layers, dst["layers"],
                                           src["layers"])
    if "enc_out" in dst:
        out["enc_out"] = dst["enc_out"].at[slot].set(src["enc_out"][src_slot])
    return out


def _extract_slot(state, slot: int):
    def ex(a):
        return a[:, slot: slot + 1]
    out = {"layers": jax.tree_util.tree_map(ex, state["layers"])}
    if "enc_out" in state:
        out["enc_out"] = state["enc_out"][slot: slot + 1]
    return out


class InstanceEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 kv_capacity: int, instance_id: int = 0,
                 temperature: float = 0.0, eos_token: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.kv_capacity = kv_capacity
        self.instance_id = instance_id
        self.temperature = temperature
        self.eos_token = eos_token
        self.state = init_state(cfg, num_slots, kv_capacity)
        self.lengths = np.zeros((num_slots,), np.int32)
        self.last_tokens = np.zeros((num_slots,), np.int32)
        self.slot_req: Dict[int, Request] = {}
        # replica slots: requests whose primary lives on the paired instance
        self.replica_of: Dict[int, Tuple[int, int]] = {}  # slot -> (inst, slot)
        self._key = jax.random.PRNGKey(seed + instance_id)
        self._jit_decode = jax.jit(
            functools.partial(decode_step, cfg), donate_argnums=(2,))
        self._jit_prefill = jax.jit(functools.partial(prefill, cfg))

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        used = set(self.slot_req) | set(self.replica_of)
        return [s for s in range(self.num_slots) if s not in used]

    def active_slots(self) -> List[int]:
        return sorted(self.slot_req)

    @property
    def batch_size(self) -> int:
        return len(self.slot_req)

    def total_kv_tokens(self) -> int:
        return int(sum(self.lengths[s] for s in self.slot_req))

    def state_bytes(self) -> int:
        return state_bytes(self.state)

    # -- prefill --------------------------------------------------------------
    def prefill_request(self, req: Request, extra: Optional[dict] = None
                        ) -> int:
        """Run the prompt through the model into a free slot; returns the
        first generated token."""
        free = self.free_slots()
        assert free, f"instance {self.instance_id} has no free slot"
        slot = free[0]
        batch = {"tokens": req.prompt_tokens}
        if extra:
            batch.update(extra)
        fresh = init_state(self.cfg, 1, self.kv_capacity)
        logits, fresh = self._jit_prefill(self.params, batch, fresh)
        self._key, sub = jax.random.split(self._key)
        tok = int(sample(logits, sub, self.temperature)[0])
        self.state = _merge_slot(self.state, fresh, slot)
        self.lengths[slot] = req.prompt_len
        self.last_tokens[slot] = tok
        self.slot_req[slot] = req
        req.phase = Phase.DECODE
        req.generated += 1
        req.output_tokens.append(tok)
        return slot

    # -- decode ----------------------------------------------------------------
    def decode(self) -> Dict[int, int]:
        """One decode iteration over all active slots; returns slot->token."""
        if not self.slot_req:
            return {}
        tokens = jnp.asarray(self.last_tokens)[:, None]
        t = jnp.asarray(self.lengths)
        logits, self.state = self._jit_decode(self.params, tokens, self.state, t)
        self._key, sub = jax.random.split(self._key)
        next_tokens = np.asarray(sample(logits, sub, self.temperature))
        out = {}
        for slot, req in list(self.slot_req.items()):
            tok = int(next_tokens[slot])
            self.lengths[slot] += 1
            self.last_tokens[slot] = tok
            req.generated += 1
            req.output_tokens.append(tok)
            out[slot] = tok
            if req.done or (self.eos_token is not None
                            and tok == self.eos_token):
                req.phase = Phase.DONE
                self.release(slot)
        return out

    # -- slot management --------------------------------------------------------
    def release(self, slot: int):
        self.slot_req.pop(slot, None)
        self.replica_of.pop(slot, None)
        self.lengths[slot] = 0

    # -- redundancy primitives ---------------------------------------------------
    def export_slot(self, slot: int):
        """Per-request state + clock, for replication to the pair partner.
        On a TPU mesh this is the per-layer KV stream (ppermute) described
        in DESIGN.md §3 — here it is a device-to-device state copy."""
        return (_extract_slot(self.state, slot), int(self.lengths[slot]),
                int(self.last_tokens[slot]))

    def import_slot(self, slot: int, exported, req: Request,
                    as_replica_of: Optional[Tuple[int, int]] = None):
        sub_state, length, last_tok = exported
        self.state = _merge_slot(self.state, sub_state, slot)
        self.lengths[slot] = length
        self.last_tokens[slot] = last_tok
        if as_replica_of is not None:
            self.replica_of[slot] = as_replica_of
        else:
            self.slot_req[slot] = req

    def promote_replica(self, slot: int, req: Request):
        """Instant role-flip enabled by redundancy (AcceLLM §4.1.2): a
        replica slot becomes the primary with zero data movement."""
        assert slot in self.replica_of
        del self.replica_of[slot]
        self.slot_req[slot] = req

    def demote_to_replica(self, slot: int, of: Tuple[int, int]):
        assert slot in self.slot_req
        del self.slot_req[slot]
        self.replica_of[slot] = of

    def sync_replica_from(self, src: "InstanceEngine", src_slot: int,
                          dst_slot: int):
        """Mirror the partner's newly generated KV line(s) into our replica
        slot (AcceLLM §4.1.2 'newly computed KV cache lines are transferred
        back'). Implemented as a per-slot state copy; the traffic this
        stands for is one KV line (or one constant-size SSM state)."""
        exported = src.export_slot(src_slot)
        self.state = _merge_slot(self.state, exported[0], dst_slot)
        self.lengths[dst_slot] = exported[1]
        self.last_tokens[dst_slot] = exported[2]
