"""InstanceEngine: the real-execution serving instance.

One engine = one AcceLLM *instance* (paper: 4 accelerators under TP; here:
a JAX device set / submesh, or a single CPU device in the examples). It owns

  * the model params (full replica per instance — AcceLLM §4.2),
  * a slot-based continuous batch: fixed ``num_slots`` requests in flight,
  * a :class:`repro.kvstore.PagedStore` holding the serving state (KV
    caches / SSM states) for all slots behind a block-table ledger,
  * per-slot clocks (lengths) — decode runs with per-request ``t``.

Redundancy primitives used by the AcceLLM core:
  export_slot / import_slot    — whole per-request state; ``export_stream``
                                 yields it as per-layer chunks (prefill-time
                                 KV streaming; on a TPU mesh this is the
                                 per-layer ppermute described in DESIGN.md §3)
  sync_replica_from            — the per-decode-step mirror update: ONLY the
                                 new KV lines since the replica's synced
                                 mark move (constant-size state copy for
                                 SSMs) — O(delta), not O(kv_capacity)

All line/byte accounting (primaries AND replicas) flows through the
store's ledger, the same arithmetic the simulator's ``SimStore`` runs.

The engine never batches prefill with decode (AcceLLM §4.2.3: vLLM modified
so prefill and decode are never co-scheduled on one instance).
"""
from __future__ import annotations

import contextlib
import functools
from typing import (TYPE_CHECKING, Dict, Iterable, List, Mapping, Optional,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kvstore import PagedStore
from repro.models import (decode_multi, decode_step, init_state, prefill,
                          prefill_batched, prefill_chunk)
from repro.models.state import state_bytes
from repro.serving.request import Phase, Request
from repro.serving.sampling import decode_keys, sample_slots

if TYPE_CHECKING:  # runtime import is lazy: stepplan -> ... -> engine cycle
    from repro.stepplan import (DecodePlan, PrefillItem,  # noqa: F401
                                PrefillPlan)


class InstanceEngine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int,
                 kv_capacity: int, instance_id: int = 0,
                 temperature: float = 0.0, eos_token: Optional[int] = None,
                 seed: int = 0, block_lines: Optional[int] = None,
                 paged_decode: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefix_cache_blocks: Optional[int] = None,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.kv_capacity = kv_capacity
        self.instance_id = instance_id
        self.temperature = temperature
        self.eos_token = eos_token
        self.store = PagedStore(cfg, num_slots, kv_capacity,
                                block_lines=block_lines)
        #: mesh slice backing this instance (repro.meshserve.MeshSlice):
        #: params and the KV pool are committed to its devices and every
        #: model dispatch runs under its sharding context — tensor
        #: parallelism within the instance, with redundancy traffic to
        #: other instances riding the cross-slice collectives.  ``None``
        #: keeps the seed single-device behavior.
        self.mesh = mesh
        if mesh is not None:
            from repro.meshserve import shard_params, shard_store
            self.params = shard_params(cfg, params, mesh)
            shard_store(self.store, mesh)
            self._model_axis = mesh.model_axis_for(cfg)
        self.lengths = np.zeros((num_slots,), np.int32)
        self.last_tokens = np.zeros((num_slots,), np.int32)
        self.slot_req: Dict[int, Request] = {}
        # replica slots: requests whose primary lives on the paired instance
        self.replica_of: Dict[int, Tuple[int, int]] = {}  # slot -> (inst, slot)
        # slots mid-chunked-prefill: occupied, but not yet decoding
        self.prefilling: Dict[int, Request] = {}
        self._key = jax.random.PRNGKey(seed + instance_id)
        #: device->host materializations on the decode path (the sync the
        #: fused scan amortizes: 1/token dense-per-step vs 1/plan fused)
        self.host_syncs = 0
        #: uploaded decode block tables, keyed by (resident rids, block
        #: bucket) — slot-affine tables are growth-stable, so they only
        #: rebuild when batch membership or the bucket changes
        self._tables_cache: Optional[Tuple[tuple, jnp.ndarray]] = None
        self._jit_decode = jax.jit(
            functools.partial(decode_step, cfg), donate_argnums=(2,))
        self._jit_prefill = jax.jit(functools.partial(prefill, cfg))
        # bucketed batched prefill: one compile per (batch, bucket) shape
        self._jit_prefill_batched = jax.jit(
            functools.partial(prefill_batched, cfg))
        # chunk resume: `history` is the static cursor
        self._jit_prefill_chunk = jax.jit(
            functools.partial(prefill_chunk, cfg),
            static_argnames=("history",))
        # the padded batched path and chunk resume need every KV row to
        # be maskable by the decode clock — attention-only decoder stacks
        self._attn_only = (all(b == "attn" for b in cfg.block_pattern)
                           and not cfg.is_encoder_decoder
                           and cfg.frontend is None)
        if paged_decode is None:
            paged_decode = self.supports_paged_decode
        #: decode through the block-table gather kernel with the batch
        #: compacted to active primary slots (vs the dense full-window,
        #: full-batch oracle path)
        self.use_paged_decode = paged_decode and self.supports_paged_decode
        #: radix prefix cache over the store's ledger (suffix-only
        #: prefill rides the chunk path, so attention-only stacks only)
        self.prefix_cache = None
        if prefix_cache and self.supports_chunked_prefill:
            from repro.prefixcache import PrefixCache
            if prefix_cache_blocks is None:
                prefix_cache_blocks = (num_slots
                                       * self.store.line_blocks_per_slot) // 2
            self.prefix_cache = PrefixCache(
                self.store.ledger, capacity_blocks=prefix_cache_blocks)
        #: pinned hit runs awaiting their prefill's first chunk
        self._hit_runs: Dict[int, List[int]] = {}
        # fused multi-step decode: compiles per (batch, table, steps)
        # shape; eos/temperature are baked in as compile-time constants
        self._jit_decode_multi = jax.jit(
            functools.partial(
                decode_multi, cfg, block_lines=self.store.block_lines,
                temperature=temperature,
                eos_token=-1 if eos_token is None else eos_token),
            donate_argnums=(2,))

    @property
    def supports_chunked_prefill(self) -> bool:
        """Whether this engine can resume a prompt mid-chunk (recurrent
        state continuation across chunks is not implemented)."""
        return self._attn_only

    @property
    def supports_paged_decode(self) -> bool:
        """Paged decode gathers per-head K/V line blocks: attention-only
        decoder stacks with GQA attention (MLA decodes through the
        absorbed latent path; recurrent blocks carry no line-indexed
        cache to gather)."""
        return self._attn_only and self.cfg.attention_kind == "gqa"

    @property
    def state(self):
        return self.store.state

    @state.setter
    def state(self, value):
        self.store.state = value

    def _mesh_ctx(self):
        """Sharding context for this engine's model dispatches.  On a
        mesh slice the trace-time constraints bind to the slice's mesh
        (no batch axis — a serving batch stays whole per instance, only
        heads split); single-device engines get a no-op.  The jits are
        per-engine, so each traces exactly once under its own slice."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro import sharding
        return sharding.use_mesh(self.mesh.mesh, batch_axes=(),
                                 model_axis=self._model_axis)

    # -- capacity ------------------------------------------------------------
    def free_slots(self) -> List[int]:
        """Slots usable for a fresh admission: unoccupied AND with an
        allocatable own block region.  A released slot whose blocks live
        on under the prefix cache still counts (``_clean_slot`` evicts
        those entries on take); one kept alive by another table's shared
        reference does not — its rows are live data."""
        used = (set(self.slot_req) | set(self.replica_of)
                | set(self.prefilling))
        out = []
        cached = (set(self.prefix_cache.index.blocks())
                  if self.prefix_cache is not None else set())
        pinned = (self.prefix_cache.pinned()
                  if self.prefix_cache is not None else set())
        for s in range(self.num_slots):
            if s in used:
                continue
            held = self.store.slot_used_blocks(s)
            if all(self.store.ledger.refcount(b) == 1 and b in cached
                   and b not in pinned for b in held):
                out.append(s)
        return out

    def active_slots(self) -> List[int]:
        return sorted(self.slot_req)

    @property
    def batch_size(self) -> int:
        return len(self.slot_req)

    def primary_kv_tokens(self) -> int:
        return int(sum(self.store.lines(r.rid)
                       for r in self.slot_req.values()))

    def replica_kv_tokens(self) -> int:
        return int(sum(self.store.lines(self.store.slot_rid[s])
                       for s in self.replica_of))

    def total_kv_tokens(self) -> int:
        """KV lines resident on this instance — primaries AND replicas
        (replica bytes are real HBM; the balancer must see them)."""
        return self.primary_kv_tokens() + self.replica_kv_tokens()

    def state_bytes(self) -> int:
        """Physical bytes of the allocated state arrays."""
        return state_bytes(self.store.state)

    def used_bytes(self) -> float:
        """Ledger bytes of resident requests (primaries + replicas)."""
        return self.store.used_bytes()

    def free_blocks(self) -> int:
        return self.store.free_blocks()

    def _rid_at(self, slot: int) -> int:
        return self.store.slot_rid[slot]

    # -- prefix cache ----------------------------------------------------------
    def _prefix_key(self, req: Request) -> List[int]:
        """Radix key for ``req``'s shareable prompt head: real token ids,
        trimmed to the block-aligned usable hit length.  Empty when the
        request declares no sharing (the index only ever sees declared
        prefixes, exactly like the token-free simulator's)."""
        if (self.prefix_cache is None or req.prefix_id is None
                or req.extra or req.prompt_tokens is None):
            return []
        from repro.prefixcache import aligned_hit_lines
        n = aligned_hit_lines(req.prefix_len, req.prompt_len,
                              self.store.block_lines)
        if n <= 0:
            return []
        return [int(t) for t in np.asarray(req.prompt_tokens)[0, :n]]

    def prefix_stamp(self, req: Request) -> int:
        """Consult the index once, when the prefill is first scheduled:
        stamps ``req.prefix_hit`` (the planner prices the suffix from it)
        and pins the hit run so eviction cannot release it before the
        first chunk adopts it.  Idempotent across re-planning."""
        if req.prefix_hit is not None:
            return req.prefix_hit
        key = self._prefix_key(req)
        blocks = (self.prefix_cache.lookup_pin(req.rid, key)
                  if key else [])
        if blocks:
            self._hit_runs[req.rid] = blocks
        req.prefix_hit = len(blocks) * self.store.block_lines
        return req.prefix_hit

    def prefix_abandon(self, req: Request):
        """The stamped prefill will not run here after all (requeued or
        its instance died): drop the pin and the stamp so the next
        placement consults its own instance's cache."""
        self._hit_runs.pop(req.rid, None)
        if self.prefix_cache is not None:
            self.prefix_cache.unpin(req.rid)
        req.prefix_hit = None

    def _prefix_insert(self, req: Request):
        """Index the just-prefilled request's shareable head (its table's
        leading blocks gain a cache reference)."""
        key = self._prefix_key(req)
        if not key:
            return
        k = len(key) // self.store.block_lines
        self.prefix_cache.insert(key, self.store.ledger.tables[req.rid][:k])

    def _clean_slot(self, slot: int) -> int:
        """Make ``slot``'s own block region allocatable, evicting cache
        entries that are its only remaining referents."""
        used = self.store.slot_used_blocks(slot)
        if used:
            assert self.prefix_cache is not None, \
                f"slot {slot} region held with no cache to evict"
            self.prefix_cache.evict_obstructing(set(used))
            assert not self.store.slot_used_blocks(slot), \
                f"slot {slot} region still referenced after cache purge"
        return slot

    # -- prefill --------------------------------------------------------------
    def prefill_request(self, req: Request, extra: Optional[dict] = None
                        ) -> int:
        """Run the prompt through the model into a free slot; returns the
        slot.  Thin wrapper over :meth:`prefill_batch` with a one-item
        plan (scratch sized to the padded bucket, not kv_capacity)."""
        from repro.stepplan import PrefillItem, PrefillPlan, bucket_len
        item = PrefillItem(req.rid, req.prompt_len, 0, req.prompt_len,
                           req=req)
        plan = PrefillPlan(self.instance_id, (item,),
                           bucket_len(req.prompt_len, cap=self.kv_capacity))
        done = self.prefill_batch(plan, extras={req.rid: extra})
        return done[req.rid]

    def prefill_batch(self, plan: PrefillPlan,
                      extras: Optional[Mapping[int, Optional[dict]]] = None
                      ) -> Dict[int, int]:
        """Execute one prefill step plan; returns {rid: slot} for every
        request whose prefill *completed* this iteration.

        Whole-prompt items on attention-only stacks run as ONE jitted
        call, right-padded to ``plan.bucket_len`` (batch padded to a
        power of two as well) — the jit cache is keyed by bucket shapes,
        so a stream of arbitrary prompt lengths compiles O(log max_len)
        kernels instead of one per length.  Scratch state is allocated
        at the bucket length, not ``kv_capacity``.  Items that cannot
        pad (modality extras, recurrent blocks, enc-dec, prompts beyond
        the bucket) run the unpadded single-prompt path with
        bucket-sized scratch.  Chunk items (``start > 0`` or partial
        ``end``) resume through the KV ledger cursor."""
        extras = extras or {}
        completed: Dict[int, int] = {}
        padded: List[PrefillItem] = []
        for it in plan.items:
            extra = extras.get(it.rid)
            if extra is None and getattr(it.req, "extra", None):
                extra = it.req.extra
            if not (it.start == 0 and it.completes):
                if (not self._attn_only or extra) and it.start == 0:
                    # can't resume this prompt mid-chunk here: degrade
                    # to one whole-prompt call rather than crash (the
                    # caller sees it completed ahead of its cursor)
                    completed[it.rid] = self._prefill_single(it.req, extra)
                    continue
                slot = self._prefill_chunk_item(it, extra)
                if slot is not None:
                    completed[it.rid] = slot
            elif (self._attn_only and not extra
                    and it.prompt_len <= min(plan.bucket_len,
                                             self.kv_capacity)):
                padded.append(it)
            else:
                completed[it.rid] = self._prefill_single(it.req, extra)
        if padded:
            # plan buckets are backend-agnostic; scratch is clamped to
            # this engine's cache window at execution time
            completed.update(self._prefill_padded(
                padded, min(plan.bucket_len, self.kv_capacity)))
        return completed

    def _take_slot(self) -> int:
        free = self.free_slots()
        assert free, f"instance {self.instance_id} has no free slot"
        return self._clean_slot(free[0])

    def _finish_prefill(self, req: Request, slot: int, tok: int,
                        ledgered: bool = False):
        self.lengths[slot] = req.prompt_len
        self.last_tokens[slot] = tok
        self.slot_req[slot] = req
        req.phase = Phase.DECODE
        req.generated += 1
        req.output_tokens.append(tok)
        # ledger: prompt lines + the reserved line for the sampled token
        if ledgered:
            self.store.set_lines(req.rid, req.total_len)
        else:
            self.store.alloc(req.rid, slot, lines=req.total_len)
        if self.prefix_cache is not None:
            self._prefix_insert(req)

    def _prefill_single(self, req: Request, extra: Optional[dict]) -> int:
        """Unpadded single-prompt path (modality extras, recurrent or
        enc-dec stacks); scratch sized to the prompt's bucket when the
        batch is token-only, else the full window (prefix tokens /
        encoder memory need the room)."""
        slot = self._take_slot()
        from repro.stepplan import bucket_len
        batch = {"tokens": req.prompt_tokens}
        if extra:
            batch.update(extra)
        window = (bucket_len(req.prompt_len, cap=self.kv_capacity)
                  if self._attn_only and not extra else self.kv_capacity)
        fresh = init_state(self.cfg, 1, window)
        with self._mesh_ctx():
            logits, fresh = self._jit_prefill(self.params, batch, fresh)
        self._key, sub = jax.random.split(self._key)
        tok = int(sample_slots(logits, sub, jnp.asarray([slot]),
                               self.temperature)[0])
        self.store.merge_slot_rows(slot, fresh, 0, window)
        self._finish_prefill(req, slot, tok)
        return slot

    def _prefill_padded(self, items: List[PrefillItem], bucket: int
                        ) -> Dict[int, int]:
        """Batched bucketed prefill: all items in one jitted call."""
        from repro.stepplan import bucket_len
        slots = self.free_slots()
        assert len(slots) >= len(items), \
            f"instance {self.instance_id}: {len(items)} prefills, " \
            f"{len(slots)} free slots"
        for s in slots[:len(items)]:
            self._clean_slot(s)
        B = len(items)
        Bp = bucket_len(B, floor=1)
        toks = np.zeros((Bp, bucket), np.int32)
        lens = np.ones((Bp,), np.int32)
        for i, it in enumerate(items):
            toks[i, :it.prompt_len] = np.asarray(it.req.prompt_tokens)[0]
            lens[i] = it.prompt_len
        fresh = init_state(self.cfg, Bp, bucket)
        with self._mesh_ctx():
            logits, fresh = self._jit_prefill_batched(
                self.params, jnp.asarray(toks), fresh, jnp.asarray(lens))
        self._key, sub = jax.random.split(self._key)
        # pad rows fold in an unused sentinel slot; their draws are
        # discarded and never perturb a real slot's stream
        row_slots = np.full((Bp,), self.num_slots, np.int32)
        row_slots[:B] = slots[:B]
        next_toks = np.asarray(sample_slots(logits, sub,
                                            jnp.asarray(row_slots),
                                            self.temperature))
        out: Dict[int, int] = {}
        for i, it in enumerate(items):
            slot = slots[i]
            self.store.merge_slot_rows(slot, fresh, 0, bucket, src_slot=i)
            self._finish_prefill(it.req, slot, int(next_toks[i]))
            out[it.rid] = slot
        return out

    def _prefill_chunk_item(self, it: PrefillItem, extra: Optional[dict]
                            ) -> Optional[int]:
        """One resumable chunk of a prompt; returns the slot when the
        final chunk completes the prefill, else None."""
        req = it.req
        if not self._attn_only or extra:
            raise NotImplementedError(
                "chunked prefill needs an attention-only decoder stack "
                "(recurrent state continuation across chunks is not "
                "implemented) and a token-only batch")
        if req.prompt_len > self.kv_capacity:
            raise NotImplementedError(
                f"chunked prefill of a {req.prompt_len}-token prompt "
                f"would wrap the {self.kv_capacity}-line cache window")
        if req.rid not in self.store.rid_slot:
            # first chunk: admit the request.  A prefix-cache hit adopts
            # the cached run as the table head (ledger: suffix blocks
            # only) and gathers the hit rows into this slot's window
            # once — the chunk below then resumes *past* the hit, never
            # recomputing it.
            slot = self._take_slot()
            self.prefilling[slot] = req
            req.phase = Phase.PREFILL
            hit = int(req.prefix_hit or 0)
            run = self._hit_runs.pop(req.rid, None)
            if hit:
                assert run is not None, \
                    f"rid {req.rid}: stamped hit {hit} lost its run"
                assert it.start == hit, (it.start, hit)
                self.store.alloc(req.rid, slot, lines=hit, shared=run)
                self.store.copy_prefix(run, slot, hit)
                self.prefix_cache.unpin(req.rid)
                self.lengths[slot] = hit
            else:
                self.store.alloc(req.rid, slot, lines=0)
        else:
            slot = self.store.rid_slot[req.rid]
            assert self.prefilling.get(slot) is req
        toks = req.prompt_tokens[:, it.start:it.end]
        sub = self.store.extract_slot(slot)
        with self._mesh_ctx():
            logits, sub = self._jit_prefill_chunk(self.params, toks, sub,
                                                  history=it.start)
        self.store.merge_slot_rows(slot, sub, it.start, it.end)
        if not it.completes:
            # cursor over the KV ledger: lines materialized so far.  The
            # decode step this iteration writes a garbage row at the
            # cursor for this slot; the next chunk overwrites it.
            self.store.set_lines(req.rid, it.end)
            self.lengths[slot] = it.end
            return None
        del self.prefilling[slot]
        self._key, sub_key = jax.random.split(self._key)
        tok = int(sample_slots(logits, sub_key, jnp.asarray([slot]),
                               self.temperature)[0])
        self._finish_prefill(req, slot, tok, ledgered=True)
        return slot

    # -- decode ----------------------------------------------------------------
    def decode(self) -> Dict[int, int]:
        """One decode iteration over the active slots; returns
        slot->token.  Paged engines run the compacted single-step fused
        path; others the dense full-batch oracle."""
        if not self.slot_req:
            # a release mid-iteration can empty the batch: never pay a
            # jitted full-batch dispatch to generate nothing
            return {}
        if self.use_paged_decode:
            return {slot: toks[0]
                    for slot, toks in self.decode_multi(steps=1).items()}
        tokens = jnp.asarray(self.last_tokens)[:, None]
        t = jnp.asarray(self.lengths)
        with self._mesh_ctx():
            logits, self.store.state = self._jit_decode(
                self.params, tokens, self.store.state, t)
        self._key, sub = jax.random.split(self._key)
        # per-slot keys (fold_in by slot index == row index here) keep
        # sampled tokens invariant to batch compaction on the paged path
        next_tokens = np.asarray(sample_slots(
            logits, sub, jnp.arange(self.num_slots), self.temperature))
        self.host_syncs += 1
        out = {}
        for slot, req in list(self.slot_req.items()):
            # rows of free/replica slots hold garbage logits: sampled
            # tokens are read ONLY at active primary slots (this loop),
            # and those must be real rows of the batch
            assert 0 <= slot < next_tokens.shape[0]
            tok = int(next_tokens[slot])
            self.lengths[slot] += 1
            self.last_tokens[slot] = tok
            req.generated += 1
            req.output_tokens.append(tok)
            self.store.append_line(req.rid)
            out[slot] = tok
            if req.done or (self.eos_token is not None
                            and tok == self.eos_token):
                req.phase = Phase.DONE
                self.release(slot)
        return out

    def decode_multi(self, plan: Optional["DecodePlan"] = None,
                     steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Execute a (possibly fused) decode plan: ``steps`` decode
        iterations as ONE jitted ``lax.scan`` over the compacted active
        batch, with on-device sampling and EOS short-circuiting — one
        dispatch and one host transfer per plan instead of per token.
        Returns {slot: [tokens]} (a dead row stops contributing).

        Engines without paged-decode support degrade to sequential
        single-step calls (same tokens, per-step host syncs)."""
        if steps is None:
            steps = max(1, plan.steps) if plan is not None else 1
        if not self.slot_req:
            return {}
        if not self.use_paged_decode:
            out: Dict[int, List[int]] = {}
            for _ in range(steps):
                if not self.slot_req:
                    break
                for slot, tok in self.decode().items():
                    out.setdefault(slot, []).append(tok)
            return out
        slots = self.active_slots()
        reqs = [self.slot_req[s] for s in slots]
        budget = np.asarray([r.max_new_tokens - r.generated for r in reqs],
                            np.int32)
        # never scan past the last live row's budget: trailing steps
        # would only re-freeze dead rows
        steps = max(1, min(steps, int(budget.max())))
        t0 = self.lengths[slots].astype(np.int32)
        # tables cover the lines the scan can reach; padded to a
        # power-of-two block count so compiles stay O(log window)
        from repro.stepplan import bucket_len
        need = -(-min(int(t0.max()) + steps, self.kv_capacity)
                 // self.store.block_lines)
        blocks = bucket_len(need, floor=1,
                            cap=self.store.line_blocks_per_slot)
        # the slot-affine tables are growth-stable: reuse the uploaded
        # array until batch membership — (rid, slot) pairs, since a
        # request can leave and re-enter at a different slot — or the
        # block bucket changes (rebuilding per token would tax the
        # default steps=1 path)
        cache_key = (tuple(slots), tuple(r.rid for r in reqs), blocks)
        if self._tables_cache is None or self._tables_cache[0] != cache_key:
            self._tables_cache = (cache_key, jnp.asarray(
                self.store.decode_block_tables([r.rid for r in reqs],
                                               blocks)))
        tables = self._tables_cache[1]
        key_chain, keys = decode_keys(self._key, steps)
        with self._mesh_ctx():
            toks_all, self.store.state, emitted = self._jit_decode_multi(
                self.params, jnp.asarray(self.last_tokens[slots])[:, None],
                self.store.state, jnp.asarray(t0), jnp.asarray(slots),
                tables, jnp.asarray(budget), keys)
        toks_np = np.asarray(toks_all)
        emitted = np.asarray(emitted)
        self.host_syncs += 1
        # consume key splits only for iterations that actually ran (EOS
        # can empty the batch early; sequential decode would have
        # stopped splitting there) — fused and per-step paths agree on
        # the key state the NEXT request samples under
        self._key = key_chain[int(emitted.max())]
        out = {}
        for i, slot in enumerate(slots):
            req = reqs[i]
            n = int(emitted[i])
            if n == 0:
                continue
            toks = [int(x) for x in toks_np[:n, i]]
            out[slot] = toks
            req.generated += n
            req.output_tokens.extend(toks)
            self.store.append_line(req.rid, n)
            self.lengths[slot] += n
            self.last_tokens[slot] = toks[-1]
            if req.done or (self.eos_token is not None
                            and toks[-1] == self.eos_token):
                req.phase = Phase.DONE
                self.release(slot)
        return out

    # -- slot management --------------------------------------------------------
    def release(self, slot: int) -> int:
        """Free the slot; returns the number of blocks returned to the
        pool."""
        self.slot_req.pop(slot, None)
        self.replica_of.pop(slot, None)
        self.prefilling.pop(slot, None)
        freed = self.store.free_slot(slot)
        self.lengths[slot] = 0
        # a stale token here would leak into a later occupant's first
        # decode if any path ever read before writing; clear with lengths
        self.last_tokens[slot] = 0
        return freed

    # -- redundancy primitives ---------------------------------------------------
    def export_slot(self, slot: int):
        """Per-request state + clocks, for replication to the pair
        partner (whole-state form; :meth:`export_stream` is the
        per-layer-chunk form a real mesh overlaps with prefill)."""
        return (self.store.extract_slot(slot), int(self.lengths[slot]),
                int(self.last_tokens[slot]), self.store.lines(self._rid_at(slot)))

    def export_stream(self, slot: int):
        """Per-layer streamed export: ``(chunk_iter, length, last_token,
        lines)``."""
        return (self.store.stream_slot(slot), int(self.lengths[slot]),
                int(self.last_tokens[slot]),
                self.store.lines(self._rid_at(slot)))

    def import_slot(self, slot: int, exported, req: Request,
                    as_replica_of: Optional[Tuple[int, int]] = None):
        sub_state, length, last_tok, lines = exported
        self._clean_slot(slot)
        self.store.alloc(req.rid, slot, lines=lines)
        self.store.merge_slot(slot, sub_state)
        self._install(slot, length, last_tok, req, as_replica_of)

    def import_stream(self, slot: int, chunks: Iterable, length: int,
                      last_tok: int, lines: int, req: Request,
                      as_replica_of: Optional[Tuple[int, int]] = None):
        """Install a per-layer streamed export chunk by chunk.  When this
        instance's prefix cache already holds the request's prompt head,
        the new table adopts those blocks — a shared-prefix replica costs
        only its unique suffix in pool blocks (the redundancy interplay
        the paper's HBM argument rides on)."""
        self._clean_slot(slot)
        run = None
        if self.prefix_cache is not None:
            key = self._prefix_key(req)
            run = self.prefix_cache.peek_blocks(key) if key else None
        self.store.alloc(req.rid, slot, lines=lines, shared=run or None)
        for path, chunk in chunks:
            self.store.import_chunk(slot, path, chunk)
        self._install(slot, length, last_tok, req, as_replica_of)

    def _install(self, slot: int, length: int, last_tok: int, req: Request,
                 as_replica_of: Optional[Tuple[int, int]]):
        self.lengths[slot] = length
        self.last_tokens[slot] = last_tok
        if as_replica_of is not None:
            self.replica_of[slot] = as_replica_of
        else:
            self.slot_req[slot] = req

    def promote_replica(self, slot: int, req: Request):
        """Instant role-flip enabled by redundancy (AcceLLM §4.1.2): a
        replica slot becomes the primary with zero data movement."""
        assert slot in self.replica_of
        del self.replica_of[slot]
        self.slot_req[slot] = req
        self.store.mark_synced(req.rid)

    def demote_to_replica(self, slot: int, of: Tuple[int, int]):
        assert slot in self.slot_req
        rid = self.slot_req[slot].rid
        del self.slot_req[slot]
        self.replica_of[slot] = of
        # an ex-primary's copy is current by definition
        self.store.mark_synced(rid)

    def sync_replica_from(self, src: "InstanceEngine", src_slot: int,
                          dst_slot: int, from_line: Optional[int] = None,
                          to_line: Optional[int] = None) -> float:
        """Mirror the partner's newly generated KV line(s) into our
        replica slot (AcceLLM §4.1.2 'newly computed KV cache lines are
        transferred back'): copies ONLY lines ``[from_line, to_line)``
        (default: our ledger's synced mark up to the primary's current
        lines) plus the constant-size recurrent states.  Returns the
        bytes moved — one KV line per decode step in steady state."""
        rid = src._rid_at(src_slot)
        if to_line is None:
            to_line = src.store.lines(rid)
        if from_line is None:
            from_line = self.store.synced_line(rid)
        # lines inside an adopted shared head are already resident here:
        # a catch-up sync never re-moves them (ISSUE: MirrorSync skips
        # blocks the mirror holds)
        from_line = max(from_line, self.store.shared_head_lines(rid))
        to_line = max(to_line, from_line)
        moved = self.store.copy_lines(src.store, src_slot, dst_slot,
                                      from_line, to_line)
        self.lengths[dst_slot] = src.lengths[src_slot]
        self.last_tokens[dst_slot] = src.last_tokens[src_slot]
        self.store.set_lines(rid, to_line)
        self.store.mark_synced(rid, to_line)
        return moved
