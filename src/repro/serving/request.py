"""Request lifecycle + latency bookkeeping (TTFT / TBT / JCT — AcceLLM §3.4)."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional

_ids = itertools.count()


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    #: rejected by admission control (queue bound / deadline shed) —
    #: terminal, never served; counts as an SLO miss, not a silent drop
    SHED = "shed"
    #: cancelled mid-flight by an ``AbortRequest`` — terminal; all
    #: serving state (blocks, replicas, planner cursors) is torn down
    ABORTED = "aborted"


@dataclass
class Request:
    prompt_len: int
    max_new_tokens: int
    arrival: float = 0.0
    rid: int = field(default_factory=lambda: next(_ids))
    prompt_tokens: Optional[object] = None      # jax array (1, prompt_len)
    extra: Optional[dict] = None                # modality payload (vision/audio)
    #: shared-prefix identity from the workload layer: requests with the
    #: same prefix_id open with the same first prefix_len tokens (system
    #: prompt / conversation history).  None = no declared sharing.
    prefix_id: Optional[int] = None
    prefix_len: int = 0
    #: block-aligned prefix-cache hit, stamped once when the prefill is
    #: first scheduled (both backends stamp at action creation so the
    #: planner prices the same suffix); None = not yet consulted
    prefix_hit: Optional[int] = None
    phase: Phase = Phase.QUEUED
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    # timing
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    # -- serving state size (bytes of KV/SSM state at current length) -------
    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    # -- metrics -------------------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def jct(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def tbts(self) -> List[float]:
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]
