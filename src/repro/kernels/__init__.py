"""Pallas TPU kernels for the serving hot-spots (flash prefill attention,
GQA decode attention) + jit'd wrappers (ops) and pure-jnp oracles (ref)."""
