"""Pallas TPU fused selective-scan kernel (Mamba-1 recurrence).

The naive ``lax.scan`` implementation round-trips the SSM state
(B, d_in, d_state) through HBM every timestep — the dominant memory term of
the hybrid arch's train/prefill roofline (EXPERIMENTS.md §Perf iteration
8). This kernel keeps the state tile resident in VMEM scratch for the
whole sequence: inputs stream in time-blocks, the time loop runs inside
the kernel, and state only touches HBM once at the end.

Grid: (batch, channel_blocks, time_blocks) — time innermost and sequential
("arbitrary") so the scratch state persists across time blocks.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) outer B_t
    y_t = h_t · C_t + D * x_t

Shapes per tile: state (C_BLK, N); N = d_state (16) packs the lane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, time_blk: int,
                 num_time_blocks: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0]                      # (C_BLK, N)

    a = a_ref[...]                                  # (C_BLK, N)
    d = d_ref[...]                                  # (C_BLK,)

    def step(t, h):
        x_t = x_ref[0, t]                           # (C_BLK,)
        dt_t = dt_ref[0, t]                         # (C_BLK,)
        b_t = b_ref[0, t]                           # (N,)
        c_t = c_ref[0, t]                           # (N,)
        da = jnp.exp(dt_t[:, None] * a)             # (C_BLK, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=1) + d * x_t
        return h

    h_scr[...] = jax.lax.fori_loop(0, time_blk, step, h_scr[...])

    @pl.when(ti == num_time_blocks - 1)
    def _finalize():
        hout_ref[0] = h_scr[...]


def mamba_scan_pallas(
    x: jax.Array,        # (B, S, C) gated/conv'd input, f32
    dt: jax.Array,       # (B, S, C) softplus'd step sizes
    b_ssm: jax.Array,    # (B, S, N)
    c_ssm: jax.Array,    # (B, S, N)
    a: jax.Array,        # (C, N)  negative decay rates
    d: jax.Array,        # (C,)    skip weights
    h0: jax.Array,       # (B, C, N) initial state
    *,
    channel_blk: int = 128,
    time_blk: int = 256,
    interpret: bool = False,
):
    """Returns (y (B,S,C), h_final (B,C,N))."""
    B, S, C = x.shape
    N = b_ssm.shape[-1]
    channel_blk = min(channel_blk, C)
    time_blk = min(time_blk, S)
    assert C % channel_blk == 0 and S % time_blk == 0
    nc, nt = C // channel_blk, S // time_blk

    kernel = functools.partial(_scan_kernel, time_blk=time_blk,
                               num_time_blocks=nt)
    y, h_out = pl.pallas_call(
        kernel,
        grid=(B, nc, nt),
        in_specs=[
            pl.BlockSpec((1, time_blk, channel_blk),
                         lambda b, ci, ti: (b, ti, ci)),   # x
            pl.BlockSpec((1, time_blk, channel_blk),
                         lambda b, ci, ti: (b, ti, ci)),   # dt
            pl.BlockSpec((1, time_blk, N),
                         lambda b, ci, ti: (b, ti, 0)),    # B_ssm
            pl.BlockSpec((1, time_blk, N),
                         lambda b, ci, ti: (b, ti, 0)),    # C_ssm
            pl.BlockSpec((channel_blk, N),
                         lambda b, ci, ti: (ci, 0)),       # A
            pl.BlockSpec((channel_blk,),
                         lambda b, ci, ti: (ci,)),         # D
            pl.BlockSpec((1, channel_blk, N),
                         lambda b, ci, ti: (b, ci, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, time_blk, channel_blk),
                         lambda b, ci, ti: (b, ti, ci)),   # y
            pl.BlockSpec((1, channel_blk, N),
                         lambda b, ci, ti: (b, ci, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((channel_blk, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32),
      b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32),
      a.astype(jnp.float32), d.astype(jnp.float32), h0.astype(jnp.float32))
    return y, h_out
