"""jit'd public wrappers for the Pallas kernels with backend dispatch.

``backend``:
  "pallas"     — compiled Mosaic TPU kernel (production target)
  "interpret"  — Pallas interpret mode (CPU correctness validation)
  "ref"        — pure-jnp oracle

On CPU hosts the default is "ref" so models run everywhere; tests force
"interpret" to execute the real kernel bodies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "window", "block_q", "block_k", "backend"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    backend: Optional[str] = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale,
                                        window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, window=window,
        block_q=block_q, block_k=block_k,
        interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "backend"))
def decode_attention(q, k_cache, v_cache, lengths, *,
                     scale: Optional[float] = None, block_k: int = 256,
                     backend: Optional[str] = None):
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                         scale=scale)
    return decode_attention_pallas(
        q, k_cache, v_cache, lengths, scale=scale, block_k=block_k,
        interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("channel_blk", "time_blk",
                                             "backend"))
def mamba_scan(x, dt, b_ssm, c_ssm, a, d, h0, *, channel_blk: int = 128,
               time_blk: int = 256, backend: Optional[str] = None):
    from repro.kernels.mamba_scan import mamba_scan_pallas
    backend = backend or default_backend()
    if backend == "ref":
        return _ref.mamba_scan_ref(x, dt, b_ssm, c_ssm, a, d, h0)
    return mamba_scan_pallas(x, dt, b_ssm, c_ssm, a, d, h0,
                             channel_blk=channel_blk, time_blk=time_blk,
                             interpret=(backend == "interpret"))
