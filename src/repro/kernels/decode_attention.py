"""Pallas TPU decode-attention kernel (single query token vs KV cache).

The decode phase is HBM-bandwidth bound (AcceLLM §3.3): per step the whole
KV cache streams HBM->VMEM once while compute is two skinny matmuls. The
kernel therefore tiles the cache's sequence dim and processes all G grouped
query heads of one KV head per tile, so every K/V byte fetched feeds G
query heads (GQA bandwidth amplification).

Grid: (batch, kv_heads, num_kv_blocks), KV-block axis innermost and
sequential, online-softmax accumulation in VMEM scratch. Invalid (not yet
written) cache slots are masked via the per-request ``length`` scalar,
prefetched to SMEM.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, num_kv_blocks: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (block_k, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, block_k)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,         # (B, 1, H, hd) or (B, H, hd)
    k_cache: jax.Array,   # (B, W, KVH, hd)
    v_cache: jax.Array,
    lengths: jax.Array,   # (B,) int32 — valid KV entries per request
    *,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    squeeze = False
    if q.ndim == 4:
        assert q.shape[1] == 1
        q = q[:, 0]
        squeeze = True
    B, H, hd = q.shape
    W, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_k = min(block_k, W)
    assert W % block_k == 0, f"cache window {W} must divide block_k {block_k}"
    nk = W // block_k

    qg = q.reshape(B, KVH, G, hd)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, num_kv_blocks=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ki, lens: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ki, lens: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)

    out = out.reshape(B, H, hd)
    return out[:, None] if squeeze else out


# ---------------------------------------------------------------------------
# Paged decode attention: K/V gathered through per-request block tables
# ---------------------------------------------------------------------------


def _paged_decode_kernel(lengths_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *,
                         scale: float, block_lines: int, max_blocks: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    k_start = ki * block_lines

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (block_lines, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, block_lines)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == max_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,             # (B, 1, H, hd) or (B, H, hd)
    k_pool: jax.Array,        # (num_blocks, block_lines, KVH, hd)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, max_blocks) int32 — physical block ids
    lengths: jax.Array,       # (B,) int32 — valid KV lines per request
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention over a paged KV pool (`repro.kvstore.PagedStore`
    layout): the kernel never sees a contiguous per-request cache — each
    KV tile is DMA'd from the physical block the request's block table
    names, via scalar-prefetched table indices in the BlockSpec index
    map.  Same online-softmax body and GQA tiling as the dense kernel;
    entries of ``block_tables`` beyond a request's blocks may be any
    valid block id (their scores are masked by ``lengths``)."""
    squeeze = False
    if q.ndim == 4:
        assert q.shape[1] == 1
        q = q[:, 0]
        squeeze = True
    B, H, hd = q.shape
    num_blocks, block_lines, KVH = k_pool.shape[:3]
    G = H // KVH
    max_blocks = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, KVH, G, hd)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_lines=block_lines,
        max_blocks=max_blocks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, ki, lens, tabs: (b, h, 0, 0)),
            pl.BlockSpec((1, block_lines, 1, hd),
                         lambda b, h, ki, lens, tabs: (tabs[b, ki], 0, h, 0)),
            pl.BlockSpec((1, block_lines, 1, hd),
                         lambda b, h, ki, lens, tabs: (tabs[b, ki], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ki, lens, tabs: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      qg, k_pool, v_pool)

    out = out.reshape(B, H, hd)
    return out[:, None] if squeeze else out


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    use_pallas: bool = False,
) -> jax.Array:
    """Backend dispatcher for the model's paged decode hot path: the
    Mosaic kernel on TPU (interpret mode anywhere else, so the
    ``pallas`` backend stays testable on CPU CI), the jnp gather oracle
    otherwise.  Both paths read K/V exclusively through the block
    tables — the dense per-slot window is never touched.

    Under an active mesh (repro.meshserve) the pools arrive with their
    KV-head dim on the slice's model axis; the gather is per-head, so
    each shard touches only its own heads' blocks and the result needs
    no collective until the attention output hits the row-parallel
    output projection.  The tables and lengths are tiny and replicated."""
    from repro import sharding
    # pin the pools' KV-head dim where the store committed it, so GSPMD
    # never rematerializes the whole pool for the gather (no-op without
    # a mesh; skipped when the KV heads don't divide the slice — the
    # store then keeps the pool replicated and only q heads split)
    ctx = sharding.current()
    if (ctx.mesh is not None and ctx.model_axis is not None
            and k_pool.ndim >= 4
            and k_pool.shape[2] % ctx.model_size == 0):
        k_pool = sharding.constrain(k_pool, None, None, "model", None)
        v_pool = sharding.constrain(v_pool, None, None, "model", None)
    if use_pallas:
        return paged_decode_attention_pallas(
            q, k_pool, v_pool, block_tables, lengths, scale=scale,
            interpret=jax.default_backend() != "tpu")
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tables,
                                      lengths, scale=scale)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               *, scale: Optional[float] = None):
    """jnp oracle: gather each request's blocks into a contiguous cache,
    then run the dense decode path."""
    from repro.models.attention import decode_attention, ring_valid
    squeeze = q.ndim == 4
    if not squeeze:
        q = q[:, None]
    B = q.shape[0]
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bl = k_pool.shape[1]
    gathered_k = k_pool[block_tables].reshape(
        B, block_tables.shape[1] * bl, *k_pool.shape[2:])
    gathered_v = v_pool[block_tables].reshape(
        B, block_tables.shape[1] * bl, *v_pool.shape[2:])
    valid = ring_valid(lengths, gathered_k.shape[1])
    out = decode_attention(q, gathered_k, gathered_v, scale=scale,
                           valid=valid)
    return out if squeeze else out[:, 0]
