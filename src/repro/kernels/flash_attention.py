"""Pallas TPU flash-attention kernel (prefill / training path).

Causal (optionally sliding-window) multi-head attention with GQA, computed
block-by-block in VMEM with online softmax — the HBM->VMEM streaming
analogue of FlashAttention's SRAM tiling (see DESIGN.md §3: this is a
re-tiling for the TPU memory hierarchy, not a CUDA port).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the KV-block axis
innermost and sequential ("arbitrary"), accumulating into VMEM scratch.
Causal block-skipping uses @pl.when so fully-masked KV blocks do no MXU
work. GQA is expressed in the K/V index_map (kv_head = q_head // G) so K/V
tiles are fetched once per KV head, not once per Q head.

Block sizes default to (128, head_dim): MXU-aligned when head_dim is a
multiple of 128; head_dim=64 models still lower (Mosaic pads lanes).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level liveness: skip KV blocks that are entirely masked out
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(
            live, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)         # (block_q, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)      # (block_k, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (B, Sq, H, hd)
    k: jax.Array,      # (B, Skv, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (
        f"seq ({Sq},{Skv}) must divide blocks ({block_q},{block_k})")
    nq, nk = Sq // block_q, Skv // block_k

    qt = q.transpose(0, 2, 1, 3)                     # (B, H, Sq, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, k, v)
    return out.transpose(0, 2, 1, 3)
