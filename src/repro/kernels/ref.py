"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,      # (B, Sq, H, hd)
    k: jax.Array,      # (B, Skv, KVH, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def mamba_scan_ref(x, dt, b_ssm, c_ssm, a, d, h0):
    """Selective-scan oracle. x/dt (B,S,C); b/c (B,S,N); a (C,N); d (C,);
    h0 (B,C,N) -> (y (B,S,C), h_final (B,C,N))."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, c_t) + d * x_t
        return h, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          b_ssm.swapaxes(0, 1), c_ssm.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         tuple(t.astype(jnp.float32) for t in xs))
    return ys.swapaxes(0, 1), h


def decode_attention_ref(
    q: jax.Array,        # (B, 1, H, hd) or (B, H, hd)
    k_cache: jax.Array,  # (B, W, KVH, hd)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    squeeze = False
    if q.ndim == 4:
        q = q[:, 0]
        squeeze = True
    B, H, hd = q.shape
    W, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qf, k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(W)[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    o = o.reshape(B, H, hd).astype(q.dtype)
    return o[:, None] if squeeze else o
