"""Radix prefix index + the refcount contract with the block ledger.

The index is block-granular: a prompt prefix is cached (and can hit)
only in whole ``block_lines`` chunks.  That alignment is what keeps
copy-on-write out of the serving fast path — a shared block is always
*full*, so the first divergent token of a new request lands in its own
fresh block and the ledger-level COW machinery (``BlockLedger.append_line``)
is exercised only by adversarial interleavings, not by admission.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

from repro.kvstore.base import BlockLedger, KVStoreError


def aligned_hit_lines(prefix_len: int, prompt_len: int,
                      block_lines: int) -> int:
    """Largest usable hit: block-aligned, and strictly less than the
    prompt (at least one suffix token must run through prefill so the
    request has logits to sample its first token from)."""
    cap = min(prefix_len, prompt_len - 1)
    return max(0, (cap // block_lines) * block_lines)


def chunk_key(tokens: Sequence[Hashable], i: int,
              block_lines: int) -> Tuple[Hashable, ...]:
    """The i-th block-granular radix key of a token sequence."""
    return tuple(tokens[i * block_lines:(i + 1) * block_lines])


@dataclass
class _Node:
    key: Tuple[Hashable, ...]
    block: int
    parent: Optional["_Node"]
    children: Dict[Tuple[Hashable, ...], "_Node"] = field(
        default_factory=dict)
    last_use: int = 0


class PrefixIndex:
    """Radix tree over block-granular token chunks → pool block ids."""

    def __init__(self, block_lines: int):
        self.block_lines = block_lines
        self.root: Dict[Tuple[Hashable, ...], _Node] = {}
        self._nodes: List[_Node] = []
        self._tick = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def blocks(self) -> List[int]:
        return [n.block for n in self._nodes]

    def walk(self, tokens: Sequence[Hashable],
             touch: bool = True) -> List[_Node]:
        """Longest cached path matching ``tokens``; ``touch`` refreshes
        LRU stamps along the way."""
        if touch:
            self._tick += 1
        path: List[_Node] = []
        children = self.root
        for i in range(len(tokens) // self.block_lines):
            node = children.get(chunk_key(tokens, i, self.block_lines))
            if node is None:
                break
            if touch:
                node.last_use = self._tick
            path.append(node)
            children = node.children
        return path

    def extend(self, tokens: Sequence[Hashable],
               blocks: Sequence[int]) -> List[_Node]:
        """Insert the path for ``tokens`` (backed block-for-block by
        ``blocks``); returns the *newly created* nodes."""
        self._tick += 1
        created: List[_Node] = []
        children, parent = self.root, None
        for i in range(min(len(tokens) // self.block_lines, len(blocks))):
            key = chunk_key(tokens, i, self.block_lines)
            node = children.get(key)
            if node is None:
                node = _Node(key=key, block=blocks[i], parent=parent)
                children[key] = node
                self._nodes.append(node)
                created.append(node)
            node.last_use = self._tick
            children, parent = node.children, node
        return created

    def remove(self, node: _Node):
        if node.children:
            raise KVStoreError("cannot remove an interior radix node")
        siblings = node.parent.children if node.parent else self.root
        del siblings[node.key]
        self._nodes.remove(node)

    def lru_leaves(self) -> List[_Node]:
        return sorted((n for n in self._nodes if not n.children),
                      key=lambda n: n.last_use)

    def subtree(self, node: _Node) -> List[_Node]:
        """Post-order descendants-then-self (safe removal order)."""
        out: List[_Node] = []
        for child in list(node.children.values()):
            out.extend(self.subtree(child))
        out.append(node)
        return out


class PrefixCache:
    """The index wired to a :class:`BlockLedger`: cached blocks carry one
    cache reference (``retain``), eviction ``release``-s them, and hits
    adopted by an admission carry their own table reference — so a block
    frees exactly when its last referent (table *or* cache) lets go.

    Identical instances run on both backends; only the token alphabet
    differs (real ids live, ``(prefix_id, pos)`` pairs in the
    simulator).
    """

    def __init__(self, ledger: BlockLedger,
                 capacity_blocks: Optional[int] = None):
        self.ledger = ledger
        self.index = PrefixIndex(ledger.block_lines)
        #: max blocks the cache may retain (None: unbounded — pool
        #: pressure alone evicts via ``evict_obstructing``)
        self.capacity_blocks = capacity_blocks
        self._pins: Dict[int, Set[int]] = {}
        self.stats: Dict[str, int] = {
            "lookups": 0, "hits": 0, "hit_blocks": 0, "hit_tokens": 0,
            "inserted_blocks": 0, "evicted_blocks": 0}

    # -- queries -------------------------------------------------------------
    def cached_blocks(self) -> int:
        return len(self.index)

    def peek_blocks(self, tokens: Sequence[Hashable]) -> List[int]:
        """Longest resident block run for ``tokens`` without touching
        LRU state or stats (scheduler views use this)."""
        return [n.block for n in self.index.walk(tokens, touch=False)]

    # -- the hit path --------------------------------------------------------
    def lookup_pin(self, rid: int,
                   tokens: Sequence[Hashable]) -> List[int]:
        """Longest resident block run for ``tokens``, pinned under
        ``rid`` until :meth:`unpin` — eviction will not release a pinned
        block, so the run survives the gap between scheduling the
        prefill and allocating the request's table."""
        self.stats["lookups"] += 1
        blocks = [n.block for n in self.index.walk(tokens)]
        if blocks:
            self.stats["hits"] += 1
            self.stats["hit_blocks"] += len(blocks)
            self.stats["hit_tokens"] += len(blocks) \
                * self.ledger.block_lines
            self._pins[rid] = set(blocks)
        return blocks

    def unpin(self, rid: int):
        self._pins.pop(rid, None)

    def pinned(self) -> Set[int]:
        out: Set[int] = set()
        for s in self._pins.values():
            out |= s
        return out

    # -- inserts and eviction ------------------------------------------------
    def insert(self, tokens: Sequence[Hashable],
               blocks: Sequence[int]) -> int:
        """Cache the (block-aligned) prefix path for a just-prefilled
        request; newly indexed blocks gain a cache reference.  Returns
        how many blocks were newly cached."""
        created = self.index.extend(tokens, blocks)
        self.ledger.retain([n.block for n in created])
        self.stats["inserted_blocks"] += len(created)
        if self.capacity_blocks is not None:
            self._evict_to(self.capacity_blocks)
        return len(created)

    def _remove_node(self, node) -> int:
        self.index.remove(node)
        return self.ledger.release([node.block])

    def _evict_to(self, capacity: int) -> int:
        """LRU-evict unpinned leaves until at most ``capacity`` blocks
        stay cached; returns blocks actually returned to the pool."""
        freed = 0
        pinned = self.pinned()
        while len(self.index) > capacity:
            victims = [n for n in self.index.lru_leaves()
                       if n.block not in pinned]
            if not victims:
                break
            freed += self._remove_node(victims[0])
            self.stats["evicted_blocks"] += 1
        return freed

    def evict_obstructing(self, blocks: Set[int]) -> int:
        """Drop every cached entry whose block is in ``blocks`` (and,
        for index consistency, its whole subtree); pinned blocks stay.
        Returns blocks actually returned to the pool — the live store
        calls this to reclaim a slot whose region is held only by the
        cache."""
        pinned = self.pinned()
        freed = 0
        for node in [n for n in list(self.index._nodes)
                     if n.block in blocks]:
            if node not in self.index._nodes:
                continue  # already removed as part of an earlier subtree
            sub = self.index.subtree(node)
            if any(n.block in pinned for n in sub):
                continue
            for n in sub:
                freed += self._remove_node(n)
                self.stats["evicted_blocks"] += 1
        return freed

    def release_all(self) -> int:
        """Drop the whole cache (instance teardown)."""
        self._pins.clear()
        freed = 0
        while self.index._nodes:
            for node in self.index.lru_leaves():
                freed += self._remove_node(node)
        return freed
