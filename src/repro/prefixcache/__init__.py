"""Prefix cache: radix index over token ids → resident block runs.

Production traffic shares system prompts, few-shot templates and
conversation history; a request whose prompt head is already resident
should never re-prefill it.  This package layers that reuse on the
refcounted :class:`repro.kvstore.BlockLedger`:

* :class:`PrefixIndex` — a radix tree keyed on *block-granular* chunks
  of token ids; each node maps one chunk to the pool block holding its
  KV lines.
* :class:`PrefixCache` — the index plus the ledger contract: cached
  blocks are ``retain``-ed (kept alive past their last table), LRU
  leaves are ``release``-d under capacity pressure, and in-flight hits
  are pinned so eviction cannot snatch a run between scheduling and
  allocation.

Both backends run this same code: the live engine keys the index on
real prompt-token ids, the (token-free) simulator on synthetic
``(prefix_id, position)`` pairs — the radix walk only needs hashable,
equality-comparable chunk keys, so hit/miss decisions agree run-for-run
(see docs/ARCHITECTURE.md, "Prefix cache").
"""
from repro.prefixcache.index import (PrefixCache, PrefixIndex,
                                     aligned_hit_lines, chunk_key)

__all__ = ["PrefixCache", "PrefixIndex", "aligned_hit_lines", "chunk_key"]
