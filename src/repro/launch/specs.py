"""ShapeDtypeStruct input specs + PartitionSpec shardings for the dry-run.

``input_specs(cfg, shape)`` builds weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation — the full configs
are only ever lowered, never materialized).

``param_pspecs`` / ``state_pspecs`` derive PartitionSpec pytrees from leaf
paths + shapes with divisibility-checked rules:
  * TP ("model") on head/ffn/expert dims,
  * FSDP ("data", + "pod" when multi-pod) on a second dim in train mode,
  * batch on ("data") (+"pod"), KV-sequence on "data" for the long-context
    decode of the hybrid arch (sharded-KV decode combine — DESIGN.md §5).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# ---------------------------------------------------------------------------
# Input ShapeDtypeStructs
# ---------------------------------------------------------------------------


def token_layout(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Resolve per-arch token/frontend layout for an input shape."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        p = cfg.frontend.num_prefix_tokens
        out["patch_embeds"] = (B, p, cfg.frontend.embed_dim)
        out["text_len"] = max(S - p, 1)
    elif cfg.is_encoder_decoder:
        frames = min(cfg.encoder.max_source_positions, S)
        out["frames"] = (B, frames, cfg.frontend.embed_dim)
        out["text_len"] = S
    else:
        out["text_len"] = S
    return out


def input_specs(cfg: ModelConfig, shape: InputShape, multi_pod: bool = False,
                layout: str = "tp"
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
    """(ShapeDtypeStructs, PartitionSpecs) for the batch of this shape."""
    if layout == "fsdp":
        b_ax = (("pod", "data", "model") if multi_pod
                else ("data", "model"))
        n_b = _axes_size(multi_pod) * 16
    else:
        b_ax = ("pod", "data") if multi_pod else ("data",)
        n_b = _axes_size(multi_pod)
    B = shape.global_batch
    bspec = b_ax if _div(B, n_b) else None
    layout = token_layout(cfg, shape)
    sds: Dict[str, jax.ShapeDtypeStruct] = {}
    specs: Dict[str, P] = {}

    if shape.kind in ("train", "prefill"):
        sds["tokens"] = jax.ShapeDtypeStruct((B, layout["text_len"]), jnp.int32)
        specs["tokens"] = P(bspec, None)
        if shape.kind == "train":
            sds["labels"] = jax.ShapeDtypeStruct((B, layout["text_len"]),
                                                 jnp.int32)
            specs["labels"] = P(bspec, None)
    else:  # decode: ONE new token + per-request clock
        sds["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["tokens"] = P(bspec, None)
        sds["t"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        specs["t"] = P(bspec)

    if "patch_embeds" in layout and shape.kind in ("train", "prefill"):
        sds["patch_embeds"] = jax.ShapeDtypeStruct(layout["patch_embeds"],
                                                   jnp.bfloat16)
        specs["patch_embeds"] = P(bspec, None, None)
    if "frames" in layout and shape.kind in ("train", "prefill"):
        sds["frames"] = jax.ShapeDtypeStruct(layout["frames"], jnp.bfloat16)
        specs["frames"] = P(bspec, None, None)
    return sds, specs


def _axes_size(multi_pod: bool) -> int:
    return 32 if multi_pod else 16


def _div(n: int, k: int) -> bool:
    return n % k == 0 and n >= k


# ---------------------------------------------------------------------------
# Param PartitionSpecs (path+shape rules)
# ---------------------------------------------------------------------------

# column-parallel (shard OUTPUT dim on model)
_COL = re.compile(
    r"(wq|wk|wv|wq_b|wkv_a|wq_a|wkv_b|w_gate|w_up|w_z|w_in|in_proj|x_proj|"
    r"combine|w1)$")
# row-parallel (shard INPUT dim on model)
_ROW = re.compile(r"(wo|w_down|out_proj|dt_w|w2)$")
_EXPERT = re.compile(r"ffn/(w_gate|w_up|w_down)$")
_REPLICATED = re.compile(
    r"(norm|bias|b_i|b_f|b|dt_b|router|logit|w_i|w_f|w_o|A_log|D|r)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def pick_layout(cfg: ModelConfig, shape: InputShape) -> str:
    """Per-(arch x shape) parallel layout on the FIXED production mesh.

    §Perf iteration 3 tried pure FSDP/ZeRO-256 for dense training
    (napkin: ~8x less wire traffic) — REFUTED by measurement: at 1
    batch-row per chip GSPMD picks partial-sum TP-like schedules with
    (B,S,D) all-reduces over all 256 chips and re-gathers the stacked
    scan weights per layer step (collective 19.8s -> 73.3s on phi3
    train_4k). The baseline TP16(+FSDP16-on-data) layout stays the best
    known on this mesh; "fsdp" remains selectable for experimentation via
    REPRO_LAYOUT=fsdp."""
    import os
    if (os.environ.get("REPRO_LAYOUT") == "fsdp"
            and shape.kind == "train" and cfg.moe is None):
        return "fsdp"
    return "tp"


def param_pspecs(cfg: ModelConfig, params_shape, *, mode: str,
                 multi_pod: bool = False, layout: str = "tp",
                 model_n: int = 16):
    """mode: "serve" (TP only, replicated over data) or "train" (TP+FSDP).
    layout "fsdp": no tensor parallelism — every matrix shards one dim over
    ALL mesh axes combined (pure FSDP/ZeRO-3 data parallel).
    ``model_n`` is the model-axis width the divisibility rules check
    against — 16 on the fixed production mesh; a mesh-serving slice
    (repro.meshserve) passes its own TP width."""
    fsdp_ax = ("pod", "data") if multi_pod else ("data",)
    fsdp_n = _axes_size(multi_pod)

    if layout == "fsdp":
        all_ax = (("pod", "data", "model") if multi_pod
                  else ("data", "model"))
        all_n = _axes_size(multi_pod) * model_n

        def rule_fsdp(path, leaf) -> P:
            shape_ = leaf.shape
            spec = [None] * len(shape_)
            # shard the largest divisible dim over the full mesh
            order = sorted(range(len(shape_)), key=lambda i: -shape_[i])
            for i in order:
                if _div(shape_[i], all_n):
                    spec[i] = all_ax
                    break
            return P(*spec)

        return jax.tree_util.tree_map_with_path(rule_fsdp, params_shape)

    def rule(path, leaf) -> P:
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd

        def try_shard(dim: int, axis, n: int) -> bool:
            if spec[dim] is None and _div(shape[dim], n):
                spec[dim] = axis
                return True
            return False

        is_expert = bool(_EXPERT.search(ps)) and cfg.moe is not None and \
            shape[-3:-2] and nd >= 3 and shape[-3] == cfg.moe.num_experts
        if ps.endswith("embed"):
            try_shard(0, "model", model_n)          # vocab on model
            if mode == "train":
                try_shard(1, fsdp_ax, fsdp_n)
        elif ps.endswith("lm_head"):
            try_shard(nd - 1, "model", model_n)
            if mode == "train":
                try_shard(nd - 2, fsdp_ax, fsdp_n)
        elif is_expert:
            try_shard(nd - 3, "model", model_n)     # expert dim
            if mode == "train":
                try_shard(nd - 2, fsdp_ax, fsdp_n)
        elif ps.endswith("ffn/router"):
            pass                                    # replicated
        elif _COL.search(ps):
            try_shard(nd - 1, "model", model_n)
            if mode == "train" and nd >= 2:
                try_shard(nd - 2, fsdp_ax, fsdp_n)
        elif _ROW.search(ps):
            if nd >= 2:
                try_shard(nd - 2, "model", model_n)
                if mode == "train":
                    try_shard(nd - 1, fsdp_ax, fsdp_n)
        elif ps.endswith("conv_w") and nd >= 2:
            try_shard(nd - 1, "model", model_n)     # (k, d_in)
        elif ps.endswith("conv_b") or ps.endswith("A_log") \
                or ps.endswith("/D") or ps.endswith("dt_b") \
                or ps.endswith("w_o"):
            try_shard(nd - 1 if ps.endswith(("conv_b", "dt_b", "w_o"))
                      else nd - 2, "model", model_n)
        elif ps.endswith("wv") and nd >= 3:
            # xLSTM headwise value proj: shard hd_out — the mLSTM matrix
            # memory C then shards its value dim and the whole time scan
            # runs collective-free (§Perf iteration 4; q/k/n replicated)
            try_shard(nd - 1, "model", model_n)
        elif re.search(r"(wq|wk|r)$", ps) and nd >= 3:
            pass  # replicated: q/k must be whole per chip (C's key dim)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# Serving-state PartitionSpecs
# ---------------------------------------------------------------------------


def state_pspecs(cfg: ModelConfig, state_shape, shape: InputShape,
                 *, long_context: bool, multi_pod: bool = False,
                 model_n: int = 16):
    """KV caches: batch on data when divisible; otherwise (long_500k, B=1)
    shard the KV sequence dim on data (sharded-KV decode combine)."""
    b_ax = ("pod", "data") if multi_pod else ("data",)
    b_n = _axes_size(multi_pod)
    B = shape.global_batch
    batch_ok = _div(B, b_n)

    def rule(path, leaf) -> P:
        ps = _path_str(path)
        shape_ = leaf.shape
        nd = len(shape_)
        spec = [None] * nd
        is_enc = ps.endswith("enc_out")
        # batch dim: 1 for layer states (dim0 = repeat), 0 for enc_out
        bdim = 0 if is_enc else 1
        if batch_ok and nd > bdim and _div(shape_[bdim], b_n):
            spec[bdim] = b_ax
        seq_dims = {"k": 2, "v": 2, "c_kv": 2, "k_rope": 2, "xk": 2, "xv": 2}
        tail = ps.rsplit("/", 1)[-1]
        if not batch_ok and tail in seq_dims and nd > 2 \
                and _div(shape_[2], b_n):
            spec[2] = b_ax                      # shard KV seq over data
        if tail in ("c_kv", "k_rope") and nd > 2 and spec[2] is None \
                and _div(shape_[2], model_n):
            # MLA latent cache: shard the SEQUENCE dim over the model axis
            # (flash-decode-style sharded-KV; §Perf iteration 5). All heads
            # share the latent, so head-sharding the cache is impossible —
            # sequence sharding keeps HBM reads 1/16 per chip and replaces
            # two per-layer latent all-gathers with tiny softmax-combine
            # all-reduces.
            spec[2] = "model"
        # model-parallel inner dims where divisible
        if tail in ("k", "v", "xk", "xv") and nd >= 4 \
                and _div(shape_[3], model_n):
            spec[3] = "model"                   # kv heads
        if tail == "ssm" and nd >= 3 and _div(shape_[2], model_n):
            spec[2] = "model"                   # mamba d_in
        if tail == "conv" and nd >= 4 and _div(shape_[3], model_n):
            spec[3] = "model"
        if tail == "C" and nd >= 4 and _div(shape_[3], model_n):
            spec[3] = "model"                   # mLSTM key dim
        if tail == "n" and nd >= 4 and _div(shape_[3], model_n):
            spec[3] = "model"
        if is_enc and _div(shape_[-1], model_n):
            spec[-1] = None                     # keep enc_out replicated on d
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, state_shape)
