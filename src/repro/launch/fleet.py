"""Fleet orchestration dry-run: render a ``ServeSpec`` (with its fleet
schedule) as the Kubernetes-shaped rollout a real deployment would
execute — one pod per serving instance, readiness gating, and the
fault-injection timeline as pod deletes / creates / cordons.

No cluster is contacted and no k8s client is imported: the output is a
plain JSON plan (manifests + timeline) suitable for inspection, diffing
in CI, or piping into ``kubectl apply -f -`` pod-by-pod on a real fleet.
The timeline is the *same* event stream (``FleetSchedule.stream``) the
live executor and the simulator consume, so what the orchestrator would
do to pods is exactly what the backends inject as
``KillInstance``/``JoinInstance``/``Drain``.

Usage:
  PYTHONPATH=src python -m repro.launch.fleet --arch phi3-medium-14b \
      --instances 4 [--fleet-mtbf 200 --duration 600] \
      [--fleet-trace trace.jsonl] [--out plan.json]
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional

from repro.api import ServeSpec
from repro.configs import get_config, list_archs
from repro.fleet import (DegradeInstance, Drain, FleetSchedule,
                         JoinInstance, KillInstance, PoissonFailures,
                         RecoverInstance, load_fleet_trace)
from repro.scheduling.registry import policy_names

#: accelerator asked of the node pool; the dry-run never allocates one
DEFAULT_ACCELERATOR = "tpu-v5e-4"


def pod_name(spec: ServeSpec, instance: int) -> str:
    return f"repro-{spec.policy}-{spec.arch}-{instance}".replace("_", "-")


def pod_spec(spec: ServeSpec, instance: int) -> dict:
    """Kubernetes Pod manifest for one serving instance.

    Pairing is surfaced as a label (``repro/pair``) so affinity rules
    can keep AcceLLM pair partners in distinct failure domains — a
    replica on the same rack as its primary defeats the failover story.
    """
    cfg = get_config(spec.arch)
    return {
        "apiVersion": "v1",
        "kind": "Pod",
        "metadata": {
            "name": pod_name(spec, instance),
            "labels": {
                "app": "repro-serve",
                "repro/policy": spec.policy,
                "repro/arch": spec.arch,
                "repro/instance": str(instance),
                "repro/pair": str(instance // 2),
            },
        },
        "spec": {
            "restartPolicy": "Never",   # the fleet layer owns recovery
            "containers": [{
                "name": "engine",
                "image": "repro-serve:latest",
                "args": ["python", "-m", "repro.launch.serve",
                         "--arch", spec.arch,
                         "--policy", spec.policy,
                         "--instances", str(spec.n_instances),
                         "--slots", str(spec.num_slots),
                         "--kv-capacity", str(spec.kv_capacity)],
                "env": [
                    {"name": "REPRO_INSTANCE_ID", "value": str(instance)},
                    {"name": "REPRO_N_INSTANCES",
                     "value": str(spec.n_instances)},
                ],
                "resources": {"limits": {
                    "google.com/tpu": 4,
                }},
                # an instance is routable only once its engine answers:
                # the warm-up (weights + first compile) stays off the
                # serving path, the same contract as warm_on_join
                "readinessProbe": {
                    "httpGet": {"path": "/healthz", "port": 8000},
                    "initialDelaySeconds": 30,
                    "periodSeconds": 5,
                },
            }],
            "nodeSelector": {
                "cloud.google.com/gke-tpu-accelerator": DEFAULT_ACCELERATOR,
            },
        },
        # sizing note for reviewers of the plan; stripped by kubectl
        "x-repro": {"params": int(cfg.param_count())},
    }


def fleet_manifest(spec: ServeSpec) -> List[dict]:
    return [pod_spec(spec, i) for i in range(spec.n_instances)]


def fleet_timeline(spec: ServeSpec, schedule: Optional[FleetSchedule],
                   seed: int = 0) -> List[dict]:
    """The orchestration steps, in order: initial rollout + readiness,
    then each fleet event as the pod operation it corresponds to, then
    teardown.  ``t`` is in the executor's clock units (iterations live,
    modeled seconds in the sim)."""
    steps: List[dict] = [
        {"t": 0.0, "op": "apply", "pods": [pod_name(spec, i)
                                           for i in range(spec.n_instances)]},
        {"t": 0.0, "op": "wait-ready",
         "pods": [pod_name(spec, i) for i in range(spec.n_instances)]},
    ]
    n = spec.n_instances
    for ev in (schedule.stream(seed) if schedule is not None else []):
        if isinstance(ev, KillInstance):
            steps.append({"t": ev.t, "op": "delete",
                          "pod": pod_name(spec, ev.instance),
                          "grace_period": 0})      # abrupt: SIGKILL
        elif isinstance(ev, JoinInstance):
            idx = ev.instance if ev.instance is not None else n
            n = max(n, idx + 1)
            steps.append({"t": ev.t, "op": "apply",
                          "pod": pod_name(spec, idx)})
            steps.append({"t": ev.t, "op": "wait-ready",
                          "pod": pod_name(spec, idx)})
        elif isinstance(ev, Drain):
            steps.append({"t": ev.t, "op": "cordon",
                          "pod": pod_name(spec, ev.instance)})
        elif isinstance(ev, DegradeInstance):
            # partial failure: the pod keeps serving — annotate it so
            # dashboards and affinity rules can see the straggler; the
            # scheduler-level response (hedging) happens in-band
            steps.append({"t": ev.t, "op": "annotate",
                          "pod": pod_name(spec, ev.instance),
                          "annotations": {
                              "repro/degraded": "true",
                              "repro/degrade-factor": str(ev.factor),
                              "repro/link-factor": str(ev.link_factor)}})
        elif isinstance(ev, RecoverInstance):
            steps.append({"t": ev.t, "op": "annotate",
                          "pod": pod_name(spec, ev.instance),
                          "annotations": {"repro/degraded": "false"}})
        else:
            raise ValueError(f"unknown fleet event {ev!r}")
    steps.append({"t": None, "op": "teardown",
                  "selector": "app=repro-serve"})
    return steps


def dry_run(spec: ServeSpec, schedule: Optional[FleetSchedule] = None,
            seed: int = 0) -> dict:
    """The full orchestration plan: manifests + timeline."""
    schedule = schedule if schedule is not None else spec.fleet
    return {
        "arch": spec.arch,
        "policy": spec.policy,
        "n_instances": spec.n_instances,
        "manifests": fleet_manifest(spec),
        "timeline": fleet_timeline(spec, schedule, seed=seed),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--policy", default="accellm", choices=policy_names())
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--kv-capacity", type=int, default=256)
    ap.add_argument("--fleet-mtbf", type=float, default=None,
                    help="mean time between failures (seeded Poisson)")
    ap.add_argument("--fleet-recovery", type=float, default=None,
                    help="time until a killed instance rejoins")
    ap.add_argument("--duration", type=float, default=600.0,
                    help="fault-injection window for --fleet-mtbf")
    ap.add_argument("--fleet-trace", default=None,
                    help="JSONL fleet trace to replay")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the JSON plan here instead of stdout")
    args = ap.parse_args()

    schedule: Optional[FleetSchedule] = None
    if args.fleet_trace:
        schedule = load_fleet_trace(args.fleet_trace)
    elif args.fleet_mtbf:
        schedule = PoissonFailures(mtbf=args.fleet_mtbf,
                                   duration=args.duration,
                                   n_instances=args.instances,
                                   recovery=args.fleet_recovery)
    spec = ServeSpec(arch=args.arch, policy=args.policy,
                     n_instances=args.instances, num_slots=args.slots,
                     kv_capacity=args.kv_capacity, fleet=schedule)
    plan = dry_run(spec, seed=args.seed)
    text = json.dumps(plan, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}: {len(plan['manifests'])} pods, "
              f"{len(plan['timeline'])} timeline steps")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
