"""Serving launcher: drive the AcceLLM cluster on live engines.

CPU-runnable with reduced configs (default); on a real TPU fleet the same
code paths run the full configs with the TP specs from launch/specs.py.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --instances 4 --requests 16 [--no-redundancy] [--workload mixed]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import AcceLLMCluster
from repro.models import init_params
from repro.serving import Request
from repro.sim.workload import WORKLOADS


def build_requests(cfg, n, workload, seed=0, scale=0.05):
    """Sample prompt/decode lengths from the paper's workload tables,
    scaled down for the CPU-sized engines."""
    (plo, phi), (dlo, dhi) = WORKLOADS[workload]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = max(4, int(rng.integers(plo, phi + 1) * scale))
        dlen = max(2, int(rng.integers(dlo, dhi + 1) * scale))
        extra = None
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            extra = {"patch_embeds": jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (1, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))}
        elif cfg.is_encoder_decoder:
            # frames length must equal the encoder memory capacity so the
            # engine can merge the per-request state into its slot
            extra = {"frames": jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (1, cfg.encoder.max_source_positions,
                 cfg.frontend.embed_dim))}
        reqs.append((Request(
            prompt_len=plen, max_new_tokens=dlen,
            prompt_tokens=jax.random.randint(
                jax.random.fold_in(key, i), (1, plen), 0, cfg.vocab_size)),
            extra))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--kv-capacity", type=int, default=256)
    ap.add_argument("--workload", default="mixed", choices=list(WORKLOADS))
    ap.add_argument("--no-redundancy", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) architecture")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"serving {cfg.name} on {args.instances} instances "
          f"({args.instances // 2} pairs), redundancy="
          f"{not args.no_redundancy}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    cluster = AcceLLMCluster(
        cfg, params, n_instances=args.instances, num_slots=args.slots,
        kv_capacity=args.kv_capacity, redundancy=not args.no_redundancy)
    for r, extra in build_requests(cfg, args.requests, args.workload):
        cluster.submit(r, extra)
    done = cluster.run(max_steps=2000)

    ttfts = [r.ttft() for r in done]
    jcts = [r.jct() for r in done]
    tbts = [t for r in done for t in r.tbts()] or [0.0]
    print(f"finished {len(done)}/{args.requests}")
    print(f"TTFT (iters): p50={np.percentile(ttfts, 50):.1f} "
          f"p99={np.percentile(ttfts, 99):.1f}")
    print(f"TBT  (iters): mean={np.mean(tbts):.2f} worst={max(tbts):.1f}")
    print(f"JCT  (iters): p50={np.percentile(jcts, 50):.1f} "
          f"p99={np.percentile(jcts, 99):.1f}")
    print("stats:", cluster.stats)
    return 0 if len(done) == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
