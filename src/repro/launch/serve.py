"""Serving launcher: drive a live-engine cluster under any registered
scheduling policy through the ``repro.api.serve`` facade.

CPU-runnable with reduced configs (default); on a real TPU fleet the same
code paths run the full configs with the TP specs from launch/specs.py.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --instances 4 --requests 16 [--policy accellm|vllm|splitwise|sarathi] \
      [--no-redundancy] [--workload mixed]
"""
from __future__ import annotations

import argparse

from repro.api import ServeSpec, serve
from repro.configs import list_archs
from repro.scheduling.registry import policy_names
from repro.sim.workload import WORKLOADS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--policy", default="accellm", choices=policy_names(),
                    help="scheduling policy (shared kernel; the same names "
                         "drive the simulator)")
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--kv-capacity", type=int, default=256)
    ap.add_argument("--workload", default="mixed", choices=list(WORKLOADS))
    ap.add_argument("--no-redundancy", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) architecture")
    args = ap.parse_args()

    spec = ServeSpec(
        arch=args.arch, policy=args.policy, n_instances=args.instances,
        num_slots=args.slots, kv_capacity=args.kv_capacity,
        redundancy=not args.no_redundancy, reduced=not args.full_config,
        workload=args.workload, n_requests=args.requests)
    print(f"serving {args.arch} on {args.instances} instances "
          f"with policy={args.policy}, redundancy={spec.redundancy}")
    report = serve(spec)
    print(report.describe())
    return 0 if report.all_finished else 1


if __name__ == "__main__":
    raise SystemExit(main())
