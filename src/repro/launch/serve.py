"""Serving launcher: drive a live-engine cluster under any registered
scheduling policy through the ``repro.api.serve`` facade.

CPU-runnable with reduced configs (default); on a real TPU fleet the same
code paths run the full configs with the TP specs from launch/specs.py.

Traffic comes from the shared workload layer (``repro.workloads``): the
default is the legacy batch-at-t=0 request set, but ``--arrival poisson``
/ ``bursty`` / ``diurnal`` run the cluster open-loop with requests
arriving over time on the iteration clock, and ``--arrival closed``
keeps ``--concurrency`` requests in flight.  ``--slo-ttft/--slo-tbt``
add SLO attainment and goodput to the report.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --instances 4 --requests 16 \
      [--policy accellm|vllm|splitwise|sarathi|ulb] \
      [--no-redundancy] [--workload mixed] [--arrival poisson --rate 0.5 \
      --duration 60] [--slo-ttft 20 --slo-tbt 4]

Every registered policy name is accepted, including the ``-vec``
variants (``accellm-vec`` / ``vllm-vec`` / ``splitwise-vec`` /
``ulb-vec``) — on live engines those fall back to the identical scalar
decision path, so they are interchangeable with the originals.
"""
from __future__ import annotations

import argparse

from repro.api import ServeSpec, serve
from repro.configs import list_archs
from repro.fleet import (FixedFleet, PoissonDegradations, PoissonFailures,
                         load_fleet_trace)
from repro.scheduling.registry import policy_names
from repro.workloads import (SLO, TABLE2, Batch, Bursty, ClosedLoop,
                             DiurnalRamp, Poisson, PrefixReuse, TableLengths,
                             WorkloadSpec)


def build_arrival(args):
    if args.arrival == "batch":
        return Batch(args.requests)
    if args.arrival == "poisson":
        return Poisson(rate=args.rate, duration=args.duration)
    if args.arrival == "bursty":
        return Bursty(rate_on=args.rate, duration=args.duration,
                      mean_on=args.mean_on, mean_off=args.mean_off)
    if args.arrival == "diurnal":
        return DiurnalRamp(low=args.rate / 4.0, peak=args.rate,
                           period=args.duration, duration=args.duration)
    if args.arrival == "closed":
        return ClosedLoop(k=args.concurrency, n_requests=args.requests)
    raise ValueError(args.arrival)


def build_fleet(args):
    """Fleet fault-injection schedule from the CLI flags (repro.fleet):
    a recorded JSONL trace replays exactly; an MTBF draws seeded
    Poisson failures across the serve window, and ``--degrade-mtbf``
    adds seeded partial failures (stragglers).  When both are given the
    two streams are pre-drawn with the run's seed and merged into one
    deterministic schedule."""
    if args.fleet_trace:
        return load_fleet_trace(args.fleet_trace)
    schedules = []
    if args.fleet_mtbf:
        schedules.append(PoissonFailures(mtbf=args.fleet_mtbf,
                                         duration=args.duration,
                                         n_instances=args.instances,
                                         recovery=args.fleet_recovery))
    if args.degrade_mtbf:
        schedules.append(PoissonDegradations(
            mtbf=args.degrade_mtbf, duration=args.duration,
            n_instances=args.instances, recovery=args.degrade_recovery,
            factor=args.degrade_factor))
    if not schedules:
        return None
    if len(schedules) == 1:
        return schedules[0]
    merged = sorted((ev for s in schedules for ev in s.stream(args.seed)),
                    key=lambda e: e.t)
    return FixedFleet(fleet_events=tuple(merged))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--policy", default="accellm", choices=policy_names(),
                    help="scheduling policy (shared kernel; the same names "
                         "drive the simulator)")
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--kv-capacity", type=int, default=256)
    ap.add_argument("--block-lines", type=int, default=None,
                    help="KV lines per block in the paged store "
                         "(default: largest divisor of kv-capacity <= 16)")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="fused decode ceiling: idle open-loop stretches "
                         "run up to N decode iterations as one jitted scan")
    ap.add_argument("--workload", default="mixed", choices=list(TABLE2))
    ap.add_argument("--scale", type=float, default=0.05,
                    help="length scale for CPU-sized engines")
    ap.add_argument("--arrival", default="batch",
                    choices=["batch", "poisson", "bursty", "diurnal",
                             "closed"])
    ap.add_argument("--rate", type=float, default=0.5,
                    help="arrivals per iteration (open-loop modes)")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="arrival window in iterations (open-loop modes)")
    ap.add_argument("--mean-on", type=float, default=8.0)
    ap.add_argument("--mean-off", type=float, default=8.0)
    ap.add_argument("--concurrency", type=int, default=4,
                    help="in-flight requests for --arrival closed")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="TTFT target in iterations")
    ap.add_argument("--slo-tbt", type=float, default=None,
                    help="per-token TBT target in iterations")
    ap.add_argument("--fleet-mtbf", type=float, default=None,
                    help="mean iterations between instance failures "
                         "(seeded Poisson fault injection)")
    ap.add_argument("--fleet-recovery", type=float, default=None,
                    help="iterations until a killed instance rejoins "
                         "(default: never)")
    ap.add_argument("--fleet-trace", default=None,
                    help="JSONL fleet trace to replay "
                         "(repro.fleet.save_fleet_trace)")
    ap.add_argument("--degrade-mtbf", type=float, default=None,
                    help="mean iterations between partial failures "
                         "(seeded Poisson straggler injection)")
    ap.add_argument("--degrade-factor", type=float, default=4.0,
                    help="slowdown factor of a degraded instance")
    ap.add_argument("--degrade-recovery", type=float, default=None,
                    help="iterations until a degraded instance returns "
                         "to full speed (default: never)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: shed arrivals once the "
                         "backlog holds this many requests")
    ap.add_argument("--shed-deadline", type=float, default=None,
                    help="shed queued requests waiting longer than this "
                         "many iterations (deadline-aware admission)")
    ap.add_argument("--no-hedging", action="store_true",
                    help="disable straggler hedging in hedging-aware "
                         "policies (decode stays on degraded instances)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted radix prefix cache on every engine: "
                         "shared prompt heads prefill once and dedup in HBM")
    ap.add_argument("--prefix-cache-blocks", type=int, default=None,
                    help="cache retention cap in pool blocks "
                         "(default: half of each engine's block pool)")
    ap.add_argument("--prefix-reuse", type=float, default=0.0,
                    help="probability a request shares a pooled prompt "
                         "prefix (enables prefix-reuse traffic when > 0)")
    ap.add_argument("--prefix-pool", type=int, default=4,
                    help="number of shared prefix groups (system prompts)")
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="declared shared-prefix length in tokens")
    ap.add_argument("--mesh-tp", type=int, default=None,
                    help="tensor-parallel width per instance: carve the "
                         "host's devices into per-instance mesh slices "
                         "(repro.meshserve) and shard params + KV pool; "
                         "needs instances*tp devices (on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-steps", type=int, default=2000)
    ap.add_argument("--no-redundancy", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-reduced) architecture")
    args = ap.parse_args()

    reuse = (PrefixReuse(pool=args.prefix_pool, reuse=args.prefix_reuse,
                         prefix_len=args.prefix_len)
             if args.prefix_reuse > 0 else None)
    traffic = WorkloadSpec(
        arrival=build_arrival(args),
        lengths=TableLengths(args.workload, scale=args.scale),
        name=args.workload, prefix_reuse=reuse)
    slo = None
    if args.slo_ttft is not None or args.slo_tbt is not None:
        slo = SLO(ttft=args.slo_ttft if args.slo_ttft is not None
                  else float("inf"),
                  tbt=args.slo_tbt if args.slo_tbt is not None
                  else float("inf"))
    spec = ServeSpec(
        arch=args.arch, policy=args.policy, n_instances=args.instances,
        num_slots=args.slots, kv_capacity=args.kv_capacity,
        block_lines=args.block_lines, fuse_decode_steps=args.fuse_steps,
        prefix_cache=args.prefix_cache,
        prefix_cache_blocks=args.prefix_cache_blocks,
        redundancy=not args.no_redundancy, hedging=not args.no_hedging,
        max_queue=args.max_queue, shed_deadline=args.shed_deadline,
        reduced=not args.full_config,
        seed=args.seed, max_steps=args.max_steps, traffic=traffic, slo=slo,
        fleet=build_fleet(args), mesh_tp=args.mesh_tp)
    print(f"serving {args.arch} on {args.instances} instances "
          f"with policy={args.policy}, redundancy={spec.redundancy}"
          + (f", mesh_tp={args.mesh_tp}" if args.mesh_tp else "")
          + (", prefix_cache=on" if args.prefix_cache else ""))
    print(traffic.describe())
    report = serve(spec)
    print(report.describe())
    return 0 if report.all_finished else 1


if __name__ == "__main__":
    raise SystemExit(main())
