"""Training launcher: run the substrate end-to-end on any architecture
(reduced on CPU; the full configs lower via launch/dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
      --steps 100 [--schedule wsd] [--ckpt /tmp/ckpt.npz]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import init_params
from repro.training import (SCHEDULES, AdamWConfig, DataConfig, batches,
                            init_opt_state, make_train_step)
from repro.training.checkpoint import save


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="wsd", choices=list(SCHEDULES))
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps, schedule={args.schedule}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=args.lr)
    opt = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    sched = SCHEDULES[args.schedule]
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch, seed=0))
    needs_extra = cfg.frontend is not None or cfg.is_encoder_decoder
    key = jax.random.PRNGKey(7)

    t0 = time.time()
    first = last = None
    for i, b in zip(range(args.steps), data):
        batch = {"tokens": jnp.asarray(b[:, :-1]),
                 "labels": jnp.asarray(b[:, 1:])}
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            batch["patch_embeds"] = jax.random.normal(
                key, (args.batch, cfg.frontend.num_prefix_tokens,
                      cfg.frontend.embed_dim))
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                key, (args.batch, 32, cfg.frontend.embed_dim))
        lr = sched(i, warmup=max(args.steps // 10, 1), total=args.steps)
        params, opt, m = step_fn(params, opt, batch, lr)
        last = float(m["loss"])
        first = first if first is not None else last
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss={last:.4f} lr={float(lr):.3f} "
                  f"tok/s={tok_s:.0f}")
    if args.ckpt:
        save(args.ckpt, params)
        print(f"checkpoint: {args.ckpt}")
    print(f"loss {first:.4f} -> {last:.4f}")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
