"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

MUST set the host-device override before any other import (jax locks the
device count on first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import sharding  # noqa: E402
from repro.configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from repro.launch.mesh import batch_axes, make_production_mesh  # noqa: E402
from repro.launch.specs import (input_specs, param_pspecs, pick_layout,  # noqa: E402
                                state_pspecs)
from repro.models import decode_step, init_params, init_state, prefill  # noqa: E402
from repro.training import AdamWConfig, init_opt_state, train_step  # noqa: E402
from repro.training.optimizer import OptState  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_lowered(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    long_ctx = shape_name == "long_500k"
    mesh = make_production_mesh(multi_pod=multi_pod)
    layout = pick_layout(cfg, shape)
    if layout == "fsdp":
        b_axes = (("pod", "data", "model") if multi_pod
                  else ("data", "model"))
        model_axis = None
    else:
        b_axes = batch_axes(multi_pod)
        model_axis = "model"

    # optimizer-state dtype: bf16 m/v for the huge MoE/hybrid archs so the
    # per-chip footprint stays inside 16 GB v5e HBM (DESIGN.md §5)
    big = cfg.param_count() > 100e9
    opt_cfg = AdamWConfig(state_dtype="bfloat16" if big else "float32")

    params_shape = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    mode = "train" if shape.kind == "train" else "serve"
    p_specs = param_pspecs(cfg, params_shape, mode=mode, multi_pod=multi_pod,
                           layout=layout)
    sds, in_specs = input_specs(cfg, shape, multi_pod, layout=layout)

    with sharding.use_mesh(mesh, batch_axes=b_axes, model_axis=model_axis):
        if shape.kind == "train":
            opt_shape = jax.eval_shape(
                lambda p: init_opt_state(opt_cfg, p), params_shape)
            opt_specs = OptState(step=P(), m=p_specs, v=p_specs)

            def fn(params, opt_state, batch, lr):
                return train_step(cfg, opt_cfg, params, opt_state, batch, lr)

            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, opt_specs),
                              _named(mesh, in_specs),
                              NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            ).lower(params_shape, opt_shape, sds,
                    jax.ShapeDtypeStruct((), jnp.float32))
        elif shape.kind == "prefill":
            state_shape = jax.eval_shape(
                lambda: init_state(cfg, shape.global_batch, shape.seq_len,
                                   long_ctx))
            s_specs = state_pspecs(cfg, state_shape, shape,
                                   long_context=long_ctx, multi_pod=multi_pod)

            def fn(params, batch, state):
                logits, state = prefill(cfg, params, batch, state,
                                        long_context=long_ctx)
                return jnp.argmax(logits, -1).astype(jnp.int32), state

            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, in_specs),
                              _named(mesh, s_specs)),
                donate_argnums=(2,),
            ).lower(params_shape, sds, state_shape)
        else:  # decode
            state_shape = jax.eval_shape(
                lambda: init_state(cfg, shape.global_batch, shape.seq_len,
                                   long_ctx))
            s_specs = state_pspecs(cfg, state_shape, shape,
                                   long_context=long_ctx, multi_pod=multi_pod)
            t_sds = sds.pop("t")
            t_spec = in_specs.pop("t")

            def fn(params, tokens, state, t):
                logits, state = decode_step(cfg, params, tokens, state, t,
                                            long_context=long_ctx)
                return jnp.argmax(logits, -1).astype(jnp.int32), state

            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs),
                              NamedSharding(mesh, in_specs["tokens"]),
                              _named(mesh, s_specs),
                              NamedSharding(mesh, t_spec)),
                donate_argnums=(2,),
            ).lower(params_shape, sds["tokens"], state_shape, t_sds)
    return lowered, mesh


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save_hlo: bool = True) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
           "layout": pick_layout(get_config(arch), INPUT_SHAPES[shape_name])}
    try:
        lowered, mesh = build_lowered(arch, shape_name, multi_pod)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec.update(ok=True, lower_s=round(t1 - t0, 1),
                   compile_s=round(t2 - t1, 1))
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
        if cost:
            rec["flops"] = float(cost.get("flops", 0.0))
            rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
            rec["transcendentals"] = float(cost.get("transcendentals", 0.0))
        if save_hlo:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            tag = f"{arch}_{shape_name}_{rec['mesh']}"
            with open(os.path.join(RESULTS_DIR, f"hlo_{tag}.txt"), "w") as f:
                f.write(compiled.as_text())
        print(f"[OK] {arch} {shape_name} {rec['mesh']} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"flops={rec.get('flops', 0):.3e}")
    except Exception as e:  # noqa: BLE001 — a failure here is a finding
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAIL] {arch} {shape_name} {rec['mesh']}: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                records.append(run_one(arch, shape, mp,
                                       save_hlo=not args.no_hlo))
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(r["ok"] for r in records)
    print(f"\n{n_ok}/{len(records)} combinations lowered+compiled")
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
