"""End-to-end driver (deliverable b): serve a small model with batched
requests through the full AcceLLM cluster — pairs, dynamic roles, redundant
KV, per-layer streaming, load balancing — and report TTFT/TBT/JCT.

Run: PYTHONPATH=src python examples/serve_cluster.py \
        [--arch phi3-medium-14b] [--requests 12] [--instances 4]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import AcceLLMCluster
from repro.models import init_params
from repro.serving import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--no-redundancy", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cluster = AcceLLMCluster(cfg, params, n_instances=args.instances,
                             num_slots=8, kv_capacity=256,
                             redundancy=not args.no_redundancy)
    key = jax.random.PRNGKey(42)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        req = Request(
            prompt_len=plen, max_new_tokens=int(rng.integers(4, 16)),
            prompt_tokens=jax.random.randint(
                jax.random.fold_in(key, i), (1, plen), 0, cfg.vocab_size))
        cluster.submit(req)

    done = cluster.run(max_steps=500)
    assert len(done) == args.requests, "not all requests completed"

    ttfts = [r.ttft() for r in done]
    jcts = [r.jct() for r in done]
    tbts = [t for r in done for t in r.tbts()]
    print(f"finished {len(done)}/{args.requests} requests on "
          f"{args.instances} instances ({len(cluster.pairs)} pairs)")
    print(f"TTFT (iters): p50={np.percentile(ttfts, 50):.1f} "
          f"max={max(ttfts):.1f}")
    print(f"TBT  (iters): mean={np.mean(tbts):.2f} worst={max(tbts):.1f}")
    print(f"JCT  (iters): p50={np.percentile(jcts, 50):.1f} "
          f"max={max(jcts):.1f}")
    print("scheduler stats:", cluster.stats)


if __name__ == "__main__":
    main()
