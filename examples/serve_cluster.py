"""End-to-end driver (deliverable b): one traffic kernel, two clocks.

A single :class:`repro.workloads.WorkloadSpec` — bursty MMPP arrivals
with uniform lengths — drives BOTH backends with no per-backend workload
code:

* **live**: requests arrive over time on the scheduling-iteration clock
  (open loop) through the unified ``repro.api.serve`` facade — pairs,
  dynamic roles, redundant KV, load balancing on real JAX engines — and
  the report prints SLO attainment / goodput alongside TTFT/TBT/JCT.
* **sim**: the identical spec (same seed, same request stream) runs on
  the discrete-event simulator in modeled seconds.

Run: PYTHONPATH=src python examples/serve_cluster.py \
        [--arch phi3-medium-14b] [--instances 4] [--policy accellm] \
        [--duration 40] [--seed 0] [--prefix-reuse 0.6]

``--prefix-reuse p`` adds a pool of shared system prompts to the
traffic and enables the radix prefix cache on both backends: repeated
prompt heads prefill once, dedup in HBM, and the reports show the hit
accounting (identically priced on live engines and the simulator).
"""
import argparse

from repro.api import ServeSpec, serve
from repro.configs import get_config, list_archs
from repro.scheduling.registry import policy_names
from repro.sim import (H100, InstanceSpec, PerfModel, Simulator, summarize)
from repro.sim.policies import AcceLLMPolicy
from repro.workloads import (SLO, Bursty, PrefixReuse, UniformLengths,
                             WorkloadSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--policy", default="accellm", choices=policy_names())
    ap.add_argument("--duration", type=float, default=40.0,
                    help="arrival window in traffic time units")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-redundancy", action="store_true")
    ap.add_argument("--prefix-reuse", type=float, default=0.0,
                    help="shared-prefix probability; > 0 enables the "
                         "prefix cache on both backends")
    args = ap.parse_args()

    # the one workload description both backends consume
    traffic = WorkloadSpec(
        arrival=Bursty(rate_on=0.8, duration=args.duration,
                       mean_on=6.0, mean_off=6.0),
        lengths=UniformLengths(prompt=(8, 48), decode=(4, 16)),
        name="bursty-demo",
        prefix_reuse=(PrefixReuse(pool=3, reuse=args.prefix_reuse,
                                  prefix_len=16)
                      if args.prefix_reuse > 0 else None))
    use_cache = args.prefix_reuse > 0
    slo = SLO(ttft=12.0, tbt=4.0)

    # -- live backend: open loop on the iteration clock ----------------------
    spec = ServeSpec(arch=args.arch, policy=args.policy,
                     n_instances=args.instances, num_slots=8,
                     kv_capacity=256, redundancy=not args.no_redundancy,
                     prefix_cache=use_cache,
                     seed=args.seed, max_steps=800, traffic=traffic, slo=slo)
    print(f"live: {traffic.describe()}")
    report = serve(spec)
    assert report.all_finished, "not all requests completed"
    print(f"finished {len(report.finished)}/{report.n_submitted} requests on "
          f"{args.instances} instances with policy={args.policy}")
    print(report.describe())
    if use_cache:
        print(f"live prefix cache: {report.stats['prefix_hits']} hits, "
              f"{report.stats['prefix_hit_tokens']} prefill tokens saved, "
              f"{report.stats['stream_skipped_lines']} replica lines "
              f"skipped")

    # -- simulator backend: the identical spec, modeled seconds --------------
    sim = Simulator(AcceLLMPolicy(redundancy=not args.no_redundancy),
                    PerfModel(get_config(args.arch), InstanceSpec(H100, 4)),
                    n_instances=args.instances, prefix_cache=use_cache)
    done = sim.run(source=traffic.source(seed=args.seed),
                   horizon=args.duration * 10)
    s = summarize(sim.submitted, args.instances,
                  max(sim.now, args.duration), slo=SLO(ttft=2.0, tbt=0.5))
    print(f"\nsim: same spec, same seed -> {len(done)} finished in modeled "
          f"seconds")
    print(f"sim: ttft_p50={s.ttft_p50:.3f}s tbt_mean={s.tbt_mean * 1e3:.1f}ms"
          f" jct_p50={s.jct_p50:.2f}s slo_attainment={s.slo_attainment:.1%}"
          f" goodput={s.goodput:.2f}req/s")
    if use_cache:
        hits = sum(i.prefix_cache.stats["hits"] for i in sim.instances
                   if i.prefix_cache is not None)
        saved = sum(i.prefix_cache.stats["hit_tokens"]
                    for i in sim.instances if i.prefix_cache is not None)
        print(f"sim prefix cache: {hits} hits, {saved} prefill tokens "
              f"saved (same aligned-hit rule as the live engines)")


if __name__ == "__main__":
    main()
