"""End-to-end driver (deliverable b): one traffic kernel, two clocks.

A single :class:`repro.workloads.WorkloadSpec` — bursty MMPP arrivals
with uniform lengths — drives BOTH backends with no per-backend workload
code:

* **live**: requests arrive over time on the scheduling-iteration clock
  (open loop) through the unified ``repro.api.serve`` facade — pairs,
  dynamic roles, redundant KV, load balancing on real JAX engines — and
  the report prints SLO attainment / goodput alongside TTFT/TBT/JCT.
* **sim**: the identical spec (same seed, same request stream) runs on
  the discrete-event simulator in modeled seconds.

Run: PYTHONPATH=src python examples/serve_cluster.py \
        [--arch phi3-medium-14b] [--instances 4] [--policy accellm] \
        [--duration 40] [--seed 0]
"""
import argparse

from repro.api import ServeSpec, serve
from repro.configs import get_config, list_archs
from repro.scheduling.registry import policy_names
from repro.sim import (H100, InstanceSpec, PerfModel, Simulator, summarize)
from repro.sim.policies import AcceLLMPolicy
from repro.workloads import (SLO, Bursty, UniformLengths, WorkloadSpec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--policy", default="accellm", choices=policy_names())
    ap.add_argument("--duration", type=float, default=40.0,
                    help="arrival window in traffic time units")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-redundancy", action="store_true")
    args = ap.parse_args()

    # the one workload description both backends consume
    traffic = WorkloadSpec(
        arrival=Bursty(rate_on=0.8, duration=args.duration,
                       mean_on=6.0, mean_off=6.0),
        lengths=UniformLengths(prompt=(8, 48), decode=(4, 16)),
        name="bursty-demo")
    slo = SLO(ttft=12.0, tbt=4.0)

    # -- live backend: open loop on the iteration clock ----------------------
    spec = ServeSpec(arch=args.arch, policy=args.policy,
                     n_instances=args.instances, num_slots=8,
                     kv_capacity=256, redundancy=not args.no_redundancy,
                     seed=args.seed, max_steps=800, traffic=traffic, slo=slo)
    print(f"live: {traffic.describe()}")
    report = serve(spec)
    assert report.all_finished, "not all requests completed"
    print(f"finished {len(report.finished)}/{report.n_submitted} requests on "
          f"{args.instances} instances with policy={args.policy}")
    print(report.describe())

    # -- simulator backend: the identical spec, modeled seconds --------------
    sim = Simulator(AcceLLMPolicy(redundancy=not args.no_redundancy),
                    PerfModel(get_config(args.arch), InstanceSpec(H100, 4)),
                    n_instances=args.instances)
    done = sim.run(source=traffic.source(seed=args.seed),
                   horizon=args.duration * 10)
    s = summarize(sim.submitted, args.instances,
                  max(sim.now, args.duration), slo=SLO(ttft=2.0, tbt=0.5))
    print(f"\nsim: same spec, same seed -> {len(done)} finished in modeled "
          f"seconds")
    print(f"sim: ttft_p50={s.ttft_p50:.3f}s tbt_mean={s.tbt_mean * 1e3:.1f}ms"
          f" jct_p50={s.jct_p50:.2f}s slo_attainment={s.slo_attainment:.1%}"
          f" goodput={s.goodput:.2f}req/s")


if __name__ == "__main__":
    main()
