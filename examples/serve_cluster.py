"""End-to-end driver (deliverable b): serve a small model with batched
requests through the unified ``repro.api.serve`` facade — pairs, dynamic
roles, redundant KV, per-layer streaming, load balancing — and report
TTFT/TBT/JCT.  Any registered policy (accellm / vllm / splitwise /
sarathi) runs on the same live engines.

Run: PYTHONPATH=src python examples/serve_cluster.py \
        [--arch phi3-medium-14b] [--requests 12] [--instances 4] \
        [--policy accellm]
"""
import argparse

import jax
import numpy as np

from repro.api import ServeSpec, serve
from repro.configs import get_config, list_archs
from repro.scheduling.registry import policy_names
from repro.serving import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--policy", default="accellm", choices=policy_names())
    ap.add_argument("--no-redundancy", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(42)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        reqs.append(Request(
            prompt_len=plen, max_new_tokens=int(rng.integers(4, 16)),
            prompt_tokens=jax.random.randint(
                jax.random.fold_in(key, i), (1, plen), 0, cfg.vocab_size)))

    spec = ServeSpec(arch=args.arch, policy=args.policy,
                     n_instances=args.instances, num_slots=8,
                     kv_capacity=256, redundancy=not args.no_redundancy,
                     max_steps=500)
    report = serve(spec, requests=reqs, cfg=cfg)
    assert report.all_finished, "not all requests completed"

    print(f"finished {len(report.finished)}/{args.requests} requests on "
          f"{args.instances} instances with policy={args.policy}")
    print(report.describe())


if __name__ == "__main__":
    main()
