"""Train a ~100M-param model for a few hundred steps on the synthetic
pipeline (deliverable b: end-to-end training driver), with WSD schedule and
checkpointing.

Run: PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.training import (AdamWConfig, DataConfig, batches, init_opt_state,
                            make_train_step, wsd)
from repro.training.checkpoint import restore, save


def build_100m():
    """A ~100M-parameter MiniCPM-family model (WSD is its native recipe)."""
    base = get_config("minicpm-2b")
    return dataclasses.replace(
        base, name="minicpm-100m", num_layers=8, d_model=768, num_heads=12,
        num_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=32768,
        block_pattern=tuple(["attn"] * 8), dtype="float32",
        residual_scale=1.4 / 8 ** 0.5, logit_scale=256.0 / 768.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_small.npz")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.0f}M params")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=6e-4)
    opt = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch, seed=0))

    t0 = time.time()
    first = None
    for i, b in zip(range(args.steps), data):
        batch = {"tokens": jnp.asarray(b[:, :-1]),
                 "labels": jnp.asarray(b[:, 1:])}
        lr = wsd(i, warmup=20, total=args.steps)
        params, opt, m = step_fn(params, opt, batch, lr)
        loss = float(m["loss"])
        first = first or loss
        if i % 25 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss={loss:.4f} lr={float(lr):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f} tok/s={tok_s:.0f}")
    assert loss < first, "loss did not improve"
    save(args.ckpt, params)
    restored = restore(args.ckpt, params)
    print(f"checkpoint saved+restored at {args.ckpt}; "
          f"final loss {loss:.4f} (from {first:.4f})")


if __name__ == "__main__":
    main()
