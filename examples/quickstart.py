"""Quickstart: load an architecture, run prefill + a few decode steps, and
show the AcceLLM redundancy primitives on a single pair of instances —
then serve a small batch through the unified ``repro.api.serve`` facade.

Run: PYTHONPATH=src python examples/quickstart.py [--arch starcoder2-3b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import ServeSpec, serve
from repro.configs import get_config, list_archs
from repro.core.kvbytes import state_bytes_at
from repro.models import init_params
from repro.serving import InstanceEngine, Request
from repro.workloads import Poisson, TableLengths, WorkloadSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=list_archs())
    args = ap.parse_args()

    cfg_full = get_config(args.arch)
    cfg = cfg_full.reduced()     # CPU-sized variant of the same family
    print(f"arch={cfg_full.name} family={cfg_full.family} "
          f"params={cfg_full.param_count() / 1e9:.1f}B "
          f"(running reduced {cfg.num_layers}L/{cfg.d_model}d on CPU)")
    print(f"serving state at len 1024: "
          f"{state_bytes_at(cfg_full, 1024) / 1e6:.1f} MB/request")

    params = init_params(jax.random.PRNGKey(0), cfg)
    a = InstanceEngine(cfg, params, num_slots=4, kv_capacity=128,
                       instance_id=0)
    b = InstanceEngine(cfg, params, num_slots=4, kv_capacity=128,
                       instance_id=1)

    req = Request(prompt_len=16, max_new_tokens=8,
                  prompt_tokens=jax.random.randint(
                      jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size))
    slot = a.prefill_request(req)
    print(f"prefilled rid={req.rid} on instance 0 slot {slot}; "
          f"first token: {req.output_tokens[0]}")

    # AcceLLM §4.1.2: stream state to the partner, keep a redundant copy
    b.import_slot(0, a.export_slot(slot), req)
    a.demote_to_replica(slot, of=(1, 0))
    print("state streamed to instance 1 (primary); instance 0 keeps replica")

    for _ in range(4):
        b.decode()
        a.sync_replica_from(b, 0, slot)   # mirror new KV lines back
    print(f"decoded on instance 1: tokens={req.output_tokens}")

    # zero-cost migration back (role flip): replica promotion
    a.promote_replica(slot, req)
    b.demote_to_replica(0, of=(0, slot))
    for _ in range(req.max_new_tokens - req.generated):
        a.decode()
    print(f"finished on instance 0 after zero-cost migration: "
          f"tokens={req.output_tokens}")
    assert len(req.output_tokens) == req.max_new_tokens

    # the same mechanism, end to end: one pair under the full AcceLLM
    # policy via the unified serving facade, fed by the shared traffic
    # layer (Poisson arrivals over the iteration clock, Table-2 lengths
    # scaled for CPU engines)
    traffic = WorkloadSpec(arrival=Poisson(rate=0.5, duration=8.0),
                           lengths=TableLengths("light", scale=0.05),
                           name="quickstart")
    spec = ServeSpec(arch=args.arch, policy="accellm", n_instances=2,
                     num_slots=4, kv_capacity=128, traffic=traffic,
                     max_steps=200)
    report = serve(spec, cfg=cfg, params=params)
    print(f"facade run (open loop): finished {len(report.finished)}/"
          f"{report.n_submitted}, stats={report.stats}")
    assert report.all_finished
    print("OK")


if __name__ == "__main__":
    main()
