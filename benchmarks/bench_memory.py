"""Paper Fig. 9: peak per-instance state memory vs request rate — AcceLLM's
redundant copies cost only a few extra GB."""
import time

from benchmarks.common import emit, policies_for, run_sim


def main():
    for rate in (4.0, 8.0, 12.0):
        peaks = {}
        for name, pol in policies_for(4).items():
            t0 = time.perf_counter()
            sim, _ = run_sim(pol, "mixed", rate, 40.0, 4)
            us = (time.perf_counter() - t0) * 1e6
            peaks[name] = max(i.peak_state_bytes for i in sim.instances) / 1e9
        emit(f"fig9_memory_rate{int(rate)}", us,
             f"vllm={peaks['vllm']:.1f}GB;splitwise={peaks['splitwise']:.1f}GB;"
             f"accellm={peaks['accellm']:.1f}GB;"
             f"overhead={peaks['accellm'] - peaks['splitwise']:.1f}GB")


if __name__ == "__main__":
    main()
