"""Shared benchmark helpers. Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = the paper-figure quantity).

Traffic comes from the shared layer (``repro.workloads``): ``run_sim``
builds a Poisson × Table-2 :class:`WorkloadSpec` by default, and any
benchmark can pass its own spec (bursty, diurnal, trace replay, closed
loop) — the same object would drive the live backend unchanged.

Set ``REPRO_BENCH_SMOKE=1`` to shrink rates/durations for CI smoke runs.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

from repro.configs import get_config
from repro.sim import (AcceLLMPolicy, ASCEND_910B2, H100, InstanceSpec,
                       PerfModel, Simulator, SplitwisePolicy, ULBPolicy,
                       VLLMPolicy, summarize)
from repro.workloads import SLO, WorkloadSpec, table2_spec

CFG = get_config("llama2-70b")            # the paper's eval model (§5.2)

#: CI smoke mode: tiny workloads so the entry points can't silently rot
SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))

#: latency targets used for goodput columns (modeled seconds; roughly the
#: interactive-serving targets the paper's §5 plots are judged against)
DEFAULT_SLO = SLO(ttft=2.0, tbt=0.2)


def perf(device=H100, n_dev=4, inst: Optional[InstanceSpec] = None
         ) -> PerfModel:
    """Cost model for one instance; pass ``inst`` to price a fully
    specified slice (per-link bandwidths, heterogeneous pods)."""
    return PerfModel(CFG, inst or InstanceSpec(device, n_dev))


def decode_time(pm: PerfModel, lengths) -> float:
    """Price one decode iteration through the single step-cost entry
    point (``PerfModel.decode_step_time`` is deprecated)."""
    from repro.stepplan import DecodePlan
    return pm.plan_time(DecodePlan(0, lengths=tuple(lengths)))


def run_sim(policy, workload, rate, duration, n_instances, device=H100,
            seed=0, horizon_mult=10.0, spec: Optional[WorkloadSpec] = None,
            slo: Optional[SLO] = DEFAULT_SLO,
            inst: Optional[InstanceSpec] = None):
    """Simulate ``spec`` (default: Poisson × Table-2 at ``rate`` for
    ``duration``) under ``policy`` and summarize, including SLO
    attainment/goodput.  ``inst`` prices every instance on an explicit
    :class:`InstanceSpec` (e.g. per-link bandwidths) instead of a bare
    ``device``."""
    if SMOKE:
        rate, duration = min(rate, 4.0), min(duration, 5.0)
    if spec is None:
        spec = table2_spec(workload, rate=rate, duration=duration)
    sim = Simulator(policy, perf(device, inst=inst),
                    n_instances=n_instances)
    sim.run(source=spec.source(seed=seed), horizon=duration * horizon_mult)
    # score ALL offered traffic (stragglers count as unfinished / SLO
    # misses) over the time the cluster actually ran
    elapsed = max(sim.now, float(duration))
    return sim, summarize(sim.submitted, n_instances, elapsed, slo=slo,
                          sched_us_per_iter=sim.sched_us_per_iter)


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Mean wall microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


POLICIES = {
    "vllm": VLLMPolicy,
    "splitwise": lambda: SplitwisePolicy(1),
    "accellm": AcceLLMPolicy,
    "ulb": ULBPolicy,
}


def policies_for(n_instances: int):
    n_prefill = {4: 1, 8: 2, 16: 4}.get(n_instances, max(1, n_instances // 4))
    return {
        "vllm": VLLMPolicy(),
        "splitwise": SplitwisePolicy(n_prefill),
        "accellm": AcceLLMPolicy(),
        "ulb": ULBPolicy(),
    }
