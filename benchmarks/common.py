"""Shared benchmark helpers. Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = the paper-figure quantity)."""
from __future__ import annotations

import copy
import time
from typing import Callable

from repro.configs import get_config
from repro.sim import (AcceLLMPolicy, ASCEND_910B2, H100, InstanceSpec,
                       PerfModel, Simulator, SplitwisePolicy, VLLMPolicy,
                       make_workload, summarize)

CFG = get_config("llama2-70b")            # the paper's eval model (§5.2)


def perf(device=H100, n_dev=4) -> PerfModel:
    return PerfModel(CFG, InstanceSpec(device, n_dev))


def run_sim(policy, workload, rate, duration, n_instances, device=H100,
            seed=0, horizon_mult=10.0):
    reqs = make_workload(workload, rate=rate, duration=duration, seed=seed)
    sim = Simulator(policy, perf(device), n_instances=n_instances)
    done = sim.run([copy.deepcopy(r) for r in reqs],
                   horizon=duration * horizon_mult)
    return sim, summarize(done, n_instances, duration * horizon_mult)


def timed(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Mean wall microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


POLICIES = {
    "vllm": VLLMPolicy,
    "splitwise": lambda: SplitwisePolicy(1),
    "accellm": AcceLLMPolicy,
}


def policies_for(n_instances: int):
    n_prefill = {4: 1, 8: 2, 16: 4}.get(n_instances, max(1, n_instances // 4))
    return {
        "vllm": VLLMPolicy(),
        "splitwise": SplitwisePolicy(n_prefill),
        "accellm": AcceLLMPolicy(),
    }
