"""KV-store microbenchmarks: what the paged refactor buys.

Measures, on the reduced live engine (CPU):

* mirror-sync traffic per decode step — dense whole-slot copy (the old
  O(kv_capacity) semantics) vs the paged delta (one KV line, §4.1.2),
* mirror-sync wall time — full export/import vs ``sync_replica_from``
  delta copy,
* decode step time on the paged engine,
* paged vs dense decode-attention kernel (interpret mode, tiny shape).

Writes a ``BENCH_kvstore.json`` snapshot next to the repo root so CI
keeps a machine-readable record of mirror bytes/step.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, emit
from repro.configs import get_config
from repro.core.kvbytes import bytes_per_token, state_bytes_at
from repro.models import init_params
from repro.serving import InstanceEngine, Request

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kvstore.json")


def _mk(cfg, i, plen=32, new=64):
    return Request(prompt_len=plen, max_new_tokens=new,
                   prompt_tokens=jax.random.randint(
                       jax.random.fold_in(jax.random.PRNGKey(9), i),
                       (1, plen), 0, cfg.vocab_size))


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    kv_capacity = 128 if SMOKE else 256
    snap = {}

    a = InstanceEngine(cfg, params, num_slots=4, kv_capacity=kv_capacity)
    b = InstanceEngine(cfg, params, num_slots=4, kv_capacity=kv_capacity,
                       instance_id=1)
    req = _mk(cfg, 0)
    slot = a.prefill_request(req)
    chunks, length, last, lines = a.export_stream(slot)
    b.import_stream(0, chunks, length, last, lines, req,
                    as_replica_of=(0, slot))

    # -- mirror traffic: dense whole-slot vs paged delta ----------------------
    dense_bytes = state_bytes_at(cfg, kv_capacity)   # old MirrorSync cost
    delta_bytes = bytes_per_token(cfg)               # one KV line
    emit("kvstore_mirror_bytes_dense", 0.0, f"bytes={dense_bytes:.0f}")
    emit("kvstore_mirror_bytes_paged", 0.0,
         f"bytes={delta_bytes:.0f};reduction={dense_bytes / delta_bytes:.0f}x")
    snap["mirror_bytes_per_step_dense"] = dense_bytes
    snap["mirror_bytes_per_step_paged"] = delta_bytes

    # -- mirror wall time: full copy vs delta copy ----------------------------
    n = 3 if SMOKE else 10
    a.decode()
    t0 = time.perf_counter()
    for _ in range(n):
        ex = a.export_slot(slot)
        b.store.merge_slot(0, ex[0])
        jax.block_until_ready(jax.tree_util.tree_leaves(b.state)[0])
    full_us = (time.perf_counter() - t0) / n * 1e6
    emit("kvstore_mirror_full_copy", full_us, f"kv_capacity={kv_capacity}")
    for _ in range(2):                    # warm the 1-line delta shape
        a.decode()
        b.sync_replica_from(a, slot, 0)
    total = 0.0
    for _ in range(n):
        a.decode()                        # untimed: grow one line
        t0 = time.perf_counter()
        b.sync_replica_from(a, slot, 0)
        jax.block_until_ready(jax.tree_util.tree_leaves(b.state)[0])
        total += time.perf_counter() - t0
    delta_us = total / n * 1e6
    emit("kvstore_mirror_delta_sync", delta_us,
         "1-line delta copy (ledger-bounded)")
    snap["mirror_full_copy_us"] = full_us
    snap["mirror_delta_sync_us"] = delta_us

    # -- decode step time on the paged engine ---------------------------------
    for i in range(1, 4):
        a.prefill_request(_mk(cfg, i))
    a.decode()
    t0 = time.perf_counter()
    for _ in range(n):
        a.decode()
    us = (time.perf_counter() - t0) / n * 1e6
    emit("kvstore_decode_step_b4", us,
         f"free_blocks={a.free_blocks()};used_GB={a.used_bytes() / 1e9:.4f}")
    snap["decode_step_us_b4"] = us

    # -- paged vs dense decode kernel (interpret mode, tiny) ------------------
    from repro.kernels.decode_attention import (decode_attention_pallas,
                                                paged_decode_attention_pallas)
    B, H, KVH, hd, W, bl = 2, 4, 2, 64, 128, 64
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    kc = jax.random.normal(k2, (B, W, KVH, hd))
    vc = jax.random.normal(k3, (B, W, KVH, hd))
    lengths = jnp.full((B,), W, jnp.int32)
    nb = W // bl
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    pool_k = kc.reshape(B * nb, bl, KVH, hd)
    pool_v = vc.reshape(B * nb, bl, KVH, hd)
    t0 = time.perf_counter()
    jax.block_until_ready(decode_attention_pallas(
        q, kc, vc, lengths, block_k=bl, interpret=True))
    emit("kvstore_kernel_dense_interp", (time.perf_counter() - t0) * 1e6,
         f"B={B};W={W}")
    t0 = time.perf_counter()
    jax.block_until_ready(paged_decode_attention_pallas(
        q, pool_k, pool_v, tables, lengths, interpret=True))
    emit("kvstore_kernel_paged_interp", (time.perf_counter() - t0) * 1e6,
         f"blocks={B * nb};block_lines={bl}")

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("kvstore_snapshot", 0.0, SNAPSHOT)


if __name__ == "__main__":
    main()
