"""Paper Fig. 5: (left) co-batching prefill with decode inflates token
latency >300%; (right) one instance at batch 40 vs two at batch 20."""
from benchmarks.common import decode_time, emit, perf, timed


def main():
    pm = perf()
    lengths = [500] * 20
    t_decode = decode_time(pm, lengths)
    # a 1024-token prompt lands mid-decode (vLLM-style co-batch)
    t_mixed = pm.prefill_time([1024]) + decode_time(pm, lengths)
    us = timed(decode_time, pm, lengths, n=50)
    emit("fig5_interference_decode_only", us, f"tbt={t_decode * 1e3:.2f}ms")
    emit("fig5_interference_cobatched", us,
         f"tbt={t_mixed * 1e3:.2f}ms;inflation={t_mixed / t_decode:.1f}x")
    # imbalance: 40 on one instance vs 20+20
    t40 = decode_time(pm, [500] * 40)
    t20 = decode_time(pm, [500] * 20)
    emit("fig5_imbalance_b40_vs_2x20", us,
         f"b40={t40 * 1e3:.2f}ms;b20={t20 * 1e3:.2f}ms;"
         f"delta={(t40 - t20) * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
