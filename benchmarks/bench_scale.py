"""Scale harness: million-request trace replay + the ULB shootout.

Three parts, all feeding ``BENCH_scale.json``:

* **Headline** — stream a >=10^5-request bursty trace (JSONL on disk,
  replayed via ``load_trace(stream=True)`` so it never materializes)
  through the dict-backed AND the array-backed AcceLLM scheduler with
  kernel decision tracing on; assert the decision traces are
  bit-identical and report scheduler-us/iteration for both (the
  vectorized core must win by >= 3x).
* **Shootout** — accellm / ulb / vllm / splitwise (vectorized kernels
  where registered) x {bursty, diurnal, closed-loop, prefix-heavy}:
  SLO attainment, goodput, scheduler overhead and peak RSS per cell.
* **Live smoke** — a tiny real-engine slice wiring
  ``ServeReport.sched_us_per_iter`` end to end.

``REPRO_BENCH_SMOKE=1`` (or ``--smoke``) shrinks every trace so CI can
run the entry point; the acceptance-scale numbers come from a full run.
"""
from __future__ import annotations

import json
import os
import resource
import sys
import tempfile
import time

from benchmarks.common import DEFAULT_SLO, SMOKE, emit, perf
from repro.scheduling.registry import get_policy
from repro.sim import (AcceLLMPolicy, Simulator, SplitwisePolicy, ULBPolicy,
                       VLLMPolicy, summarize)
from repro.workloads import (Bursty, ClosedLoop, DiurnalRamp, Poisson,
                             PrefixReuse, TableLengths, WorkloadSpec,
                             load_trace, save_trace)

N_INSTANCES = 8
MAX_BATCH = 128
TIMELINE_STRIDE = 64
SEED = 0
PERF = perf()  # H100 x4, llama2-70b — the paper's instance


def peak_rss_mb() -> float:
    """Process-wide high-water-mark RSS in MB (monotonic: cells report
    the max over everything run so far, not a per-run footprint)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_cell(policy, spec: WorkloadSpec, duration: float, horizon: float):
    sim = Simulator(policy, PERF, n_instances=N_INSTANCES,
                    max_batch=MAX_BATCH, timeline_stride=TIMELINE_STRIDE)
    t0 = time.perf_counter()
    sim.run(source=spec.source(seed=SEED), horizon=horizon)
    wall = time.perf_counter() - t0
    s = summarize(sim.submitted, N_INSTANCES, max(sim.now, duration),
                  slo=DEFAULT_SLO, sched_us_per_iter=sim.sched_us_per_iter)
    return sim, s, wall


# -- part 1: the >=10^5-request dict-vs-array headline -----------------------

def headline(smoke: bool) -> dict:
    # mean offered rate of this MMPP is ~69 req/s, so 1560 modeled
    # seconds clears the 10^5-request acceptance floor with margin;
    # smoke keeps the same shape at trace length ~1.5k
    duration = 20.0 if smoke else 1560.0
    spec = WorkloadSpec(
        arrival=Bursty(rate_on=90.0, duration=duration, rate_off=30.0,
                       mean_on=10.0, mean_off=4.0),
        lengths=TableLengths(workload="mixed"), name="bursty")

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "scale_trace.jsonl")
        # save_trace consumes the source lazily and load_trace
        # (stream=True) replays off the file: the trace never lives in
        # memory on either side of the round-trip
        n_requests = save_trace(path, spec.source(seed=SEED))
        replay = load_trace(path, name="scale_trace", stream=True)

        def run(policy):
            policy.kernel.trace = []
            sim = Simulator(policy, PERF, n_instances=N_INSTANCES,
                            max_batch=MAX_BATCH,
                            timeline_stride=TIMELINE_STRIDE)
            t0 = time.perf_counter()
            sim.run(source=replay.source(seed=SEED),
                    horizon=duration + 1200.0)
            wall = time.perf_counter() - t0
            return policy.kernel.trace, sim, wall

        tr_s, sim_s, wall_s = run(AcceLLMPolicy())
        tr_v, sim_v, wall_v = run(
            AcceLLMPolicy(kernel=get_policy("accellm-vec")))

    identical = tr_s == tr_v
    scalar_us = sim_s.sched_us_per_iter
    vec_us = sim_v.sched_us_per_iter
    speedup = scalar_us / vec_us if vec_us else float("nan")
    if not identical:
        raise AssertionError(
            f"kernel decision traces diverged: {len(tr_s)} vs {len(tr_v)} "
            f"entries — the vectorized core is NOT a drop-in replacement")
    emit(f"scale_headline_n{n_requests}", (wall_s + wall_v) * 1e6,
         f"sched_us scalar={scalar_us:.1f} vec={vec_us:.1f} "
         f"speedup={speedup:.2f}x trace[{len(tr_s)}] identical "
         f"iters={sim_s.n_iterations} rss={peak_rss_mb():.0f}MB")
    return {
        "n_requests": n_requests,
        "n_iterations": sim_s.n_iterations,
        "trace_entries": len(tr_s),
        "identical_decisions": identical,
        "scalar_us_per_iter": scalar_us,
        "vec_us_per_iter": vec_us,
        "speedup": speedup,
        "scalar_wall_s": wall_s,
        "vec_wall_s": wall_v,
        "peak_rss_mb": peak_rss_mb(),
    }


# -- part 2: the 4-policy x 4-scenario shootout ------------------------------

def shootout_policies():
    """Shootout contenders on their vectorized kernels (decision-trace
    identical to the dict-backed originals — the headline proves it)."""
    n_prefill = 2  # splitwise prefill split at 8 instances
    return {
        "accellm": lambda: AcceLLMPolicy(kernel=get_policy("accellm-vec")),
        "ulb": lambda: ULBPolicy(kernel=get_policy("ulb-vec")),
        "vllm": lambda: VLLMPolicy(kernel=get_policy("vllm-vec")),
        "splitwise": lambda: SplitwisePolicy(
            n_prefill, kernel=get_policy("splitwise-vec",
                                         n_prefill=n_prefill)),
    }


def scenarios(smoke: bool):
    d = 12.0 if smoke else 150.0
    k, n_cl = (16, 96) if smoke else (64, 3000)
    mixed = TableLengths(workload="mixed")
    return {
        "bursty": (WorkloadSpec(
            Bursty(rate_on=90.0, duration=d, rate_off=30.0,
                   mean_on=10.0, mean_off=4.0), mixed, name="bursty"), d),
        "diurnal": (WorkloadSpec(
            DiurnalRamp(low=20.0, peak=100.0, period=d, duration=d),
            mixed, name="diurnal"), d),
        "closed_loop": (WorkloadSpec(
            ClosedLoop(k=k, n_requests=n_cl), mixed,
            name="closed_loop"), d),
        "prefix_heavy": (WorkloadSpec(
            Poisson(rate=60.0, duration=d), mixed, name="prefix_heavy",
            prefix_reuse=PrefixReuse(pool=8, reuse=0.7, prefix_len=64)), d),
    }


def shootout(smoke: bool) -> dict:
    grid: dict = {}
    for sc_name, (spec, duration) in scenarios(smoke).items():
        grid[sc_name] = {}
        for pol_name, make in shootout_policies().items():
            sim, s, wall = run_cell(make(), spec, duration,
                                    horizon=duration * 10.0)
            grid[sc_name][pol_name] = {
                "n_finished": s.n_finished,
                "n_unfinished": s.n_unfinished,
                "slo_attainment": s.slo_attainment,
                "goodput": s.goodput,
                "tokens_per_inst_s": s.tokens_per_inst_s,
                "ttft_p50": s.ttft_p50,
                "tbt_p99": s.tbt_p99,
                "jct_p50": s.jct_p50,
                "sched_us_per_iter": s.sched_us_per_iter,
                "n_iterations": sim.n_iterations,
                "wall_s": wall,
                "peak_rss_mb": peak_rss_mb(),
            }
            emit(f"scale_{sc_name}_{pol_name}", wall * 1e6,
                 f"slo={s.slo_attainment:.3f} goodput={s.goodput:.2f} "
                 f"sched_us={s.sched_us_per_iter:.1f} "
                 f"finished={s.n_finished}")
    return grid


# -- part 3: live-engine smoke slice -----------------------------------------

def live_smoke(smoke: bool) -> dict:
    from repro.api import ServeSpec, serve
    from repro.workloads import SLO
    spec = ServeSpec(policy="accellm", n_instances=2, num_slots=4,
                     kv_capacity=64, n_requests=8 if smoke else 12,
                     request_scale=0.02, max_steps=400,
                     slo=SLO(ttft=50, tbt=8), timeline_stride=4)
    t0 = time.perf_counter()
    report = serve(spec)
    wall = time.perf_counter() - t0
    emit("scale_live_smoke", wall * 1e6,
         f"finished={len(report.finished)}/{report.n_submitted} "
         f"sched_us={report.sched_us_per_iter:.1f} "
         f"timeline={len(report.timeline)}")
    return {
        "finished": len(report.finished),
        "submitted": report.n_submitted,
        "sched_us_per_iter": report.sched_us_per_iter,
        "n_iterations": report.cluster.n_iterations,
        "timeline_points": len(report.timeline),
        "slo_attainment": report.slo().attainment,
        "wall_s": wall,
    }


def main():
    smoke = SMOKE or "--smoke" in sys.argv
    out = {
        "meta": {"smoke": smoke, "n_instances": N_INSTANCES,
                 "max_batch": MAX_BATCH,
                 "timeline_stride": TIMELINE_STRIDE, "seed": SEED,
                 "slo": {"ttft": DEFAULT_SLO.ttft, "tbt": DEFAULT_SLO.tbt}},
        "headline": headline(smoke),
        "grid": shootout(smoke),
        "live_smoke": live_smoke(smoke),
    }
    out_path = os.environ.get("REPRO_BENCH_SCALE_OUT", "BENCH_scale.json")
    with open(out_path, "w") as fh:
        json.dump(out, fh, indent=2)
    emit("scale_report", 0.0, f"wrote {out_path} "
         f"(headline speedup={out['headline']['speedup']:.2f}x)")


if __name__ == "__main__":
    main()
