"""Paper Figs. 3 & 4: prefill execution time/throughput vs prompt length and
batch; decode step time / token throughput vs batch and KV length."""
from benchmarks.common import decode_time, emit, perf, timed


def main():
    pm = perf()
    # Fig. 3 — prefill: time & throughput vs (len, batch)
    for plen in (128, 512, 1024, 2048):
        for batch in (1, 4, 16):
            t = pm.prefill_time([plen] * batch)
            us = timed(pm.prefill_time, [plen] * batch, n=50)
            thr = plen * batch / t
            emit(f"fig3_prefill_len{plen}_b{batch}", us,
                 f"t={t * 1e3:.2f}ms;tok_s={thr:.0f}")
    # Fig. 4 — decode: time & throughput vs (batch, kv len)
    for length in (250, 500, 1000):
        for batch in (1, 8, 32, 64):
            t = decode_time(pm, [length] * batch)
            us = timed(decode_time, pm, [length] * batch, n=50)
            thr = batch / t
            emit(f"fig4_decode_len{length}_b{batch}", us,
                 f"t={t * 1e3:.3f}ms;tok_s={thr:.0f}")


if __name__ == "__main__":
    main()
