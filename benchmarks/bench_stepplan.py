"""Step-plan microbenchmarks: what batched-bucketed prefill buys.

Serves the same mixed-length prompt workload two ways on the reduced
live engine (CPU):

* **seed path** — one jitted prefill per prompt at its exact length with
  a full-``kv_capacity`` scratch state: one XLA compile per distinct
  prompt length (the seed `InstanceEngine.prefill_request` behavior),
* **step-plan path** — prompts padded to power-of-two buckets
  (``repro.stepplan.bucket_len``), scratch sized to the bucket, batched
  up to 4 prompts per jitted call: compiles bounded by bucket shapes.

Emits walltime (including compiles — that is the point) and compile
counts, plus the scratch-state allocation of each path.  Writes a
``BENCH_stepplan.json`` snapshot next to the repo root so CI keeps a
machine-readable record; the acceptance bar is the step-plan path
beating the seed path on BOTH walltime and compile count.
"""
import functools
import json
import os
import time

import jax

from benchmarks.common import SMOKE, emit
from repro.configs import get_config
from repro.models import init_params, init_state, prefill
from repro.models.state import state_bytes
from repro.serving import InstanceEngine, Request
from repro.stepplan import PrefillItem, PrefillPlan, bucket_len

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_stepplan.json")


def _prompts(cfg, n):
    key = jax.random.PRNGKey(3)
    # mixed-length workload: distinct lengths spread over two buckets
    lens = [5 + (7 * i) % 60 for i in range(n)]
    return [Request(prompt_len=p, max_new_tokens=1,
                    prompt_tokens=jax.random.randint(
                        jax.random.fold_in(key, i), (1, p), 0,
                        cfg.vocab_size))
            for i, p in enumerate(lens)]


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    kv_capacity = 128 if SMOKE else 256
    n = 6 if SMOKE else 16
    snap = {}

    # -- seed path: one exact-shape compile + full-window scratch per prompt
    jit_legacy = jax.jit(functools.partial(prefill, cfg))
    reqs = _prompts(cfg, n)
    t0 = time.perf_counter()
    for r in reqs:
        fresh = init_state(cfg, 1, kv_capacity)
        logits, fresh = jit_legacy(params, {"tokens": r.prompt_tokens}, fresh)
        jax.block_until_ready(logits)
    legacy_us = (time.perf_counter() - t0) * 1e6
    legacy_compiles = jit_legacy._cache_size()
    emit("stepplan_prefill_legacy", legacy_us / n,
         f"n={n};compiles={legacy_compiles}")
    snap["legacy_total_us"] = legacy_us
    snap["legacy_compiles"] = legacy_compiles

    # -- step-plan path: bucketed + batched through the engine
    eng = InstanceEngine(cfg, params, num_slots=4, kv_capacity=kv_capacity)
    reqs = _prompts(cfg, n)
    t0 = time.perf_counter()
    for i in range(0, n, 4):
        group = reqs[i: i + 4]
        bucket = bucket_len(max(r.prompt_len for r in group),
                            cap=kv_capacity)
        plan = PrefillPlan(0, tuple(
            PrefillItem(r.rid, r.prompt_len, 0, r.prompt_len, req=r)
            for r in group), bucket)
        done = eng.prefill_batch(plan)
        for slot in done.values():
            eng.release(slot)
    plan_us = (time.perf_counter() - t0) * 1e6
    plan_compiles = eng._jit_prefill_batched._cache_size()
    emit("stepplan_prefill_bucketed", plan_us / n,
         f"n={n};compiles={plan_compiles};"
         f"speedup={legacy_us / plan_us:.2f}x")
    snap["bucketed_total_us"] = plan_us
    snap["bucketed_compiles"] = plan_compiles
    snap["walltime_speedup"] = legacy_us / plan_us

    # -- scratch-state allocation: full window vs padded bucket
    full_bytes = state_bytes(init_state(cfg, 1, kv_capacity))
    bucket_bytes = state_bytes(init_state(
        cfg, 1, bucket_len(max(r.prompt_len for r in reqs),
                           cap=kv_capacity)))
    emit("stepplan_scratch_bytes", 0.0,
         f"full_window={full_bytes};bucket={bucket_bytes};"
         f"reduction={full_bytes / bucket_bytes:.1f}x")
    snap["scratch_bytes_full_window"] = full_bytes
    snap["scratch_bytes_bucket"] = bucket_bytes

    ok = (plan_us < legacy_us) and (plan_compiles < legacy_compiles)
    snap["beats_seed_path"] = ok
    emit("stepplan_beats_seed", 0.0, f"walltime_and_compiles={ok}")

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("stepplan_snapshot", 0.0, SNAPSHOT)


if __name__ == "__main__":
    main()
