"""Fleet fault injection (ISSUE 6): what redundancy buys when an
instance actually dies mid-serve.

A ``KillInstance`` lands on instance 1 partway through a bursty and a
diurnal workload (same seed for every policy), followed by a warm
rejoin.  AcceLLM's kernel promotes the dead instance's requests onto
their warm pair replicas (paying only the unsynced tail); vllm and
splitwise must re-admit and re-prefill every resident request from
token zero.

Emits, per traffic x policy:

* ``saved``      — requests that survived via replica promotion,
* ``reprefill``  — prompt tokens re-run because state was lost,
* ``ttft_p99``   — post-kill p99 TTFT (requests finishing after the
                   kill), with the no-kill run's p99 as the baseline.

Writes a ``BENCH_fleet.json`` snapshot next to the repo root.  The
acceptance bar: AcceLLM re-prefills strictly fewer tokens AND holds a
better post-kill p99 TTFT than both baselines, under both traffics.
"""
import json
import os
import time

import numpy as np

from benchmarks.common import SMOKE, emit, perf, policies_for
from repro.fleet import (FixedFleet, FleetController, JoinInstance,
                        KillInstance)
from repro.sim import Simulator
from repro.workloads import Bursty, DiurnalRamp, TableLengths, WorkloadSpec

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet.json")

N_INSTANCES = 4
#: the victim: a decode instance for splitwise (n_prefill=1) and the
#: pair partner of instance 0 for accellm
KILL_IDX = 1


def _traffics(duration: float, rate: float):
    lengths = TableLengths("mixed")
    return {
        "bursty": WorkloadSpec(
            arrival=Bursty(rate_on=rate * 2, duration=duration,
                           mean_on=duration / 6, mean_off=duration / 6),
            lengths=lengths, name="bursty"),
        "diurnal": WorkloadSpec(
            arrival=DiurnalRamp(low=rate / 4, peak=rate * 1.5,
                                period=duration, duration=duration),
            lengths=lengths, name="diurnal"),
    }


def _run(policy, spec, duration, fleet=None, seed=0):
    sim = Simulator(policy, perf(), n_instances=N_INSTANCES)
    sim.run(source=spec.source(seed=seed), horizon=duration * 10.0,
            fleet=fleet)
    return sim


def _post_kill_ttft_p99(sim, t_kill: float) -> float:
    ttfts = [r.ttft() for r in sim.finished
             if r.finish_time is not None and r.finish_time >= t_kill]
    return float(np.percentile(ttfts, 99)) if ttfts else float("nan")


def main():
    duration, rate = (5.0, 4.0) if SMOKE else (30.0, 8.0)
    t_kill, t_join = duration / 3, duration * 2 / 3
    snap = {"n_instances": N_INSTANCES, "kill_instance": KILL_IDX,
            "t_kill": t_kill, "t_join": t_join, "traffic": {}}

    for tname, spec in _traffics(duration, rate).items():
        rows = {}
        for pname, policy in policies_for(N_INSTANCES).items():
            t0 = time.perf_counter()
            base = _run(policy, spec, duration)
            p99_base = _post_kill_ttft_p99(base, t_kill)

            fleet = FleetController(FixedFleet((
                KillInstance(t_kill, KILL_IDX),
                JoinInstance(t_join, KILL_IDX))))
            policy2 = policies_for(N_INSTANCES)[pname]   # fresh adapter
            sim = _run(policy2, spec, duration, fleet=fleet)
            us = (time.perf_counter() - t0) * 1e6

            p99 = _post_kill_ttft_p99(sim, t_kill)
            st = fleet.stats
            rows[pname] = {
                "finished": len(sim.finished),
                "submitted": len(sim.submitted),
                "requests_saved": st["promotions"],
                "requeues": st["requeues"] + st["requeue_backlog"],
                "reprefill_tokens": st["reprefill_tokens"],
                "lost_decode_tokens": st["lost_decode_tokens"],
                "warm_streams": st["warm_streams"],
                "ttft_p99_post_kill": round(p99, 4),
                "ttft_p99_no_kill": round(p99_base, 4),
                "ttft_p99_degradation": round(p99 - p99_base, 4),
            }
            emit(f"fleet_{tname}_{pname}", us,
                 f"saved={st['promotions']};reprefill="
                 f"{st['reprefill_tokens']};ttft_p99={p99:.3f}"
                 f"(base={p99_base:.3f})")
        snap["traffic"][tname] = rows

        acc, vllm, spl = rows["accellm"], rows["vllm"], rows["splitwise"]
        # the measurable contrast: redundancy turns a kill into replica
        # promotions instead of re-prefills.  Smoke runs are too short
        # to guarantee residents on the victim at kill time, so the
        # strict comparison is asserted on the full run only.
        assert acc["reprefill_tokens"] <= min(vllm["reprefill_tokens"],
                                              spl["reprefill_tokens"]), \
            (tname, acc["reprefill_tokens"], vllm["reprefill_tokens"],
             spl["reprefill_tokens"])
        if not SMOKE:
            assert (acc["reprefill_tokens"] < vllm["reprefill_tokens"]
                    and acc["reprefill_tokens"] < spl["reprefill_tokens"]), \
                (tname, acc["reprefill_tokens"], vllm["reprefill_tokens"],
                 spl["reprefill_tokens"])
            assert (acc["ttft_p99_post_kill"] < vllm["ttft_p99_post_kill"]
                    and acc["ttft_p99_post_kill"]
                    < spl["ttft_p99_post_kill"]), \
                (tname, acc["ttft_p99_post_kill"],
                 vllm["ttft_p99_post_kill"], spl["ttft_p99_post_kill"])

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
