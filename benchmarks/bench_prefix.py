"""Prefix cache (ISSUE 7): what shared prompt heads buy, on both
backends, sweeping the reuse probability.

Simulator sweep (llama2-70b on 4xH100, Poisson x Table-2 traffic with a
pool of shared system prompts): per reuse probability, the cache's hit
accounting gives

* ``tokens_saved`` — prompt tokens never prefilled (the planner prices
  PrefillItems at the unique suffix),
* ``kv_saved_mb``  — HBM the adopted block runs dedup (hit blocks x
  block bytes a share-blind allocator would have written again).

Live validation (reduced starcoder2-3b cluster, AcceLLM policy, reuse
0.6): cache on/off with redundancy on, plus cache on with redundancy
off.  The acceptance bars, asserted on the full run:

* cache-on saves prefill tokens and KV bytes on BOTH backends,
* generated tokens are bit-identical to the cache-off run,
* with redundancy on, replica StreamState traffic drops below the
  cache-off bound (mirror copies skip lines already resident on the
  destination — the unique-suffix bound).

Writes a ``BENCH_prefix.json`` snapshot next to the repo root.
"""
import json
import os
import time

import jax

from benchmarks.common import SMOKE, emit, perf
from repro.configs import get_config
from repro.models import init_params
from repro.scheduling import AcceLLMScheduler, LiveCluster
from repro.sim import Simulator, summarize
from repro.sim.policies import AcceLLMPolicy
from repro.workloads import (Poisson, PrefixReuse, TableLengths,
                             UniformLengths, WorkloadSpec)

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_prefix.json")

SIM_BLOCK_LINES = 16
SIM_PREFIX_LEN = 512          # a system prompt, in Table-2 token scale
LIVE_BLOCK_LINES = 8


def _sim_spec(reuse: float, rate: float, duration: float) -> WorkloadSpec:
    pr = (PrefixReuse(pool=4, reuse=reuse, prefix_len=SIM_PREFIX_LEN)
          if reuse > 0 else None)
    return WorkloadSpec(arrival=Poisson(rate=rate, duration=duration),
                        lengths=TableLengths("mixed"), name="mixed",
                        prefix_reuse=pr)


def _sim_point(reuse: float, rate: float, duration: float) -> dict:
    pm = perf()
    sim = Simulator(AcceLLMPolicy(), pm, n_instances=4,
                    block_lines=SIM_BLOCK_LINES, prefix_cache=True)
    sim.run(source=_sim_spec(reuse, rate, duration).source(seed=0),
            horizon=duration * 10)
    s = summarize(sim.submitted, 4, max(sim.now, duration))
    stats = [i.prefix_cache.stats for i in sim.instances
             if i.prefix_cache is not None]
    hit_blocks = sum(st["hit_blocks"] for st in stats)
    block_bytes = SIM_BLOCK_LINES * pm.line_costs.line_bytes
    return {
        "finished": len(sim.finished),
        "submitted": len(sim.submitted),
        "lookups": sum(st["lookups"] for st in stats),
        "hits": sum(st["hits"] for st in stats),
        "tokens_saved": sum(st["hit_tokens"] for st in stats),
        "kv_saved_mb": round(hit_blocks * block_bytes / 2**20, 2),
        "ttft_p50": round(s.ttft_p50, 4),
        "jct_p50": round(s.jct_p50, 4),
    }


def _live_run(cfg, params, duration: float, prefix_cache: bool,
              redundancy: bool):
    spec = WorkloadSpec(
        arrival=Poisson(rate=0.6, duration=duration),
        lengths=UniformLengths(prompt=(10, 16), decode=(3, 6)),
        name="prefix-heavy",
        prefix_reuse=PrefixReuse(pool=2, reuse=0.6, prefix_len=8))
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=64,
                          policy=AcceLLMScheduler(redundancy=redundancy),
                          block_lines=LIVE_BLOCK_LINES,
                          prefix_cache=prefix_cache)
    done = cluster.run(max_steps=400,
                       source=spec.source(seed=3, cfg=cfg))
    return cluster, done


def _live_row(cluster, done) -> dict:
    st = cluster.stats
    caches = [e.prefix_cache for e in cluster.engines
              if e.prefix_cache is not None]
    block_bytes = (LIVE_BLOCK_LINES
                   * cluster.engines[0].store.costs.line_bytes)
    hit_blocks = sum(c.stats["hit_blocks"] for c in caches)
    return {
        "finished": len(done),
        "prefix_hits": st["prefix_hits"],
        "tokens_saved": st["prefix_hit_tokens"],
        "kv_saved_mb": round(hit_blocks * block_bytes / 2**20, 4),
        "stream_bytes_mb": round(st["stream_bytes"] / 2**20, 4),
        "stream_skipped_lines": st["stream_skipped_lines"],
        "mirror_bytes_mb": round(st["mirror_bytes"] / 2**20, 4),
    }


def main():
    rate, duration = (4.0, 5.0) if SMOKE else (8.0, 30.0)
    sweep = [0.0, 0.6] if SMOKE else [0.0, 0.3, 0.6, 0.9]
    snap = {"sim": {"arch": "llama2-70b", "prefix_len": SIM_PREFIX_LEN,
                    "block_lines": SIM_BLOCK_LINES, "reuse": {}},
            "live": {"arch": "starcoder2-3b(reduced)",
                     "block_lines": LIVE_BLOCK_LINES, "reuse": 0.6}}

    prev_saved = -1
    for reuse in sweep:
        t0 = time.perf_counter()
        row = _sim_point(reuse, rate, duration)
        us = (time.perf_counter() - t0) * 1e6
        snap["sim"]["reuse"][str(reuse)] = row
        emit(f"prefix_sim_reuse{reuse}", us,
             f"tokens_saved={row['tokens_saved']};"
             f"kv_saved_mb={row['kv_saved_mb']};"
             f"hits={row['hits']}/{row['lookups']}")
        assert row["finished"] == row["submitted"]
        if reuse == 0.0:
            assert row["tokens_saved"] == 0
        elif reuse >= 0.5:
            assert row["tokens_saved"] > 0 and row["kv_saved_mb"] > 0, \
                f"reuse={reuse}: the sim cache never hit"
        assert row["tokens_saved"] >= prev_saved or SMOKE, \
            "more reuse must not save fewer prefill tokens"
        prev_saved = row["tokens_saved"]

    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    live_duration = 8.0 if SMOKE else 14.0
    rows = {}
    for name, cache, red in (("cache_off", False, True),
                             ("cache_on", True, True),
                             ("cache_on_no_redundancy", True, False)):
        t0 = time.perf_counter()
        cluster, done = _live_run(cfg, params, live_duration, cache, red)
        us = (time.perf_counter() - t0) * 1e6
        rows[name] = _live_row(cluster, done)
        rows[name]["tokens"] = {r.rid: list(map(int, r.output_tokens))
                                for r in done}
        emit(f"prefix_live_{name}", us,
             f"tokens_saved={rows[name]['tokens_saved']};"
             f"stream_mb={rows[name]['stream_bytes_mb']};"
             f"skipped_lines={rows[name]['stream_skipped_lines']}")

    off, on = rows["cache_off"], rows["cache_on"]
    assert on["tokens"] == off["tokens"], \
        "prefix-cache adoption changed a generated token"
    for row in rows.values():
        del row["tokens"]                      # verified; keep the snapshot small
    snap["live"]["runs"] = rows
    assert off["tokens_saved"] == 0 and on["tokens_saved"] > 0, \
        "live cache produced no prefill savings"
    assert on["kv_saved_mb"] > 0
    if not SMOKE:
        # replica copies skip dst-resident lines: redundancy traffic
        # lands below the cache-off bound (the unique-suffix bound)
        assert on["stream_skipped_lines"] > 0
        assert on["stream_bytes_mb"] < off["stream_bytes_mb"], \
            (on["stream_bytes_mb"], off["stream_bytes_mb"])
        assert rows["cache_on_no_redundancy"]["mirror_bytes_mb"] == 0

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
