"""Decode-path microbenchmarks: what paged fused decode buys (ISSUE 5).

Serves the same decode workload two ways on the reduced live engine
(CPU), at 1 / 4 / 16 active slots out of a 16-slot instance:

* **seed path** — dense per-step decode: every iteration runs attention
  over the **entire** ``num_slots x kv_capacity`` window (free and
  replica slots included) and pays a host round-trip per generated
  token (``paged_decode=False``),
* **paged fused** — the batch compacted to active primary slots, K/V
  gathered through the store's block tables
  (``kernels.decode_attention``), and ``steps`` iterations fused into
  one jitted ``lax.scan`` with on-device sampling: one dispatch and one
  host sync per plan.

Emits walltime per generated token and the engine's host-sync counters,
asserting the two paths produced bit-identical tokens.  Writes a
``BENCH_decode.json`` snapshot next to the repo root; the acceptance
bar is the paged-fused path beating the dense path in walltime at 4+
active slots with host syncs at 1/plan instead of 1/token.
"""
import json
import os
import time

import jax

from benchmarks.common import SMOKE, emit
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InstanceEngine, Request

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_decode.json")

NUM_SLOTS = 16


def _reqs(cfg, n, new):
    key = jax.random.PRNGKey(5)
    lens = [8 + (5 * i) % 24 for i in range(n)]
    return [Request(prompt_len=p, max_new_tokens=new,
                    prompt_tokens=jax.random.randint(
                        jax.random.fold_in(key, i), (1, p), 0,
                        cfg.vocab_size))
            for i, p in enumerate(lens)]


def _serve(eng, cfg, active, new, *, steps):
    """Prefill ``active`` requests and decode them to completion on
    ``eng``; returns (decode walltime, decode tokens, host syncs,
    output tokens).  Run twice on the SAME engine: jit caches are
    per-engine, so the first pass pays the compiles and the second
    measures steady state."""
    reqs = _reqs(cfg, active, new)
    for r in reqs:
        eng.prefill_request(r)
    syncs0 = eng.host_syncs
    t0 = time.perf_counter()
    while eng.slot_req:
        if steps > 1:
            eng.decode_multi(steps=steps)
        else:
            eng.decode()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output_tokens) for r in reqs) - len(reqs)  # decode only
    return dt, toks, eng.host_syncs - syncs0, [r.output_tokens for r in reqs]


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    kv_capacity = 64
    new = 8 if SMOKE else 24
    steps = 4 if SMOKE else 8
    snap = {"num_slots": NUM_SLOTS, "kv_capacity": kv_capacity,
            "decode_tokens": new, "fused_steps": steps, "slots": {}}

    for active in (1, 4, 16):
        eng_d = InstanceEngine(cfg, params, num_slots=NUM_SLOTS,
                               kv_capacity=kv_capacity, paged_decode=False)
        eng_p = InstanceEngine(cfg, params, num_slots=NUM_SLOTS,
                               kv_capacity=kv_capacity, paged_decode=True)
        # warm pass compiles, second pass measures steady state
        _serve(eng_d, cfg, active, new, steps=1)
        _serve(eng_p, cfg, active, new, steps=steps)
        t_dense, toks, sync_dense, ref = _serve(
            eng_d, cfg, active, new, steps=1)
        t_fused, toks_f, sync_fused, out = _serve(
            eng_p, cfg, active, new, steps=steps)
        assert out == ref, f"paged-fused tokens diverge at {active} slots"
        assert toks_f == toks
        us_dense = t_dense / toks * 1e6
        us_fused = t_fused / toks * 1e6
        emit(f"decode_dense_per_step_b{active}", us_dense,
             f"tok_s={toks / t_dense:.1f};host_syncs={sync_dense}")
        emit(f"decode_paged_fused_b{active}", us_fused,
             f"tok_s={toks / t_fused:.1f};host_syncs={sync_fused};"
             f"speedup={t_dense / t_fused:.2f}x")
        snap["slots"][str(active)] = {
            "dense_us_per_token": round(us_dense, 1),
            "fused_us_per_token": round(us_fused, 1),
            "dense_tokens_per_s": round(toks / t_dense, 1),
            "fused_tokens_per_s": round(toks / t_fused, 1),
            "dense_host_syncs": sync_dense,
            "fused_host_syncs": sync_fused,
            "speedup": round(t_dense / t_fused, 2),
            "tokens_bit_identical": True,
        }
        # host syncs: 1 per decode iteration dense vs 1 per fused plan
        assert sync_dense == toks // active, (sync_dense, toks, active)
        assert sync_fused < sync_dense

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
