"""Paper Figs. 11-15: the 4-panel latency suite (tokens/inst/s, TTFT, TBT,
JCT) vs request rate, for {mixed, light, heavy} x {H100, 910B2} x
{vllm, splitwise, accellm} at 4 instances (cluster scaling in Fig. 11/12 is
reported by the 8/16-instance rows)."""
import time

from benchmarks.common import emit, policies_for, run_sim
from repro.sim import ASCEND_910B2, H100

RATES = {
    "light": (10.0, 30.0, 60.0),
    "mixed": (10.0, 25.0, 45.0),
    "heavy": (4.0, 10.0, 20.0),
}


def sweep(workload: str, device, dev_name: str, n_instances: int = 4):
    for rate in RATES[workload]:
        t0 = time.perf_counter()
        cells = {}
        for name, pol in policies_for(n_instances).items():
            _, s = run_sim(pol, workload, rate, 30.0, n_instances,
                           device=device)
            cells[name] = s
        us = (time.perf_counter() - t0) * 1e6
        d = ";".join(
            f"{n}:tok_s={s.tokens_per_inst_s:.0f},ttft={s.ttft_p50:.3f},"
            f"tbt={s.tbt_mean * 1e3:.1f}ms,jct={s.jct_p50:.2f},"
            f"slo={s.slo_attainment:.2f},goodput={s.goodput:.2f}"
            for n, s in cells.items())
        emit(f"fig11-15_{workload}_{dev_name}_n{n_instances}_rate{int(rate)}",
             us, d)


def main():
    for wl in ("mixed", "light", "heavy"):
        sweep(wl, H100, "h100")
    sweep("mixed", ASCEND_910B2, "910b2")
    # cluster scaling (paper: 4/8/16 instances)
    sweep("mixed", H100, "h100", n_instances=8)


if __name__ == "__main__":
    main()
