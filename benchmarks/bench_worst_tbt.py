"""Paper Fig. 16: worst-case TBT — vLLM co-batching spikes, AcceLLM flat.
Plus a Sarathi-Serve (chunked prefill) column from the paper's related work
(bounded spikes, but still above AcceLLM and at a TTFT cost), and a bursty
MMPP traffic variant from the shared workload layer — the arrival pattern
under which co-batching spikes are worst."""
import time

from benchmarks.common import SMOKE, emit, policies_for, run_sim
from repro.sim import SarathiPolicy
from repro.workloads import Bursty, TableLengths, WorkloadSpec


def main():
    t0 = time.perf_counter()
    cells = {}
    pols = dict(policies_for(4))
    pols["sarathi"] = SarathiPolicy(512)
    for name, pol in pols.items():
        _, s = run_sim(pol, "mixed", 10.0, 40.0, 4)
        cells[name] = s
    us = (time.perf_counter() - t0) * 1e6
    emit("fig16_worst_tbt", us, ";".join(
        f"{n}={s.tbt_worst * 1e3:.1f}ms" for n, s in cells.items()))
    v, a = cells["vllm"].tbt_worst, cells["accellm"].tbt_worst
    emit("fig16_spike_ratio", us, f"vllm_over_accellm={v / a:.1f}x")
    emit("fig16_sarathi_ttft_tradeoff", us,
         f"sarathi_ttft={cells['sarathi'].ttft_p50:.3f};"
         f"vllm_ttft={cells['vllm'].ttft_p50:.3f};"
         f"sarathi_tbtw={cells['sarathi'].tbt_worst * 1e3:.1f}ms")

    # beyond-paper: the same comparison under bursty (MMPP on-off) arrivals
    dur = 5.0 if SMOKE else 40.0
    bursty = WorkloadSpec(
        arrival=Bursty(rate_on=8.0 if SMOKE else 20.0, duration=dur,
                       mean_on=4.0, mean_off=4.0),
        lengths=TableLengths("mixed"), name="mixed-bursty")
    t0 = time.perf_counter()
    cells = {}
    for name, pol in policies_for(4).items():
        _, s = run_sim(pol, "mixed", 10.0, 40.0, 4, spec=bursty)
        cells[name] = s
    us = (time.perf_counter() - t0) * 1e6
    emit("fig16_bursty_worst_tbt", us, ";".join(
        f"{n}={s.tbt_worst * 1e3:.1f}ms,goodput={s.goodput:.2f}"
        for n, s in cells.items()))


if __name__ == "__main__":
    main()
