"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import importlib
import sys
import traceback

MODULES = [
    "benchmarks.bench_phase_curves",    # Figs 3-4
    "benchmarks.bench_interference",    # Fig 5
    "benchmarks.bench_memory",          # Fig 9
    "benchmarks.bench_interconnect",    # Fig 10
    "benchmarks.bench_latency_suite",   # Figs 11-15
    "benchmarks.bench_worst_tbt",       # Fig 16
    "benchmarks.bench_ablation",        # beyond-paper: redundancy on/off
    "benchmarks.bench_engine",          # real-engine microbench
    "benchmarks.bench_kvstore",         # paged KV store: mirror delta cost
    "benchmarks.bench_stepplan",        # bucketed batch prefill vs seed path
    "benchmarks.bench_decode",          # paged fused decode vs dense per-step
    "benchmarks.bench_fleet",           # fault injection: failover vs re-prefill
    "benchmarks.bench_prefix",          # prefix cache: reuse-probability sweep
    "benchmarks.bench_mesh",            # TP mesh decode + collective mirror
    "benchmarks.bench_scale",           # vectorized scheduler + ULB shootout
    "benchmarks.bench_chaos",           # degradation: hedging vs no-hedge
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        try:
            importlib.import_module(mod_name).main()
        except Exception:  # noqa: BLE001
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
