"""Real-engine microbenchmarks: wall time of prefill / decode / redundancy
primitives on the reduced model (CPU) — the live counterpart of the
simulator's analytic iteration times."""
import time

import jax

from benchmarks.common import SMOKE, emit
from repro.api import ServeSpec, serve
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InstanceEngine, Request
from repro.workloads import SLO, Poisson, UniformLengths, WorkloadSpec


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = InstanceEngine(cfg, params, num_slots=8, kv_capacity=256)
    key = jax.random.PRNGKey(1)

    def mk(i, plen=32, new=16):
        return Request(prompt_len=plen, max_new_tokens=new,
                       prompt_tokens=jax.random.randint(
                           jax.random.fold_in(key, i), (1, plen), 0,
                           cfg.vocab_size))

    # prefill
    t0 = time.perf_counter()
    eng.prefill_request(mk(0))
    emit("engine_prefill_32tok", (time.perf_counter() - t0) * 1e6, "slots=1")
    for i in range(1, 6):
        eng.prefill_request(mk(i))
    # decode (warm)
    eng.decode()
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        eng.decode()
    us = (time.perf_counter() - t0) / n * 1e6
    emit("engine_decode_step_b6", us, f"tok_s={6 / (us / 1e6):.0f}")
    # redundancy primitives
    slot = eng.active_slots()[0]
    t0 = time.perf_counter()
    ex = eng.export_slot(slot)
    emit("engine_export_slot", (time.perf_counter() - t0) * 1e6,
         "per-request state extract")
    eng2 = InstanceEngine(cfg, params, num_slots=8, kv_capacity=256,
                          instance_id=1)
    t0 = time.perf_counter()
    eng2.import_slot(0, ex, eng.slot_req[slot], as_replica_of=(0, slot))
    emit("engine_import_replica", (time.perf_counter() - t0) * 1e6,
         "replica install")
    # cluster end-to-end through the unified facade
    n_req = 3 if SMOKE else 6
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     num_slots=8, kv_capacity=256, max_steps=200)
    reqs = [mk(10 + i) for i in range(n_req)]
    t0 = time.perf_counter()
    report = serve(spec, requests=reqs, cfg=cfg, params=params)
    us = (time.perf_counter() - t0) * 1e6
    emit(f"engine_cluster_{n_req}req_e2e", us,
         f"finished={len(report.finished)};"
         f"rebalances={report.stats['rebalances']};"
         f"promotions={report.stats['replica_promotions']}")

    # open-loop end-to-end: requests arrive over time on the iteration
    # clock from a shared WorkloadSpec; report scores the SLO axes
    traffic = WorkloadSpec(
        arrival=Poisson(rate=0.5, duration=8.0 if SMOKE else 16.0),
        lengths=UniformLengths(prompt=(8, 32), decode=(4, 12)),
        name="poisson-microbench")
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     num_slots=8, kv_capacity=256, max_steps=400,
                     traffic=traffic, slo=SLO(ttft=10.0, tbt=3.0))
    t0 = time.perf_counter()
    report = serve(spec, cfg=cfg, params=params)
    us = (time.perf_counter() - t0) * 1e6
    s = report.slo()
    emit("engine_cluster_openloop_e2e", us,
         f"finished={len(report.finished)}/{report.n_submitted};"
         f"slo_attainment={s.attainment:.2f};"
         f"goodput={s.goodput:.3f}req_per_iter")


if __name__ == "__main__":
    main()
