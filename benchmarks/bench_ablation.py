"""Ablation (beyond the paper's figures): AcceLLM with redundancy DISABLED
— isolates how much of the gain comes from the redundant KV copies vs the
pairing/scheduling alone. Without replicas, role flips stall the flipping
instance's decodes and rebalancing is impossible."""
import time

from benchmarks.common import emit, run_sim
from repro.sim import AcceLLMPolicy


def main():
    for rate in (10.0, 30.0):
        t0 = time.perf_counter()
        _, with_r = run_sim(AcceLLMPolicy(redundancy=True), "mixed", rate,
                            30.0, 4)
        _, without = run_sim(AcceLLMPolicy(redundancy=False), "mixed", rate,
                             30.0, 4)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"ablation_redundancy_rate{int(rate)}", us,
             f"with:jct={with_r.jct_p50:.2f},tbt_worst="
             f"{with_r.tbt_worst * 1e3:.1f}ms;"
             f"without:jct={without.jct_p50:.2f},tbt_worst="
             f"{without.tbt_worst * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
