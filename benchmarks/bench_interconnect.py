"""Paper Fig. 10: interconnect-bandwidth sweep — AcceLLM and Splitwise reach
peak performance at similar link speeds (mirror traffic is minimal).

The sweep varies the instance-to-instance network (``inter_link_gbps`` on
the :class:`InstanceSpec`) while the intra-slice fabric stays at the
device's native NVLink-class speed — mirror/stream traffic crosses the
network, tensor-parallel collectives never do."""
import time

from benchmarks.common import CFG, emit, run_sim
from repro.sim import AcceLLMPolicy, H100, InstanceSpec, SplitwisePolicy


def main():
    for link in (50, 200, 450, 900):
        inst = InstanceSpec(H100, 4,
                            intra_link_gbps=H100.link_gbps,
                            inter_link_gbps=float(link))
        row = {}
        t0 = time.perf_counter()
        for name, pol in (("splitwise", SplitwisePolicy(1)),
                          ("accellm", AcceLLMPolicy())):
            _, s = run_sim(pol, "mixed", 10.0, 40.0, 4, inst=inst)
            row[name] = s
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig10_link{link}GBs", us,
             f"spl_jct={row['splitwise'].jct_p50:.2f}s;"
             f"acc_jct={row['accellm'].jct_p50:.2f}s;"
             f"acc_tok_s={row['accellm'].tokens_per_inst_s:.0f}")


if __name__ == "__main__":
    main()
