"""Paper Fig. 10: interconnect-bandwidth sweep — AcceLLM and Splitwise reach
peak performance at similar link speeds (mirror traffic is minimal)."""
import dataclasses
import time

from benchmarks.common import CFG, emit, run_sim
from repro.sim import AcceLLMPolicy, H100, InstanceSpec, SplitwisePolicy


def main():
    for link in (50, 200, 450, 900):
        dev = dataclasses.replace(H100, link_gbps=float(link))
        row = {}
        t0 = time.perf_counter()
        for name, pol in (("splitwise", SplitwisePolicy(1)),
                          ("accellm", AcceLLMPolicy())):
            _, s = run_sim(pol, "mixed", 10.0, 40.0, 4, device=dev)
            row[name] = s
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig10_link{link}GBs", us,
             f"spl_jct={row['splitwise'].jct_p50:.2f}s;"
             f"acc_jct={row['accellm'].jct_p50:.2f}s;"
             f"acc_tok_s={row['accellm'].tokens_per_inst_s:.0f}")


if __name__ == "__main__":
    main()
