"""Mesh serving benchmarks (repro.meshserve).

Two questions, answered on the forced 8-device CPU pod (the flag must
precede jax init, so this module appends it when run standalone; under
``benchmarks.run`` jax is already up and the sweep degrades to the
widths the platform offers):

* **sharded decode** — paged fused decode tokens/s at 1 / 2 / 4-way
  model parallel vs the single-device engine, tokens asserted
  bit-identical (model-axis sharding must never change the argmax);
* **mirror transport** — walltime of a delta ``MirrorSync`` between two
  instances when the copy rides the device interconnect (disjoint mesh
  slices, gather → device_transfer → scatter) vs the host-copy path
  (both engines on the default device).

Writes a ``BENCH_mesh.json`` snapshot next to the repo root.  On a CPU
host the "interconnect" is memcpy, so the mirror comparison reports
transport overhead, not a speedup; the snapshot records both numbers
plus the d2d/host-copy counters proving which path ran.
"""
import json
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax

from benchmarks.common import SMOKE, emit
from repro.configs import get_config
from repro.models import init_params
from repro.serving import InstanceEngine, Request

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_mesh.json")

NUM_SLOTS = 8


def _reqs(cfg, n, new):
    key = jax.random.PRNGKey(5)
    lens = [8 + (5 * i) % 24 for i in range(n)]
    return [Request(prompt_len=p, max_new_tokens=new,
                    prompt_tokens=jax.random.randint(
                        jax.random.fold_in(key, i), (1, p), 0,
                        cfg.vocab_size))
            for i, p in enumerate(lens)]


def _decode_run(cfg, params, mesh, active, new, steps):
    eng = InstanceEngine(cfg, params, num_slots=NUM_SLOTS, kv_capacity=64,
                         mesh=mesh)
    reqs = _reqs(cfg, active, new)
    for r in reqs:
        eng.prefill_request(r)
    t0 = time.perf_counter()
    while eng.slot_req:
        eng.decode_multi(steps=steps)
    dt = time.perf_counter() - t0
    toks = sum(len(r.output_tokens) for r in reqs) - len(reqs)
    return dt, toks, [r.output_tokens for r in reqs]


def _mirror_run(cfg, params, slices, syncs):
    """Stream a replica across and time ``syncs`` one-line delta mirrors
    (decode on the primary between syncs, off the clock)."""
    from repro.meshserve import STATS
    mk = lambda sl: InstanceEngine(cfg, params, num_slots=2, kv_capacity=64,
                                   mesh=sl)
    a, b = (mk(slices[0]), mk(slices[1])) if slices else (mk(None), mk(None))
    # keep the primary resident: decode() auto-releases a finished slot
    (req,) = _reqs(cfg, 1, syncs + 2)
    slot = a.prefill_request(req)
    chunks, length, last, lines = a.export_stream(slot)
    b_slot = b.free_slots()[0]
    b.import_stream(b_slot, chunks, length, last, lines, req,
                    as_replica_of=(0, slot))
    STATS.reset()
    total = 0.0
    moved = 0.0
    for _ in range(syncs):
        a.decode()
        t0 = time.perf_counter()
        moved += b.sync_replica_from(a, slot, b_slot)
        total += time.perf_counter() - t0
    return total / syncs, moved, STATS.d2d_copies, STATS.host_copies


def main():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    new = 8 if SMOKE else 24
    steps = 4 if SMOKE else 8
    active = 4
    n_dev = jax.device_count()
    snap = {"devices": n_dev, "decode_tokens": new, "fused_steps": steps,
            "active_slots": active, "tp": {}, "mirror": {}}

    from repro.meshserve import carve_slices

    # warm + measure the single-device reference
    _decode_run(cfg, params, None, active, new, steps)
    t_ref, toks, ref = _decode_run(cfg, params, None, active, new, steps)
    emit("mesh_decode_tp1", t_ref / toks * 1e6,
         f"tok_s={toks / t_ref:.1f}")
    snap["tp"]["1"] = {"us_per_token": round(t_ref / toks * 1e6, 1),
                       "tokens_per_s": round(toks / t_ref, 1),
                       "tokens_bit_identical": True}

    for tp in (2, 4):
        if n_dev < tp:
            emit(f"mesh_decode_tp{tp}", 0.0, "skipped=needs_devices")
            continue
        (sl,) = carve_slices(tp, n_instances=1)
        _decode_run(cfg, params, sl, active, new, steps)
        t, toks_s, out = _decode_run(cfg, params, sl, active, new, steps)
        assert out == ref, f"tp={tp} sharded tokens diverge"
        emit(f"mesh_decode_tp{tp}", t / toks_s * 1e6,
             f"tok_s={toks_s / t:.1f};vs_tp1={t_ref / t:.2f}x")
        snap["tp"][str(tp)] = {
            "us_per_token": round(t / toks_s * 1e6, 1),
            "tokens_per_s": round(toks_s / t, 1),
            "vs_single_device": round(t_ref / t, 2),
            "tokens_bit_identical": True,
        }

    syncs = 4 if SMOKE else 16
    t_host, bytes_host, _, _ = _mirror_run(cfg, params, None, syncs)
    emit("mesh_mirror_hostcopy", t_host * 1e6,
         f"bytes={bytes_host:.0f}")
    snap["mirror"]["host_copy"] = {"us_per_sync": round(t_host * 1e6, 1),
                                   "bytes": bytes_host}
    if n_dev >= 4:
        slices = carve_slices(2, n_instances=2)
        t_coll, bytes_coll, d2d, host = _mirror_run(cfg, params, slices,
                                                    syncs)
        assert d2d > 0 and host == 0, "mirror fell off the device fabric"
        assert bytes_coll == bytes_host, "transport changed the ledger"
        emit("mesh_mirror_collective", t_coll * 1e6,
             f"bytes={bytes_coll:.0f};d2d_copies={d2d};host_copies={host};"
             f"vs_hostcopy={t_host / t_coll:.2f}x")
        snap["mirror"]["collective"] = {
            "us_per_sync": round(t_coll * 1e6, 1),
            "bytes": bytes_coll,
            "d2d_copies": d2d,
            "host_copies": host,
            "vs_host_copy": round(t_host / t_coll, 2),
        }
    else:
        emit("mesh_mirror_collective", 0.0, "skipped=needs_devices")

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
