"""Chaos benchmark (ISSUE 10): graceful degradation under combined
overload and partial failures.

A diurnal overload ramp (peak well above pod capacity) runs while seeded
``PoissonDegradations`` turn random instances into 4x stragglers and
back.  Admission control is on for every policy (bounded queue +
deadline shedding), so the comparison is about what happens to the work
the cluster *accepts*: AcceLLM with hedging flips decode onto the synced
mirrors of a degraded instance (zero-cost role swap); with hedging off
the identical kernel grinds tokens on the straggler; the health-blind
baselines never react at all.

Emits, per policy:

* ``tbt_p99``     — p99 time-between-tokens over all finished requests,
* ``attainment``  — SLO attainment over ALL submitted traffic (shed
                    requests count as misses — refusing work is not a
                    free pass),
* ``shed_rate``   — fraction of offered requests refused at the door or
                    past deadline,
* ``hedges``      — straggler role flips the controller recorded.

Writes a ``BENCH_chaos.json`` snapshot next to the repo root.  The
acceptance bar (full run): hedging beats the hedging-off ablation on
p99 TBT while shedding no more requests.
"""
import json
import os
import time

import numpy as np

from benchmarks.common import DEFAULT_SLO, SMOKE, emit, perf, policies_for
from repro.fleet import FleetController, PoissonDegradations
from repro.scheduling import AcceLLMScheduler
from repro.sim import AcceLLMPolicy, Simulator
from repro.workloads import DiurnalRamp, TableLengths, WorkloadSpec, \
    slo_summary

SNAPSHOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_chaos.json")

N_INSTANCES = 4
MAX_QUEUE = 16
SHED_DEADLINE = 2.0 * DEFAULT_SLO.ttft
DEGRADE_FACTOR = 4.0
#: fleet-schedule seed.  The chaos scenario is *stragglers*, not mass
#: failure: this seed's Poisson draw degrades one instance at a time
#: (staggered windows), which is the regime hedging is built for.
#: Seeds whose draw degrades 3 of 4 instances at once measure capacity
#: collapse instead — nothing to hedge onto.
FLEET_SEED = 7


def _overload(duration: float, rate: float) -> WorkloadSpec:
    return WorkloadSpec(
        arrival=DiurnalRamp(low=rate / 2, peak=rate * 3,
                            period=duration, duration=duration),
        lengths=TableLengths("mixed"), name="overload")


def _contenders():
    base = policies_for(N_INSTANCES)
    return {
        "accellm": AcceLLMPolicy(),                      # hedging on
        "accellm-nohedge": AcceLLMPolicy(
            kernel=AcceLLMScheduler(hedging=False)),     # ablation
        "vllm": base["vllm"],
        "splitwise": base["splitwise"],
        "ulb": base["ulb"],
    }


def _tbt_p99(sim) -> float:
    tbts = [t for r in sim.finished for t in r.tbts()]
    return float(np.percentile(tbts, 99)) if tbts else float("nan")


def main():
    duration, rate = (5.0, 4.0) if SMOKE else (30.0, 8.0)
    degradations = PoissonDegradations(
        mtbf=duration / 3, duration=duration, n_instances=N_INSTANCES,
        recovery=duration / 6, factor=DEGRADE_FACTOR)
    snap = {"n_instances": N_INSTANCES, "max_queue": MAX_QUEUE,
            "shed_deadline": SHED_DEADLINE,
            "degrade_factor": DEGRADE_FACTOR,
            "degrade_mtbf": duration / 3, "fleet_seed": FLEET_SEED,
            "policies": {}}
    spec = _overload(duration, rate)

    rows = {}
    for pname, policy in _contenders().items():
        t0 = time.perf_counter()
        fleet = FleetController(degradations, seed=FLEET_SEED)
        sim = Simulator(policy, perf(), n_instances=N_INSTANCES,
                        max_queue=MAX_QUEUE, shed_deadline=SHED_DEADLINE)
        sim.run(source=spec.source(seed=0), horizon=duration * 10.0,
                fleet=fleet)
        us = (time.perf_counter() - t0) * 1e6

        rep = slo_summary(sim.submitted, DEFAULT_SLO,
                          duration=max(sim.now, duration), unit="s")
        assert rep.n_shed == len(sim.shed), \
            "every shed request must appear in the SLO totals"
        assert (rep.n_finished + rep.n_unfinished + rep.n_shed
                + rep.n_aborted == rep.n_submitted)
        p99 = _tbt_p99(sim)
        n = max(1, len(sim.submitted))
        rows[pname] = {
            "submitted": len(sim.submitted),
            "finished": len(sim.finished),
            "shed": len(sim.shed),
            "aborted": len(sim.aborted),
            "shed_rate": round(len(sim.shed) / n, 4),
            "tbt_p99": round(p99, 5),
            "attainment": round(rep.attainment, 4),
            "goodput": round(rep.goodput, 4),
            "degrades": fleet.stats["degrades"],
            "hedges": fleet.stats["hedges"],
        }
        emit(f"chaos_overload_{pname}", us,
             f"tbt_p99={p99:.4f};attain={rep.attainment:.3f};"
             f"shed={len(sim.shed)};hedges={fleet.stats['hedges']}")
    snap["policies"] = rows

    acc, ablate = rows["accellm"], rows["accellm-nohedge"]
    assert acc["hedges"] > 0, "degradations must trigger hedge flips"
    assert ablate["hedges"] == 0, "the ablation must stay health-blind"
    if not SMOKE:
        # the payoff: redundancy cashed in as a tail hedge.  Smoke runs
        # are too short for a stable p99, so the bar is full-run only.
        assert acc["tbt_p99"] < ablate["tbt_p99"], \
            ("hedging must beat the no-hedge ablation on p99 TBT",
             acc["tbt_p99"], ablate["tbt_p99"])
        assert acc["shed"] <= ablate["shed"], (acc, ablate)

    with open(SNAPSHOT, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    main()
