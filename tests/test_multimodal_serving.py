"""VLM / audio enc-dec requests through the full AcceLLM cluster (the
modality-frontend carve-out feeds precomputed embeddings as request extras)."""
import jax
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.live import LiveCluster
from repro.serving import Request


def _serve(cfg, extras_fn, n=4):
    params = init_params(jax.random.PRNGKey(0), cfg)
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=6,
                          kv_capacity=128, policy=AcceLLMScheduler())
    key = jax.random.PRNGKey(3)
    for i in range(n):
        plen = 6 + i
        req = Request(prompt_len=plen, max_new_tokens=3 + i,
                      prompt_tokens=jax.random.randint(
                          jax.random.fold_in(key, i), (1, plen), 0,
                          cfg.vocab_size))
        cluster.submit(req, extras_fn(jax.random.fold_in(key, 100 + i)))
    done = cluster.run(max_steps=200)
    assert len(done) == n
    for r in done:
        assert len(r.output_tokens) == r.max_new_tokens
    return cluster


def test_vlm_requests_through_cluster():
    cfg = get_config("internvl2-1b").reduced()

    def extras(key):
        return {"patch_embeds": jax.random.normal(
            key, (1, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))}

    cluster = _serve(cfg, extras)
    assert cluster.stats["mirror_syncs"] > 0


def test_audio_encdec_requests_through_cluster():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    frames = cfg.encoder.max_source_positions

    def extras(key):
        return {"frames": jax.random.normal(
            key, (1, frames, cfg.frontend.embed_dim))}

    cluster = _serve(cfg, extras)
    # encoder output is replicated state: redundancy covers it too
    assert cluster.stats["replica_promotions"] >= 0
