"""Scale layer: the vectorized scheduler core must be a bit-identical
drop-in for the dict-backed policies (same kernel decision trace, same
request outcomes), ULB must route by least outstanding work on both
backends, and the supporting harness pieces (streaming traces, timeline
stride, O(1) ledger bytes) must hold their invariants."""
import json

import pytest

from repro.configs import get_config
from repro.scheduling.registry import get_policy
from repro.sim import (H100, AcceLLMPolicy, InstanceSpec, PerfModel,
                       Simulator, SplitwisePolicy, ULBPolicy, VLLMPolicy)
from repro.workloads import Bursty, TableLengths, WorkloadSpec

CFG = get_config("llama2-70b")
PERF = PerfModel(CFG, InstanceSpec(H100, 4))

#: small-but-busy MMPP stream: enough contention that routing, pairing
#: and rebalancing all fire, cheap enough for CI
_SPEC = WorkloadSpec(
    arrival=Bursty(rate_on=12.0, duration=40.0, rate_off=2.0,
                   mean_on=6.0, mean_off=4.0),
    lengths=TableLengths(workload="mixed"), name="bursty")


def _run_traced(policy, n_instances=4, horizon=500.0, seed=0):
    policy.kernel.trace = []
    sim = Simulator(policy, PERF, n_instances=n_instances)
    sim.run(source=_SPEC.source(seed=seed), horizon=horizon)
    return policy.kernel.trace, sim


def _fingerprint(sim):
    return [(r.rid, r.generated, r.finish_time)
            for r in sorted(sim.submitted, key=lambda r: r.rid)]


# ---------------------------------------------------------------------------
# golden equivalence: array-backed kernels == dict-backed kernels (sim)
# ---------------------------------------------------------------------------


PAIRS = {
    "accellm": (lambda: AcceLLMPolicy(),
                lambda: AcceLLMPolicy(kernel=get_policy("accellm-vec"))),
    "vllm": (lambda: VLLMPolicy(),
             lambda: VLLMPolicy(kernel=get_policy("vllm-vec"))),
    "ulb": (lambda: ULBPolicy(),
            lambda: ULBPolicy(kernel=get_policy("ulb-vec"))),
    "splitwise": (lambda: SplitwisePolicy(1),
                  lambda: SplitwisePolicy(
                      1, kernel=get_policy("splitwise-vec", n_prefill=1))),
}


@pytest.mark.parametrize("name", sorted(PAIRS))
def test_vectorized_kernel_identical_decisions_sim(name):
    """The array-backed kernel must emit the identical decision trace AND
    produce identical request outcomes on a bursty workload — the
    guarantee that lets the shootout run vectorized kernels and report
    them as the original policies."""
    make_scalar, make_vec = PAIRS[name]
    tr_s, sim_s = _run_traced(make_scalar())
    tr_v, sim_v = _run_traced(make_vec())
    assert len(tr_s) > 50, "trace must exercise real scheduling"
    assert tr_s == tr_v, (
        f"{name}: vectorized kernel diverged from dict-backed at entry "
        f"{next(i for i, (a, b) in enumerate(zip(tr_s, tr_v)) if a != b)}"
        if tr_s != tr_v and any(a != b for a, b in zip(tr_s, tr_v))
        else f"{name}: trace lengths differ {len(tr_s)} vs {len(tr_v)}")
    assert _fingerprint(sim_s) == _fingerprint(sim_v)


def test_vectorized_kernel_reports_sched_speed():
    """The timer plumbing: a sim run reports a positive per-iteration
    scheduler overhead and counts iterations."""
    _, sim = _run_traced(AcceLLMPolicy(kernel=get_policy("accellm-vec")))
    assert sim.n_iterations > 100
    assert sim.sched_us_per_iter > 0.0


# ---------------------------------------------------------------------------
# golden equivalence on the live backend: vec kernels fall back cleanly
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def live_setup():
    import jax
    from repro.models import init_params
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_live(cfg, params, kernel, n_instances=2):
    import jax
    from repro.scheduling import LiveCluster
    from repro.serving import Request
    kernel.trace = []
    cluster = LiveCluster(cfg, params, n_instances=n_instances, num_slots=8,
                          kv_capacity=256, policy=kernel)
    key = jax.random.PRNGKey(7)
    lengths = [(8, 4), (12, 6), (6, 5), (10, 3), (7, 6), (9, 4)]
    for i, (plen, dlen) in enumerate(lengths):
        # explicit rids: the global Request counter would differ between
        # the two runs and make the traces trivially unequal
        cluster.submit(Request(
            prompt_len=plen, max_new_tokens=dlen, rid=i,
            prompt_tokens=jax.random.randint(
                jax.random.fold_in(key, i), (1, plen), 0, cfg.vocab_size)))
        cluster.step()
    steps = 0
    while cluster.pending() and steps < 60:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    return kernel.trace, cluster


@pytest.mark.parametrize("name", ["accellm", "ulb"])
def test_vectorized_kernel_identical_decisions_live(live_setup, name):
    """On the live backend there is no array state (``cluster.arrays`` is
    None), so the vectorized kernels must fall back to the scalar path —
    and therefore trace identically to the dict-backed originals."""
    cfg, params = live_setup
    tr_s, cl_s = _run_live(cfg, params, get_policy(name))
    tr_v, cl_v = _run_live(cfg, params, get_policy(f"{name}-vec"))
    assert tr_s, "live trace must not be empty"
    assert tr_s == tr_v
    assert cl_s.sched_us_per_iter > 0.0
    assert cl_s.n_iterations == cl_v.n_iterations


# ---------------------------------------------------------------------------
# ULB kernel: least outstanding work in tokens
# ---------------------------------------------------------------------------


class _FakeInst:
    def __init__(self, index, backlog, remaining, admit=True):
        self.index = index
        self._backlog = backlog
        self._remaining = remaining
        self._admit = admit

    def alive(self):
        return True

    def draining(self):
        return False

    def can_admit(self, req):
        return self._admit

    def can_queue(self):
        return True

    def prefill_backlog_tokens(self):
        return self._backlog

    def decode_remaining(self):
        return dict(enumerate(self._remaining))


class _FakeCluster:
    def __init__(self, insts):
        self._insts = insts

    def instances(self):
        return self._insts


class _FakeReq:
    rid = 77


def test_ulb_routes_to_least_outstanding_work():
    """Queue length and resident count must NOT decide: instance 1 has
    more resident requests but strictly less outstanding token work."""
    kernel = get_policy("ulb")
    cluster = _FakeCluster([
        _FakeInst(0, backlog=500, remaining=[10]),          # 510 tokens
        _FakeInst(1, backlog=0, remaining=[40, 50, 60]),    # 150 tokens
    ])
    assert kernel.route(cluster, _FakeReq()) == 1


def test_ulb_tie_breaks_by_index():
    kernel = get_policy("ulb")
    cluster = _FakeCluster([_FakeInst(0, 100, [20]), _FakeInst(1, 0, [120])])
    assert kernel.route(cluster, _FakeReq()) == 0


def test_ulb_prefers_admittable_instances():
    """A full instance with less work must lose to an admittable one —
    admission headroom gates the candidate pool before the work score."""
    kernel = get_policy("ulb")
    cluster = _FakeCluster([
        _FakeInst(0, backlog=0, remaining=[5], admit=False),
        _FakeInst(1, backlog=0, remaining=[900]),
    ])
    assert kernel.route(cluster, _FakeReq()) == 1


def test_ulb_runs_end_to_end_on_sim():
    """ULB completes the bursty stream and emits route decisions."""
    trace, sim = _run_traced(ULBPolicy())
    assert {e[0] for e in trace} == {"route"}
    assert all(r.finish_time is not None for r in sim.submitted)


# ---------------------------------------------------------------------------
# golden live-vs-sim trace: the ULB kernel decides identically on both
# backends (the same consistency check test_scheduling pins for AcceLLM)
# ---------------------------------------------------------------------------

#: one scheduler iteration per op; arrivals submit right before the step
_ULB_SCRIPT = [("arrive", 8, 4), ("tick",), ("arrive", 12, 6), ("tick",),
               ("arrive", 6, 5), ("arrive", 10, 3), ("tick",),
               ("arrive", 7, 6), ("tick",)]


def _run_live_ulb(cfg, params, n_instances=2):
    import jax
    from repro.scheduling import LiveCluster
    from repro.serving import Request
    kernel = get_policy("ulb")
    kernel.trace = []
    cluster = LiveCluster(cfg, params, n_instances=n_instances, num_slots=8,
                          kv_capacity=256, policy=kernel)
    key = jax.random.PRNGKey(11)
    rids = []
    for i, op in enumerate(_ULB_SCRIPT):
        if op[0] == "arrive":
            plen, dlen = op[1], op[2]
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=jax.random.randint(
                              jax.random.fold_in(key, i), (1, plen), 0,
                              cfg.vocab_size))
            rids.append(req.rid)
            cluster.submit(req)
        cluster.step()
    steps = 0
    while cluster.pending() and steps < 60:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    return kernel.trace, rids, steps


def _run_sim_ulb(rids, extra_ticks, n_instances=2):
    """Drive the simulator adapter through the same script lock-step:
    arrivals route+prefill via the adapter (kernel decides), each tick
    advances every decode batch one token.  Unlike the AcceLLM golden
    driver there is NO prefill skip — vLLM-style mixed batching decodes
    the freshly prefilled request within the same iteration, exactly as
    the live executor's phase order does."""
    from repro.sim.workload import SimRequest
    kernel = get_policy("ulb")
    kernel.trace = []
    sim = Simulator(ULBPolicy(kernel=kernel), PERF, n_instances=n_instances)
    sim.kick = lambda inst: None          # event mechanics not under test
    pol = sim.policy

    def tick():
        for inst in sim.instances:
            done = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done.append(r)
            pol.on_decode_done(inst, done)

    arrivals = iter(rids)
    for op in _ULB_SCRIPT:
        if op[0] == "arrive":
            r = SimRequest(rid=next(arrivals), arrival=0.0,
                           prompt_len=op[1], decode_len=op[2])
            inst = pol.route(r)
            r.generated = 1               # the prefill's first token
            pol.on_prefill_done(inst, [r])
        tick()
    for _ in range(extra_ticks):
        tick()
    return kernel.trace


def test_golden_ulb_trace_live_vs_sim(live_setup):
    cfg, params = live_setup
    live_trace, rids, extra = _run_live_ulb(cfg, params)
    sim_trace = _run_sim_ulb(rids, extra)
    assert live_trace == sim_trace, (
        "ULB kernel made different decisions on the two backends:\n"
        f"live: {live_trace}\nsim:  {sim_trace}")
    assert {e[0] for e in live_trace} == {"route"}
    # least-outstanding-work routing must spread the script across both
    assert {e[2] for e in live_trace} == set(range(2))


# ---------------------------------------------------------------------------
# streaming JSONL traces: stream=True replays bit-identically, O(1) memory
# ---------------------------------------------------------------------------


def test_streaming_trace_round_trip(tmp_path):
    from repro.workloads import TraceFileLengths, TraceFileReplay, \
        load_trace, save_trace
    path = tmp_path / "t.jsonl"
    n = save_trace(path, _SPEC.source(seed=5))   # consumed lazily
    eager = load_trace(path)
    lazy = load_trace(path, stream=True)
    assert isinstance(lazy.arrival, TraceFileReplay)
    assert isinstance(lazy.lengths, TraceFileLengths)
    key = lambda rs: [(r.rid, r.arrival, r.prompt_len, r.max_new_tokens)
                      for r in rs]
    eager_stream = key(eager.source(seed=0))
    assert len(eager_stream) == n
    assert key(lazy.source(seed=0)) == eager_stream
    # a fresh source rewinds the forward-only cursor
    assert key(lazy.source(seed=0)) == eager_stream


def test_streaming_trace_drives_simulator(tmp_path):
    from repro.workloads import load_trace, save_trace
    path = tmp_path / "t.jsonl"
    save_trace(path, _SPEC.source(seed=0))
    tr_mem, sim_mem = _run_traced(AcceLLMPolicy())
    pol = AcceLLMPolicy()
    pol.kernel.trace = []
    sim = Simulator(pol, PERF, n_instances=4)
    sim.run(source=load_trace(path, stream=True).source(seed=0),
            horizon=500.0)
    assert pol.kernel.trace == tr_mem
    assert _fingerprint(sim) == _fingerprint(sim_mem)


def test_streaming_fleet_trace_round_trip(tmp_path):
    from repro.fleet import (Drain, FleetTraceReplay, JoinInstance,
                             KillInstance, load_fleet_trace,
                             save_fleet_trace)
    path = tmp_path / "f.jsonl"
    events = [KillInstance(1.5, 0), JoinInstance(3.0, None),
              Drain(4.0, 1), JoinInstance(6.0, 0)]
    save_fleet_trace(path, events)
    eager = load_fleet_trace(path)
    lazy = load_fleet_trace(path, stream=True)
    assert isinstance(lazy, FleetTraceReplay)
    assert lazy.stream() == eager.stream() == events
    assert lazy.stream() == events      # re-iterable


def test_streaming_trace_missing_record(tmp_path):
    from repro.workloads import TraceFileLengths
    path = tmp_path / "t.jsonl"
    path.write_text(json.dumps(
        {"arrival": 0.0, "prompt_len": 5, "decode_len": 3}) + "\n")
    lengths = TraceFileLengths(str(path))
    assert lengths.sample(None, 0) == (5, 3)
    with pytest.raises(IndexError):
        lengths.sample(None, 1)


# ---------------------------------------------------------------------------
# timeline stride: bounded observability memory, same aggregate metrics
# ---------------------------------------------------------------------------


def test_sim_timeline_stride_bounds_memory():
    def run(stride):
        sim = Simulator(AcceLLMPolicy(), PERF, n_instances=4,
                        timeline_stride=stride)
        sim.run(source=_SPEC.source(seed=0), horizon=500.0)
        return sim
    dense, strided = run(1), run(8)
    assert 0 < len(strided.timeline) < len(dense.timeline)
    assert len(strided.timeline) <= len(dense.timeline) // 8 + 1
    # sampling must not perturb the simulation itself
    assert _fingerprint(strided) == _fingerprint(dense)
    assert strided.n_iterations == dense.n_iterations


def test_live_timeline_stride(live_setup):
    from repro.api import ServeSpec, serve
    cfg, params = live_setup
    def run(stride):
        spec = ServeSpec(arch="starcoder2-3b", policy="accellm",
                         n_instances=2, num_slots=6, kv_capacity=128,
                         n_requests=4, workload="light", max_steps=200,
                         timeline_stride=stride)
        return serve(spec, cfg=cfg, params=params)
    dense, strided = run(1), run(4)
    assert dense.all_finished and strided.all_finished
    assert 0 < len(strided.timeline) < len(dense.timeline)
    assert strided.sched_us_per_iter > 0.0
    assert strided.cluster.n_iterations == dense.cluster.n_iterations


# ---------------------------------------------------------------------------
# O(1) ledger bytes: the running total must track every mutation path
# ---------------------------------------------------------------------------


def test_ledger_used_bytes_matches_per_request_sum():
    from repro.kvstore import BlockLedger, LineCosts
    costs = LineCosts.from_config(CFG)
    led = BlockLedger(costs=costs, num_blocks=64, block_lines=4)

    def explicit():
        return sum(costs.bytes_at(led.lines(r)) for r in led.resident())

    led.alloc(0, 6)
    led.alloc(1, 0)
    assert led.used_bytes() == explicit()
    led.append_line(0, 3)
    led.append_line(1, 9)
    assert led.used_bytes() == explicit()
    led.set_lines(1, 4)            # shrink path
    led.set_lines(0, 20)           # grow path
    assert led.used_bytes() == explicit()
    led.free(0)
    assert led.used_bytes() == explicit()
    led.free(1)
    assert led.used_bytes() == 0.0
