"""jnp attention internals: chunked flash vs naive, ring buffers, MLA."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    ring_valid, ring_write)


def naive_attention(q, k, v, causal, window=None, scale=None):
    import math
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = scale or 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[1])[None]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))


@pytest.mark.parametrize("chunks", [(512, 1024), (16, 32), (7, 13)])
@pytest.mark.parametrize("window", [None, 20])
def test_flash_vs_naive(chunks, window, rng_key):
    qc, kc = chunks
    B, S, H, KVH, hd = 2, 48, 4, 2, 32
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, KVH, hd))
    v = jax.random.normal(k3, (B, S, KVH, hd))
    out = flash_attention(q, k, v, causal=True, scale=hd ** -0.5,
                          window=window, q_chunk=qc, kv_chunk=kc)
    exp = naive_attention(q, k, v, causal=True, window=window)
    assert float(jnp.abs(out - exp.astype(out.dtype)).max()) < 1e-5


def test_ring_write_scalar_and_vector():
    cap = 8
    cache = jnp.zeros((2, cap, 3))
    vals = jnp.ones((2, 2, 3))
    # scalar clock, wraps
    c1 = ring_write(cache, vals, jnp.int32(7), cap)
    assert float(c1[0, 7, 0]) == 1.0 and float(c1[0, 0, 0]) == 1.0
    # per-batch clock
    c2 = ring_write(cache, vals[:, :1], jnp.array([1, 5]), cap)
    assert float(c2[0, 1, 0]) == 1.0 and float(c2[1, 5, 0]) == 1.0
    assert float(c2[0, 5, 0]) == 0.0


def test_ring_write_overflow_keeps_tail():
    cap = 4
    cache = jnp.zeros((1, cap, 1))
    vals = jnp.arange(6, dtype=jnp.float32).reshape(1, 6, 1)
    c = ring_write(cache, vals, jnp.int32(0), cap)
    # last 4 values (2,3,4,5) at slots (2,3,0,1): slot i holds value with
    # logical position p where p % cap == i
    got = sorted(float(x) for x in c[0, :, 0])
    assert got == [2.0, 3.0, 4.0, 5.0]
    for p in range(2, 6):
        assert float(c[0, p % cap, 0]) == float(p)


def test_ring_valid():
    assert ring_valid(jnp.int32(3), 8).sum() == 3
    assert ring_valid(jnp.int32(12), 8).sum() == 8
    v = ring_valid(jnp.array([2, 9]), 8)
    assert v.shape == (2, 8) and int(v[0].sum()) == 2 and int(v[1].sum()) == 8


def test_decode_attention_batched_valid(rng_key):
    B, H, KVH, hd, W = 2, 4, 2, 16, 32
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    kc = jax.random.normal(k2, (B, W, KVH, hd))
    vc = jax.random.normal(k3, (B, W, KVH, hd))
    valid = jnp.arange(W)[None] < jnp.array([[5], [W]])
    out = decode_attention(q, kc, vc, scale=hd ** -0.5, valid=valid)
    # manual check for request 0: only first 5 slots
    exp = naive_attention(q[:1], kc[:1, :5], vc[:1, :5], causal=False,
                          scale=hd ** -0.5)
    assert float(jnp.abs(out[0] - exp[0, 0].astype(out.dtype)).max()) < 1e-5
