"""Property-based tests (hypothesis, with a built-in fallback — see
tests/_propcheck.py) for the AcceLLM load balancer."""
from _propcheck import given, settings, st

from repro.core.balancer import Item, imbalance, partition, should_rebalance

items_strategy = st.lists(
    st.tuples(st.floats(min_value=1.0, max_value=1e9),
              st.integers(min_value=0, max_value=1),
              st.booleans()),
    min_size=0, max_size=40,
).map(lambda rows: [Item(rid=i, weight=w, home=h, movable=m)
                    for i, (w, h, m) in enumerate(rows)])


@given(items_strategy)
@settings(max_examples=200, deadline=None)
def test_partition_conserves_requests(items):
    s0, s1, moves = partition(items)
    rids = {it.rid for it in items}
    assert s0 | s1 == rids
    assert s0 & s1 == set()


@given(items_strategy)
@settings(max_examples=200, deadline=None)
def test_partition_respects_immovable(items):
    s0, s1, moves = partition(items)
    for it in items:
        if not it.movable:
            assert it.rid in (s0 if it.home == 0 else s1)
    moved = {rid for rid, _, _ in moves}
    for it in items:
        if not it.movable:
            assert it.rid not in moved


@given(items_strategy)
@settings(max_examples=200, deadline=None)
def test_partition_count_balanced_when_all_movable(items):
    movable = [Item(it.rid, it.weight, it.home, True) for it in items]
    s0, s1, _ = partition(movable)
    assert abs(len(s0) - len(s1)) <= 2


@given(items_strategy)
@settings(max_examples=200, deadline=None)
def test_partition_never_worse_weight_balance_when_all_movable(items):
    movable = [Item(it.rid, it.weight, it.home, True) for it in items]
    if not movable:
        return
    _, dw_before = imbalance(movable)
    s0, s1, _ = partition(movable)
    w0 = sum(it.weight for it in movable if it.rid in s0)
    w1 = sum(it.weight for it in movable if it.rid in s1)
    # LPT greedy guarantee: final gap is at most the max single weight
    assert abs(w0 - w1) <= max(it.weight for it in movable) + 1e-6


@given(items_strategy)
@settings(max_examples=100, deadline=None)
def test_moves_are_consistent(items):
    s0, s1, moves = partition(items)
    for rid, src, dst in moves:
        assert src != dst
        assert rid in (s0 if dst == 0 else s1)


def test_should_rebalance_triggers():
    heavy = [Item(0, 100.0, 0, True), Item(1, 100.0, 0, True),
             Item(2, 1.0, 1, True)]
    assert should_rebalance(heavy)
    balanced = [Item(0, 50.0, 0, True), Item(1, 50.0, 1, True)]
    assert not should_rebalance(balanced)
    assert not should_rebalance([])
