"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU with shape + finiteness
assertions, plus one prefill->decode serving step."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, forward_train, init_params, init_state,
                          prefill)
from repro.training import AdamWConfig, init_opt_state, make_train_step


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, 32, cfg.frontend.embed_dim))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = init_params(rng_key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, rng_key, B, S)
    logits, aux = forward_train(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = init_params(rng_key, cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    B, S = 2, 16
    batch = _batch(cfg, rng_key, B, S)
    batch["labels"] = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    params2, opt2, metrics = step(params, opt, batch, jnp.float32(1.0))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)), jax.tree_util.tree_map(
            lambda a, b: jnp.any(a != b), params, params2), False)
    assert moved


@pytest.mark.parametrize("arch", list_archs())
def test_serve_prefill_decode(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = init_params(rng_key, cfg)
    B, S = 2, 12
    batch = _batch(cfg, rng_key, B, S)
    state = init_state(cfg, B, 64)
    logits, state = prefill(cfg, params, batch, state)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    prefix = (cfg.frontend.num_prefix_tokens
              if (cfg.frontend and cfg.frontend.kind == "vision") else 0)
    dl, state = decode_step(cfg, params, tok, state,
                            jnp.full((B,), S + prefix, jnp.int32))
    assert dl.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dl).all())
