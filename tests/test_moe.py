"""MoE dispatch correctness: scatter/gather dispatch vs a naive dense
all-experts reference, capacity-drop bounds, aux-loss properties."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.common import swiglu
from repro.models.moe import (_capacity, _ranks_of, _route, init_moe,
                              moe_forward)


def naive_moe(params, x2, top_k):
    """Dense reference: every expert on every token, mix by gates."""
    gates, eidx, _ = _route(x2, params["router"], top_k)
    h = swiglu(jnp.einsum("td,edf->tef", x2, params["w_gate"]),
               jnp.einsum("td,edf->tef", x2, params["w_up"]))
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T,E,d)
    oh = jax.nn.one_hot(eidx, params["w_gate"].shape[0])     # (T,k,E)
    w = (gates[..., None] * oh).sum(1)                        # (T,E)
    return jnp.einsum("te,ted->td", w, y_all.astype(jnp.float32))


@pytest.mark.parametrize("arch", ["arctic-480b", "deepseek-v3-671b"])
def test_moe_matches_dense_reference(arch, rng_key):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_moe(rng_key, cfg, jnp.float32)
    routed = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}
    T = 32
    x = jax.random.normal(jax.random.fold_in(rng_key, 2),
                          (1, T, cfg.d_model))
    y, aux = moe_forward(cfg, routed, x)
    exp = naive_moe(routed, x[0], cfg.moe.top_k)
    err = float(jnp.abs(y[0] - exp.astype(y.dtype)).max())
    assert err < 1e-4, err
    assert float(aux) > 0


def test_ranks_within_expert():
    e = jnp.array([2, 0, 2, 1, 0, 2])
    r = _ranks_of(e, 3)
    # expert 0 at idx 1,4 -> ranks 0,1 ; expert 2 at idx 0,2,5 -> 0,1,2
    assert list(map(int, r)) == [0, 0, 1, 0, 1, 2]


def test_capacity_drops_bounded(rng_key):
    """With cf=1.0 and adversarial routing, at most C tokens per expert."""
    cfg = get_config("arctic-480b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    params = init_moe(rng_key, cfg, jnp.float32)
    routed = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}
    T = 64
    x = jnp.broadcast_to(
        jax.random.normal(rng_key, (1, 1, cfg.d_model)), (1, T, cfg.d_model))
    # identical tokens -> all route to the same experts -> heavy drops; must
    # still be finite and bounded
    y, aux = moe_forward(cfg, routed, x)
    assert bool(jnp.isfinite(y).all())
    C = _capacity(T, cfg.moe.top_k, cfg.moe.num_experts,
                  cfg.moe.capacity_factor)
    assert C == -(-T * cfg.moe.top_k * 1.0 // cfg.moe.num_experts)


def test_aux_loss_uniform_is_minimal(rng_key):
    """Perfectly uniform routing gives aux == coef (the theoretical min)."""
    cfg = get_config("arctic-480b").reduced()
    m = cfg.moe
    from repro.models.moe import _aux_loss
    E, T, k = m.num_experts, 64, m.top_k
    eidx = (jnp.arange(T * k) % E).reshape(T, k)
    probs = jnp.full((T, E), 1.0 / E)
    a_uniform = _aux_loss(eidx, probs, E, 1.0)
    # concentrated routing must be larger
    eidx_bad = jnp.zeros((T, k), jnp.int32)
    probs_bad = jnp.zeros((T, E)).at[:, 0].set(1.0)
    a_bad = _aux_loss(eidx_bad, probs_bad, E, 1.0)
    assert float(a_uniform) == pytest.approx(1.0, rel=1e-5)
    assert float(a_bad) > float(a_uniform) * (E / 2)


def test_shared_expert_and_dense_residual(rng_key):
    cfg = get_config("arctic-480b").reduced()
    params = init_moe(rng_key, cfg, jnp.float32)
    assert "dense_residual" in params
    x = jax.random.normal(rng_key, (1, 8, cfg.d_model))
    y, _ = moe_forward(cfg, params, x)
    assert y.shape == x.shape

    cfg2 = get_config("deepseek-v3-671b").reduced()
    params2 = init_moe(rng_key, cfg2, jnp.float32)
    assert "shared" in params2
