"""Chunkwise-parallel mLSTM (§Perf iteration 7) vs the recurrent oracle:
outputs and carry must agree (f32 reordering tolerance), including from a
nonzero incoming state, across chunk sizes and with the stabilizer active
(large gate pre-activations)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.xlstm import _mlstm_chunkwise, _mlstm_step


def _recurrent(q, k, v, i_pre, f_pre, carry0):
    def step(c, inp):
        return _mlstm_step(*inp, c)
    carry, hs = jax.lax.scan(
        step, carry0,
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1)))
    return carry, hs.swapaxes(0, 1)


def _inputs(key, B=2, S=128, H=3, hd=16, gate_scale=2.0):
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) * hd ** -0.5
    v = jax.random.normal(ks[2], (B, S, H, hd))
    i_pre = jax.random.normal(ks[3], (B, S, H)) * gate_scale
    f_pre = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    C0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    n0 = jnp.abs(jax.random.normal(ks[5], (B, H, hd))) * 0.1
    m0 = jnp.zeros((B, H))
    return q, k, v, i_pre, f_pre, (C0, n0, m0)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunkwise_matches_recurrent(chunk, rng_key):
    q, k, v, i_pre, f_pre, carry0 = _inputs(rng_key)
    carry_ref, h_ref = _recurrent(q, k, v, i_pre, f_pre, carry0)
    carry_cw, h_cw = _mlstm_chunkwise(q, k, v, i_pre, f_pre, carry0, chunk)
    assert float(jnp.abs(h_cw - h_ref).max()) < 1e-3
    for a, b in zip(carry_cw, carry_ref):
        assert float(jnp.abs(a - b).max()) < 1e-5


def test_chunkwise_stabilizer_extreme_gates(rng_key):
    """Large input-gate pre-activations stress the max-stabilizer path."""
    q, k, v, i_pre, f_pre, carry0 = _inputs(rng_key, gate_scale=20.0)
    carry_ref, h_ref = _recurrent(q, k, v, i_pre, f_pre, carry0)
    carry_cw, h_cw = _mlstm_chunkwise(q, k, v, i_pre, f_pre, carry0, 32)
    assert bool(jnp.isfinite(h_cw).all())
    rel = float(jnp.abs(h_cw - h_ref).max() / jnp.abs(h_ref).max())
    assert rel < 1e-3, rel


def test_chunkwise_composes_with_decode(rng_key):
    """Prefill chunkwise, then continue one recurrent decode step — must
    equal the all-recurrent run (the serving handoff path)."""
    q, k, v, i_pre, f_pre, carry0 = _inputs(rng_key, S=65)
    # full recurrent over 65 steps
    carry_ref, h_ref = _recurrent(q, k, v, i_pre, f_pre, carry0)
    # chunkwise over first 64, recurrent final step
    cw_carry, _ = _mlstm_chunkwise(q[:, :64], k[:, :64], v[:, :64],
                                   i_pre[:, :64], f_pre[:, :64], carry0, 32)
    carry_last, h_last = _mlstm_step(q[:, 64], k[:, 64], v[:, 64],
                                     i_pre[:, 64], f_pre[:, 64], cw_carry)
    assert float(jnp.abs(h_last - h_ref[:, 64]).max()) < 1e-3
    for a, b in zip(carry_last, carry_ref):
        assert float(jnp.abs(a - b).max()) < 1e-4


def test_model_level_chunkwise_vs_recurrent(rng_key):
    """Whole xlstm model: the chunkwise path (S=128 >= 2*MLSTM_CHUNK) must
    agree with a forced-recurrent run."""
    from repro.configs import get_config
    from repro.models import forward_train, init_params
    from repro.models import xlstm as xmod
    cfg = get_config("xlstm-1.3b").reduced()
    params = init_params(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (1, 128), 0, cfg.vocab_size)
    logits_cw, _ = forward_train(cfg, params, {"tokens": tokens},
                                 remat=False)
    old = xmod.MLSTM_CHUNK
    try:
        xmod.MLSTM_CHUNK = 10 ** 9  # force the recurrent fallback
        logits_rec, _ = forward_train(cfg, params, {"tokens": tokens},
                                      remat=False)
    finally:
        xmod.MLSTM_CHUNK = old
    assert float(jnp.abs(logits_cw - logits_rec).max()) < 5e-3
