import jax
import pytest

# Smoke tests and benches see the single real CPU device; ONLY the dry-run
# launcher sets xla_force_host_platform_device_count (per its module docs).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
