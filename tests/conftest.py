"""Suite-wide JAX setup.

The mesh-serving tests need a multi-device host, and the device-count
override must land in ``XLA_FLAGS`` BEFORE the jax backend initializes —
so it is appended here at conftest import time (pytest imports conftest
first; nothing has touched a device yet).  Forcing host platform devices
only splits the CPU into N independent XLA devices; single-device tests
still place everything on device 0 and are unaffected.  Tests that need
the full mesh take the ``mesh8`` fixture, which skips cleanly when the
platform ignored the flag (e.g. a real accelerator is attached).
"""
import os

_FORCE = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FORCE).strip()

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def mesh8():
    """The forced 8-device CPU pod; skips where devices can't be forced."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices "
                    f"(XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r} "
                    f"gave {jax.device_count()})")
    return jax.devices()[:8]
