"""Roofline HLO parser: shape-byte math, wire factors, loop multipliers on a
synthetic HLO module."""
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import (_group_size, _shape_bytes, _wire_bytes,
                                     analytic_bytes, collective_bytes,
                                     dot_flops, model_flops)

FAKE_HLO = """\
HloModule test

%inner_body (arg.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte = f32[8,16]{1,0} get-tuple-element(%arg.1), index=1
  %ar = f32[8,16]{1,0} all-reduce(%gte), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%gte, %ar)
}

%cond (arg.2: (s32[], f32[8,16])) -> pred[] {
  %arg.2 = (s32[], f32[8,16]{1,0}) parameter(0)
  ROOT %p = pred[] constant(true)
}

ENTRY %main (p0: f32[8,32], p1: f32[32,16]) -> f32[8,16] {
  %p0 = f32[8,32]{1,0} parameter(0)
  %p1 = f32[32,16]{1,0} parameter(1)
  %d = f32[8,16]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,64]{1,0} all-gather(%p0), channel_id=2, replica_groups=[128,2]<=[256], dimensions={1}
  %w = (s32[], f32[8,16]{1,0}) while(%tpl), condition=%cond, body=%inner_body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %r = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], bf16[4])") == 16 + 8


def test_wire_factors():
    assert _wire_bytes("all-gather", 100, 2) == 50.0
    assert _wire_bytes("all-reduce", 100, 2) == 100.0
    assert _wire_bytes("collective-permute", 100, 99) == 100.0


def test_group_size_parsing():
    assert _group_size("replica_groups=[16,16]<=[256]") == 16
    assert _group_size("replica_groups={{0,1,2,3}}") == 4


def test_collective_bytes_loop_multiplier():
    total, by_kind = collective_bytes(FAKE_HLO)
    # all-reduce inside while body: 8*16*4 bytes * 2 * 15/16 * 10 trips
    ar = 8 * 16 * 4 * 2 * (15 / 16) * 10
    ag = 8 * 64 * 4 * (1 / 2)
    assert by_kind["all-reduce"] == pytest.approx(ar)
    assert by_kind["all-gather"] == pytest.approx(ag)
    assert total == pytest.approx(ar + ag)


def test_dot_flops_from_hlo():
    # one dot in entry: 2 * 8*16 * 32
    assert dot_flops(FAKE_HLO) == pytest.approx(2 * 8 * 16 * 32)


def test_model_flops_formulas():
    cfg = get_config("deepseek-v3-671b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    n_act = cfg.param_count(active_only=True)
    assert tr == pytest.approx(6 * n_act * 256 * 4096)
    assert de == pytest.approx(2 * n_act * 128)


def test_analytic_bytes_sane():
    cfg = get_config("phi3-medium-14b")
    d = analytic_bytes(cfg, INPUT_SHAPES["decode_32k"])
    # decode per chip must at least stream the TP weight shard
    assert d >= cfg.param_count() * 2 / 16
    t = analytic_bytes(cfg, INPUT_SHAPES["train_4k"])
    assert t > d
