"""Fused selective-scan Pallas kernel vs the jnp oracle, swept over shapes
and block sizes (interpret mode executes the real kernel body on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.ref import mamba_scan_ref


def _inputs(key, B, S, C, N):
    ks = jax.random.split(key, 7)
    return (
        jax.random.normal(ks[0], (B, S, C)),
        jax.nn.softplus(jax.random.normal(ks[1], (B, S, C)) - 1.0),
        jax.random.normal(ks[2], (B, S, N)),
        jax.random.normal(ks[3], (B, S, N)),
        -jnp.exp(jax.random.normal(ks[4], (C, N)) * 0.5),
        jax.random.normal(ks[5], (C,)),
        jax.random.normal(ks[6], (B, C, N)) * 0.1,
    )


@pytest.mark.parametrize("shape", [
    # (B, S, C, N, c_blk, t_blk)
    (1, 64, 32, 16, 16, 32),
    (2, 128, 64, 16, 32, 64),
    (1, 96, 48, 8, 48, 32),     # uneven-ish: single channel block
])
def test_scan_kernel_sweep(shape, rng_key):
    B, S, C, N, cb, tb = shape
    args = _inputs(rng_key, B, S, C, N)
    y_p, h_p = mamba_scan_pallas(*args, channel_blk=cb, time_blk=tb,
                                 interpret=True)
    y_r, h_r = mamba_scan_ref(*args)
    assert float(jnp.abs(y_p - y_r).max()) < 1e-4
    assert float(jnp.abs(h_p - h_r).max()) < 1e-4


def test_scan_kernel_state_carry_across_time_blocks(rng_key):
    """Splitting time into 4 grid blocks must equal a single block (the
    VMEM scratch carries across the sequential grid dim)."""
    args = _inputs(rng_key, 1, 128, 16, 16)
    y1, h1 = mamba_scan_pallas(*args, channel_blk=16, time_blk=128,
                               interpret=True)
    y4, h4 = mamba_scan_pallas(*args, channel_blk=16, time_blk=32,
                               interpret=True)
    assert float(jnp.abs(y1 - y4).max()) < 1e-5
    assert float(jnp.abs(h1 - h4).max()) < 1e-5


def test_scan_kernel_nonzero_initial_state(rng_key):
    """Continuing from a serving state (prefill-resume path)."""
    x, dt, b, c, a, d, h0 = _inputs(rng_key, 1, 64, 16, 16)
    # run in two halves through the kernel, threading the state
    y_a, h_a = mamba_scan_pallas(x[:, :32], dt[:, :32], b[:, :32], c[:, :32],
                                 a, d, h0, channel_blk=16, time_blk=32,
                                 interpret=True)
    y_b, h_b = mamba_scan_pallas(x[:, 32:], dt[:, 32:], b[:, 32:], c[:, 32:],
                                 a, d, h_a, channel_blk=16, time_blk=32,
                                 interpret=True)
    y_full, h_full = mamba_scan_ref(x, dt, b, c, a, d, h0)
    assert float(jnp.abs(jnp.concatenate([y_a, y_b], 1) - y_full).max()) < 1e-4
    assert float(jnp.abs(h_b - h_full).max()) < 1e-4
