"""Training substrate: optimizer, schedules, data, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.training import (AdamWConfig, DataConfig, batches, cosine,
                            init_opt_state, make_train_step, wsd)
from repro.training.checkpoint import restore, save


def test_loss_decreases_on_learnable_data(rng_key):
    cfg = get_config("minicpm-2b").reduced()
    params = init_params(rng_key, cfg)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=8, seed=0))
    losses = []
    for i, b in zip(range(25), data):
        batch = {"tokens": jnp.asarray(b[:, :-1]),
                 "labels": jnp.asarray(b[:, 1:])}
        params, opt, m = step(params, opt, batch, wsd(i, warmup=5, total=25))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_wsd_schedule_shape():
    total, warmup = 100, 10
    vals = np.array([float(wsd(s, warmup=warmup, total=total))
                     for s in range(total + 1)])
    assert vals[0] == 0.0
    assert vals[warmup] == pytest.approx(1.0)
    assert np.allclose(vals[warmup:90], 1.0)          # stable phase flat
    assert vals[-1] == pytest.approx(0.01, rel=0.2)    # decayed
    assert (np.diff(vals[90:]) <= 1e-9).all()          # monotone decay


def test_cosine_schedule_shape():
    vals = [float(cosine(s, warmup=10, total=100)) for s in (0, 10, 55, 100)]
    assert vals[0] == 0.0 and vals[1] == pytest.approx(1.0)
    assert 0.1 <= vals[2] <= 1.0
    assert vals[3] == pytest.approx(0.1, rel=1e-3)


def test_grad_clip_bounds_update(rng_key):
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(rng_key, cfg)
    opt_cfg = AdamWConfig(lr=1e-3, grad_clip=1e-9)  # clip ~everything
    opt = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)}
    p2, _, m = step(params, opt, batch, jnp.float32(1.0))
    max_delta = jax.tree_util.tree_reduce(
        max, jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            params, p2))
    # update is ~lr * weight_decay * w at most (grad contribution clipped)
    assert max_delta < 1e-2


def test_checkpoint_roundtrip(rng_key):
    cfg = get_config("internvl2-1b").reduced()
    params = init_params(rng_key, cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params)
        restored = restore(path, params)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            params, restored)


def test_bf16_optimizer_state():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(AdamWConfig(state_dtype="bfloat16"), params)
    leaves = jax.tree_util.tree_leaves(opt.m)
    assert all(l.dtype == jnp.bfloat16 for l in leaves)


def test_data_pipeline_deterministic():
    c = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    a = next(batches(c))
    b = next(batches(c))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 17) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 128
