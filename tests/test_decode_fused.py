"""Paged fused decode (ISSUE 5): the block-table attention kernel wired
into the model, decode-batch compaction, and the fused multi-step scan.

Covers the three bit-identity contracts of the issue:

* paged decode (both kernel backends) == the dense full-window oracle,
  for ragged lengths including ring-buffer wrap,
* ``decode_multi(steps=k)`` == k sequential ``decode()`` calls
  token-for-token, including EOS mid-scan,
* the compacted batch == the full batch when replica slots are resident,

plus the planner's fuse gating, the repriced ``PerfModel.plan_time``
(block-granular gather bytes, per-dispatch amortization), and the
fused LiveCluster run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models.attention import set_kernel_backend
from repro.scheduling import LiveCluster
from repro.scheduling.baselines import VLLMScheduler
from repro.serving import InstanceEngine, Request
from repro.serving.sampling import decode_keys, sample_slots
from repro.sim import H100, InstanceSpec, PerfModel
from repro.stepplan import DecodePlan, Planner


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, i, plen, new=6):
    return Request(prompt_len=plen, max_new_tokens=new,
                   prompt_tokens=jax.random.randint(
                       jax.random.fold_in(jax.random.PRNGKey(23), i),
                       (1, plen), 0, cfg.vocab_size))


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("kv_capacity", 16)
    return InstanceEngine(cfg, params, **kw)


def _serve(cfg, params, shapes, *, paged, steps=0, backend=None,
           eos=None, **kw):
    """Prefill ``shapes`` = [(plen, new), ...] and decode to completion;
    returns the requests' output token lists."""
    eng = _engine(cfg, params, paged_decode=paged, eos_token=eos, **kw)
    reqs = [_mk(cfg, i, p, n) for i, (p, n) in enumerate(shapes)]
    for r in reqs:
        eng.prefill_request(r)
    if backend is not None:
        set_kernel_backend(backend)
    try:
        for _ in range(200):
            if not eng.slot_req:
                break
            if steps:
                eng.decode_multi(steps=steps)
            else:
                eng.decode()
    finally:
        if backend is not None:
            set_kernel_backend("auto")
    return [r.output_tokens for r in reqs], eng


# ---------------------------------------------------------------------------
# bit-identity: paged vs dense oracle
# ---------------------------------------------------------------------------

# ragged lengths; 12+10 > 16 = kv_capacity exercises the ring wrap
RAGGED = [(5, 10), (12, 10), (9, 4)]


def test_paged_decode_matches_dense_oracle(setup):
    cfg, params = setup
    dense, eng_d = _serve(cfg, params, RAGGED, paged=False)
    assert not eng_d.use_paged_decode
    paged, eng_p = _serve(cfg, params, RAGGED, paged=True)
    assert eng_p.use_paged_decode and eng_p.supports_paged_decode
    assert paged == dense
    # dense pays one host sync per token; compacted single-step too
    # (the fused win is per-plan, tested below)
    assert eng_p.host_syncs == eng_d.host_syncs


def test_paged_decode_matches_dense_pallas_backend(setup):
    """Same contract on the Mosaic kernel (interpret mode off-TPU)."""
    cfg, params = setup
    dense, _ = _serve(cfg, params, RAGGED, paged=False)
    paged, _ = _serve(cfg, params, RAGGED, paged=True, backend="pallas")
    assert paged == dense


def test_decode_multi_matches_sequential_decode(setup):
    cfg, params = setup
    seq, eng_s = _serve(cfg, params, RAGGED, paged=True)
    fused, eng_f = _serve(cfg, params, RAGGED, paged=True, steps=4)
    assert fused == seq
    # host syncs drop from one per token to one per fused plan
    assert eng_f.host_syncs < eng_s.host_syncs


def test_decode_multi_eos_short_circuits_mid_scan(setup):
    cfg, params = setup
    ref, _ = _serve(cfg, params, RAGGED, paged=False)
    eos = ref[1][3]        # a token sampled mid-stream of request 1
    seq, _ = _serve(cfg, params, RAGGED, paged=False, eos=eos)
    fused, _ = _serve(cfg, params, RAGGED, paged=True, steps=6, eos=eos)
    assert fused == seq
    assert any(len(a) < len(b) for a, b in zip(seq, ref)), \
        "EOS never fired mid-stream; the test lost its teeth"


def test_empty_decode_skips_jitted_call(setup):
    """A batch emptied by release-mid-iteration must not pay a dispatch
    (and replica-only instances must not decode their garbage rows)."""
    cfg, params = setup
    eng = _engine(cfg, params, paged_decode=True)
    assert eng.decode() == {} and eng.decode_multi(steps=4) == {}
    assert eng.host_syncs == 0
    src = _engine(cfg, params, paged_decode=True, instance_id=1)
    req = _mk(cfg, 0, 5)
    slot = src.prefill_request(req)
    eng.import_slot(0, src.export_slot(slot), req, as_replica_of=(1, slot))
    assert eng.replica_of and eng.decode() == {}
    assert eng.host_syncs == 0


# ---------------------------------------------------------------------------
# compaction: replica/free slots cost nothing and change nothing
# ---------------------------------------------------------------------------


def test_compacted_batch_matches_full_with_replicas_resident(setup):
    cfg, params = setup

    def run(with_replica):
        src = _engine(cfg, params, instance_id=1)
        eng = _engine(cfg, params, paged_decode=True)
        reqs = [_mk(cfg, i, p, n) for i, (p, n) in
                enumerate([(5, 6), (9, 6)])]
        for r in reqs:
            eng.prefill_request(r)
        if with_replica:
            other = _mk(cfg, 7, 11, 6)
            s = src.prefill_request(other)
            eng.import_slot(eng.free_slots()[0], src.export_slot(s),
                            other, as_replica_of=(1, s))
        while eng.slot_req:
            eng.decode_multi(steps=2)
        return [r.output_tokens for r in reqs]

    assert run(True) == run(False)


def test_sample_slots_invariant_to_batch_composition():
    """Per-slot fold_in keys: the token drawn at a slot is the same
    whether the batch holds every slot or only the active subset."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(jax.random.PRNGKey(4), (6, 40))
    slots = jnp.arange(6, dtype=jnp.int32)
    full = sample_slots(logits, key, slots, temperature=0.8)
    sel = jnp.asarray([1, 4, 5], jnp.int32)
    compact = sample_slots(logits[sel], key, sel, temperature=0.8)
    assert jnp.array_equal(full[sel], compact)


def test_decode_keys_match_sequential_splits():
    key = jax.random.PRNGKey(9)
    k_seq = key
    subs = []
    chain_ref = [key]
    for _ in range(3):
        k_seq, s = jax.random.split(k_seq)
        subs.append(s)
        chain_ref.append(k_seq)
    chain, stacked = decode_keys(key, 3)
    assert all(jnp.array_equal(a, b) for a, b in zip(chain, chain_ref))
    assert jnp.array_equal(stacked, jnp.stack(subs))


def test_fused_eos_key_consumption_matches_sequential(setup):
    """A fused span that EOS ends early must leave the engine key where
    the per-step path would (sequential decode stops splitting once the
    batch empties): the NEXT request's sampled tokens at temperature > 0
    are identical fused-vs-sequential."""
    cfg, params = setup

    def run(steps, eos):
        eng = _engine(cfg, params, paged_decode=True, temperature=0.7,
                      eos_token=eos, kv_capacity=32)
        first = _mk(cfg, 0, 5, 8)
        eng.prefill_request(first)
        while eng.slot_req:
            eng.decode_multi(steps=steps)
        second = _mk(cfg, 1, 7, 8)
        eng.prefill_request(second)
        while eng.slot_req:
            eng.decode_multi(steps=steps)
        return first.output_tokens, second.output_tokens

    probe, _ = run(1, None)
    eos = probe[3]            # first request dies mid-span under fusing
    seq = run(1, eos)
    fused = run(6, eos)
    assert len(seq[0]) == 4, "EOS did not fire early; test lost its teeth"
    assert fused == seq


# ---------------------------------------------------------------------------
# planner fuse gating
# ---------------------------------------------------------------------------


class _Inst:
    def __init__(self, lines, backlog=0, bl=16):
        self._lines, self._backlog, self._bl = lines, backlog, bl

    def request_lines(self):
        return dict(self._lines)

    def prefill_backlog(self):
        return self._backlog

    def block_lines(self):
        return self._bl


class _View:
    def __init__(self, insts, placements=None):
        self._insts, self._pl = insts, placements or {}

    def instances(self):
        return self._insts

    def placements(self):
        return self._pl


def test_planner_fuses_only_unmirrored_idle_decode():
    from repro.scheduling.actions import Decode
    planner = Planner(allow_mixed=False)
    planner.max_fuse_steps = 8
    # clean decode: fuses up to the horizon
    view = _View([_Inst({1: 10, 2: 12})])
    planner.fuse_horizon = 5
    plan = planner.compile([Decode(0)], view)[0]
    # spans floor to powers of two (the live scan's static shape)
    assert plan.steps == 4 and plan.block_lines == 16
    assert plan.lengths == (10, 12)
    # mirror-bound decode keeps per-step sync points
    view = _View([_Inst({1: 10, 2: 12})], placements={1: (0, 1)})
    assert planner.compile([Decode(0)], view)[0].steps == 1
    # prefill backlog: the role may flip next iteration
    view = _View([_Inst({1: 10}, backlog=2)])
    assert planner.compile([Decode(0)], view)[0].steps == 1
    # fusing disabled: seed behavior
    planner.max_fuse_steps = 1
    view = _View([_Inst({1: 10})])
    assert planner.compile([Decode(0)], view)[0].steps == 1


# ---------------------------------------------------------------------------
# repriced cost model
# ---------------------------------------------------------------------------


def test_plan_time_block_granular_and_amortized():
    cfg = get_config("llama2-70b")
    perf = PerfModel(cfg, InstanceSpec(H100, 4))
    # block-granular gather: lines round up to whole blocks
    exact = perf.plan_time(DecodePlan(0, lengths=(200, 300)))
    paged = perf.plan_time(DecodePlan(0, lengths=(200, 300),
                                      block_lines=16))
    assert paged == perf._decode_iter_time((208, 304))
    assert paged > exact
    # fused steps price each iteration at its grown lengths...
    fused = perf.plan_time(DecodePlan(0, lengths=(200, 300),
                                      block_lines=16, steps=4))
    assert fused == pytest.approx(sum(
        perf._decode_iter_time((200 + j, 300 + j), 16) for j in range(4)))
    # ...and amortize the fixed dispatch overhead once per plan
    disp = PerfModel(cfg, InstanceSpec(H100, 4, dispatch_s=50e-6))
    one = disp.plan_time(DecodePlan(0, lengths=(200,), block_lines=16))
    four = disp.plan_time(DecodePlan(0, lengths=(200,), block_lines=16,
                                     steps=4))
    per_tok_1 = one / 1
    per_tok_4 = four / 4
    assert per_tok_4 < per_tok_1
    assert four - 4 * (one - 50e-6) == pytest.approx(50e-6, rel=1e-6)


# ---------------------------------------------------------------------------
# fused serving on the live cluster
# ---------------------------------------------------------------------------


def test_fused_cluster_matches_unfused_tokens(setup):
    cfg, params = setup

    def run(fuse):
        cluster = LiveCluster(cfg, params, n_instances=1, num_slots=4,
                              kv_capacity=32, policy=VLLMScheduler(),
                              fuse_decode_steps=fuse)
        reqs = [_mk(cfg, i, p, n) for i, (p, n) in
                enumerate([(5, 8), (9, 8), (12, 8)])]
        for r in reqs:
            cluster.submit(r)
        done = cluster.run(max_steps=100)
        assert len(done) == len(reqs)
        return ([r.output_tokens for r in reqs], cluster)

    toks_1, c1 = run(1)
    toks_8, c8 = run(8)
    assert toks_8 == toks_1
    # the fused run executed the same number of decode iterations...
    assert c8.stats["decode_steps"] == c1.stats["decode_steps"]
    # ...in fewer dispatches/host syncs (1/plan, not 1/token)
    assert c8.engines[0].host_syncs < c1.engines[0].host_syncs
    # and the iteration clock stayed comparable
    assert c8.now == c1.now
    for a, b in zip(sorted(c1.finished, key=lambda r: r.rid),
                    sorted(c8.finished, key=lambda r: r.rid)):
        assert a.finish_time == b.finish_time


def test_fused_cluster_eos_mid_span_finish_times(setup):
    """A request sampling EOS mid-fused-span must report the iteration
    it really finished, not the end of the fused block."""
    cfg, params = setup

    def run(fuse, eos):
        cluster = LiveCluster(cfg, params, n_instances=1, num_slots=4,
                              kv_capacity=32, policy=VLLMScheduler(),
                              eos_token=eos, fuse_decode_steps=fuse)
        reqs = [_mk(cfg, i, p, 8) for i, p in enumerate([5, 9, 12])]
        for r in reqs:
            cluster.submit(r)
        cluster.run(max_steps=100)
        return [(r.output_tokens, r.finish_time) for r in reqs]

    ref = run(1, None)
    eos = ref[1][0][3]                 # fires mid-stream of request 1
    unfused = run(1, eos)
    fused = run(8, eos)
    assert fused == unfused
    assert any(len(t) < len(r[0]) for (t, _), r in zip(unfused, ref)), \
        "EOS never fired mid-stream; the test lost its teeth"


def test_sim_fused_decode_plans(setup):
    """The sim backend compiles and prices fused DecodePlans when its
    adapter opts in (same knob as LiveCluster.fuse_decode_steps)."""
    from repro.sim import Simulator
    from repro.sim.policies import VLLMPolicy
    from repro.sim.workload import SimRequest

    def run(fuse):
        pol = VLLMPolicy(fuse_decode_steps=fuse)
        pol.planner.trace = []
        perf = PerfModel(get_config("llama2-70b"), InstanceSpec(H100, 4))
        sim = Simulator(pol, perf, n_instances=1, max_batch=8)
        reqs = [SimRequest(rid=i, arrival=0.0, prompt_len=64,
                           decode_len=12) for i in range(3)]
        done = sim.run(list(reqs))
        return reqs, done, pol.planner.trace

    reqs1, done1, _ = run(1)
    reqs8, done8, trace = run(8)
    assert len(done8) == len(done1) == 3
    assert all(r.generated == 12 for r in reqs8)
    fused_steps = [e[4] for e in trace if e[0] == "decode"]
    assert max(fused_steps) > 1, "no fused decode plan was compiled"
    # the span cap: never past the shortest remaining budget
    assert all(s <= 12 for s in fused_steps)
