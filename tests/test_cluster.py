"""AcceLLM real-engine cluster: end-to-end behaviour + the migration
invariant — tokens generated under redundancy/rebalancing must EXACTLY match
a single-engine greedy run of the same request (zero-cost migration means
bit-identical state)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.live import LiveCluster
from repro.serving import InstanceEngine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_cluster(cfg, params, n_instances, num_slots, kv_capacity=128,
                redundancy=True):
    return LiveCluster(cfg, params, n_instances, num_slots, kv_capacity,
                       policy=AcceLLMScheduler(redundancy=redundancy))


def _mk_requests(cfg, n, seed=3):
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = 6 + (i % 5)
        toks = jax.random.randint(jax.random.fold_in(key, i), (1, plen),
                                  0, cfg.vocab_size)
        reqs.append(Request(prompt_len=plen, max_new_tokens=4 + (i % 4),
                            prompt_tokens=toks))
    return reqs


def _single_engine_reference(cfg, params, req):
    eng = InstanceEngine(cfg, params, num_slots=1, kv_capacity=128)
    r = Request(prompt_len=req.prompt_len, max_new_tokens=req.max_new_tokens,
                prompt_tokens=req.prompt_tokens)
    eng.prefill_request(r)
    while r.generated < r.max_new_tokens:
        eng.decode()
    return r.output_tokens


def test_all_requests_finish(setup):
    cfg, params = setup
    cluster = _mk_cluster(cfg, params, n_instances=2, num_slots=6)
    reqs = _mk_requests(cfg, 8)
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=300)
    assert len(done) == 8
    for r in done:
        assert len(r.output_tokens) == r.max_new_tokens
        assert r.ttft() is not None and r.jct() is not None
        assert r.ttft() <= r.jct()


def test_migration_preserves_greedy_tokens(setup):
    """The flagship invariant: redundancy-based migration is lossless."""
    cfg, params = setup
    reqs = _mk_requests(cfg, 6, seed=11)
    expected = {r.rid: _single_engine_reference(cfg, params, r) for r in reqs}
    cluster = _mk_cluster(cfg, params, n_instances=2, num_slots=8)
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=300)
    assert len(done) == len(reqs)
    assert cluster.stats["replica_promotions"] > 0, \
        "test should actually exercise migration"
    for r in done:
        assert r.output_tokens == expected[r.rid], (
            f"rid {r.rid}: migrated tokens diverge from single-engine greedy")


def test_no_redundancy_mode(setup):
    cfg, params = setup
    cluster = _mk_cluster(cfg, params, n_instances=2, num_slots=6,
                          redundancy=False)
    reqs = _mk_requests(cfg, 4)
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=300)
    assert len(done) == 4
    assert cluster.stats["mirror_syncs"] == 0
    assert cluster.stats["replica_promotions"] == 0


def test_four_instances_two_pairs(setup):
    cfg, params = setup
    cluster = _mk_cluster(cfg, params, n_instances=4, num_slots=4)
    reqs = _mk_requests(cfg, 10, seed=5)
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=400)
    assert len(done) == 10
    assert cluster.stats["prefills"] == 10
    # every placement names a live engine slot on one of the two pairs
    assert all(pl.primary[0] < 4 for pl in cluster.placements.values())


def test_slot_accounting_invariants(setup):
    """No slot is ever both primary and replica; bookkeeping stays closed."""
    cfg, params = setup
    cluster = _mk_cluster(cfg, params, n_instances=2, num_slots=5)
    reqs = _mk_requests(cfg, 7, seed=9)
    for r in reqs:
        cluster.submit(r)
    steps = 0
    while cluster.pending() and steps < 300:
        cluster.step()
        for eng in cluster.engines:
            overlap = set(eng.slot_req) & set(eng.replica_of)
            assert not overlap, f"slot is both primary and replica: {overlap}"
        for rid, pl in cluster.placements.items():
            inst, slot = pl.primary
            assert cluster.engines[inst].slot_req[slot].rid == rid
            if pl.replica is not None:
                r_inst, r_slot = pl.replica
                assert cluster.engines[r_inst].replica_of.get(r_slot) \
                    is not None
        steps += 1
