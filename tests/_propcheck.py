"""Property-testing front-end: real hypothesis when installed (the
``[test]`` extra in pyproject.toml), else a minimal uniform-random
fallback so the suite still collects and runs the same properties.

The fallback supports exactly the subset this repo uses: ``given``,
``settings(max_examples=, deadline=)`` and the ``floats`` / ``integers``
/ ``booleans`` / ``sampled_from`` / ``tuples`` / ``lists`` strategies
plus ``.map``.  Draws are seeded, so failures reproduce.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value, max_value, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_):
            return _Strategy(
                lambda rng: [elements.example(rng)
                             for _ in range(rng.randint(min_size, max_size))])

    def settings(max_examples=100, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # deliberately no functools.wraps: pytest must see a
            # zero-argument signature, not the strategy parameters
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(fn, "_max_examples", 50)):
                    drawn = tuple(s.example(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
