"""State-size accounting vs actual engine state sizes."""
import jax
import pytest

from repro.configs import get_config, list_archs
from repro.core.kvbytes import (bytes_per_token, decode_read_bytes,
                                fixed_state_bytes, state_bytes_at)


def test_mla_latent_much_smaller_than_gqa():
    """DeepSeek MLA's redundant copy is ~an order cheaper per layer than a
    comparable dense GQA cache (the beyond-paper synergy from DESIGN.md §4)."""
    ds = get_config("deepseek-v3-671b")
    per_layer_mla = bytes_per_token(ds) / sum(
        1 for b in ds.block_pattern if b == "attn")
    # hypothetical: full 128-head KV at head_dim 128
    full = 2 * 128 * 128 * 2
    assert per_layer_mla < full / 10


def test_ssm_state_is_length_independent():
    x = get_config("xlstm-1.3b")
    assert bytes_per_token(x) == 0
    assert state_bytes_at(x, 100) == state_bytes_at(x, 100_000)
    assert fixed_state_bytes(x) > 0


def test_hybrid_mixes_both():
    j = get_config("jamba-1.5-large-398b")
    assert bytes_per_token(j) > 0
    assert fixed_state_bytes(j) > 0
    # only 9 of 72 layers are attention
    dense_like = 2 * j.num_kv_heads * j.head_dim * 2 * 72
    assert bytes_per_token(j) == dense_like * 9 / 72


@pytest.mark.parametrize("arch", list_archs())
def test_monotone_in_length(arch):
    cfg = get_config(arch)
    assert state_bytes_at(cfg, 2000) >= state_bytes_at(cfg, 1000)
    assert decode_read_bytes(cfg, 500) == state_bytes_at(cfg, 500)
