"""Config registry + parameter-count sanity."""
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, list_archs

EXPECTED_PARAMS_B = {
    "phi3-medium-14b": (13, 16),
    "internvl2-1b": (0.3, 0.8),
    "minicpm-2b": (2.0, 3.3),
    "seamless-m4t-large-v2": (1.2, 2.5),
    "starcoder2-3b": (2.7, 3.7),
    "arctic-480b": (430, 520),
    "xlstm-1.3b": (0.9, 2.2),
    "deepseek-v3-671b": (620, 720),
    "starcoder2-7b": (6.5, 8.2),
    "jamba-1.5-large-398b": (350, 440),
    "llama2-70b": (65, 72),
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(list_archs(include_extra=True)) == 11
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


@pytest.mark.parametrize("arch", list_archs(include_extra=True))
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.param_count() / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.1f}B outside [{lo},{hi}]B"


@pytest.mark.parametrize("arch", list_archs(include_extra=True))
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4
    # reduced keeps one block of each distinct kind
    assert set(r.block_pattern) <= set(get_config(arch).block_pattern)


@pytest.mark.parametrize("arch", list_archs())
def test_family_matches_blocks(arch):
    cfg = get_config(arch)
    kinds = set(cfg.block_pattern)
    if cfg.family == "ssm":
        assert "attn" not in kinds
    if cfg.family == "hybrid":
        assert {"attn", "mamba"} <= kinds
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        assert kinds == {"attn"}


def test_moe_active_params_smaller():
    for arch in ("arctic-480b", "deepseek-v3-671b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert cfg.param_count(active_only=True) < 0.5 * cfg.param_count()
