"""Mesh serving (repro.meshserve): tensor-parallel paged decode with
device-to-device redundancy collectives.

Runs on the forced 8-device CPU pod (conftest sets
``--xla_force_host_platform_device_count=8``); the ``mesh8`` fixture
skips everything here when the platform ignored the flag.

Covered invariants:
* model-axis-sharded batched prefill + fused paged decode produce tokens
  bit-identical to a single-device engine (temperature-0 argmax);
* MirrorSync / StreamState between mesh slices move KV as device-to-
  device collectives — the transfer-guard counter proves no host
  round-trip on the serving fast path — and account the SAME bytes as
  the host-copy path and the simulator's ``LineCosts`` pricing;
* a heterogeneous pod (H100-class wide slice + 910B2-class narrow
  slice) drives the unchanged policy kernel to identical decisions on
  the live executor and the simulator adapter (golden trace).
"""
import jax
import pytest

from repro.configs import get_config
from repro.kvstore import LineCosts
from repro.meshserve import STATS, MeshError, MeshPlacement, carve_slices
from repro.models import init_params
from repro.scheduling.accellm import AcceLLMScheduler
from repro.scheduling.live import LiveCluster
from repro.serving import InstanceEngine, Request
from repro.sim import (ASCEND_910B2, H100, AcceLLMPolicy, InstanceSpec,
                       PerfModel, Simulator)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_requests(cfg, n, seed=3, steps=5):
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        plen = 6 + (i % 5)
        toks = jax.random.randint(jax.random.fold_in(key, i), (1, plen),
                                  0, cfg.vocab_size)
        reqs.append(Request(prompt_len=plen, max_new_tokens=steps,
                            prompt_tokens=toks))
    return reqs


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------


def test_carve_slices_disjoint(mesh8):
    slices = carve_slices(2, n_instances=3)
    assert [sl.tp for sl in slices] == [2, 2, 2]
    seen = set()
    for sl in slices:
        devs = set(sl.devices)
        assert not (devs & seen), "slices must be disjoint"
        seen |= devs
    # heterogeneous widths carve consecutively too
    wide, narrow = carve_slices([4, 2])
    assert wide.tp == 4 and narrow.tp == 2
    assert not (set(wide.devices) & set(narrow.devices))
    with pytest.raises(MeshError):
        carve_slices(4, n_instances=3)       # 12 devices > 8


def test_model_axis_gating(mesh8, setup):
    cfg, _ = setup
    two, four = carve_slices([2, 4])
    # reduced starcoder2 has 4 query heads: both widths divide
    assert two.model_axis_for(cfg) == "model"
    assert four.model_axis_for(cfg) == "model"
    (three,) = carve_slices(3, n_instances=1)
    assert three.model_axis_for(cfg) is None   # 4 % 3 != 0: replicate


# ---------------------------------------------------------------------------
# bit-identity: sharded prefill + fused paged decode vs single device
# ---------------------------------------------------------------------------


def _generate(cfg, params, mesh, steps=5, fused=True):
    eng = InstanceEngine(cfg, params, num_slots=4, kv_capacity=64,
                         temperature=0.0, mesh=mesh)
    reqs = _mk_requests(cfg, 3, steps=steps)
    for r in reqs:
        eng.prefill_request(r)
    if fused and eng.supports_paged_decode:
        eng.decode_multi(steps=steps - 1)
    else:
        for _ in range(steps - 1):
            eng.decode()
    return [list(r.output_tokens) for r in reqs]


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_tokens_bit_identical(mesh8, setup, tp):
    cfg, params = setup
    base = _generate(cfg, params, mesh=None)
    (sl,) = carve_slices(tp, n_instances=1)
    sharded = _generate(cfg, params, mesh=sl)
    assert sharded == base, (
        f"tp={tp} sharded decode diverged from single-device greedy")


def test_indivisible_width_replicates_and_matches(mesh8, setup):
    cfg, params = setup
    base = _generate(cfg, params, mesh=None)
    (sl,) = carve_slices(3, n_instances=1)   # 4 heads % 3: replicated
    assert _generate(cfg, params, mesh=sl) == base


# ---------------------------------------------------------------------------
# collectives: device-to-device, no host round-trip, exact byte accounting
# ---------------------------------------------------------------------------


def test_cross_slice_mirror_is_d2d_and_priced_like_sim(mesh8, setup):
    cfg, params = setup
    a_sl, b_sl = carve_slices(2, n_instances=2)
    assert not (set(a_sl.devices) & set(b_sl.devices))
    a = InstanceEngine(cfg, params, num_slots=2, kv_capacity=64,
                       temperature=0.0, mesh=a_sl)
    b = InstanceEngine(cfg, params, num_slots=2, kv_capacity=64,
                       temperature=0.0, mesh=b_sl)
    (req,) = _mk_requests(cfg, 1, steps=4)
    slot = a.prefill_request(req)

    STATS.reset()
    # replica placement: per-layer streamed export lands on b's slice
    chunks, length, last, lines = a.export_stream(slot)
    b_slot = b.free_slots()[0]
    b.import_stream(b_slot, chunks, length, last, lines, req,
                    as_replica_of=(0, slot))
    assert STATS.d2d_copies > 0, "stream must cross slices on-device"
    assert STATS.host_copies == 0, "host round-trip on the stream path"

    # decode on the primary, then delta-mirror the new lines to b
    a.decode()
    from_line = b.store.lines(req.rid)
    STATS.reset()
    moved = b.sync_replica_from(a, slot, b_slot)
    assert STATS.d2d_copies > 0, "mirror must cross slices on-device"
    assert STATS.host_copies == 0, "host round-trip on the mirror path"

    # byte accounting: the live ledger's answer IS the simulator's
    delta = a.store.lines(req.rid) - from_line
    costs = LineCosts.from_config(cfg)
    assert moved == pytest.approx(costs.mirror_bytes(delta))
    sim_perf = PerfModel(cfg, InstanceSpec(H100, 2))
    assert moved == pytest.approx(
        sim_perf.line_costs.mirror_bytes(delta))


def test_mesh_cluster_byte_accounting_matches_host_copy(mesh8, setup):
    """The same trace through an unsharded pod and a mesh pod books
    identical mirror/stream bytes — the collective transport changes the
    wire, never the ledger."""
    cfg, params = setup

    def run(mesh):
        cluster = LiveCluster(cfg, params, n_instances=2, num_slots=6,
                              kv_capacity=64, policy=AcceLLMScheduler(),
                              mesh=mesh)
        for r in _mk_requests(cfg, 6, seed=11):
            cluster.submit(r)
        done = cluster.run(max_steps=200)
        assert len(done) == 6
        return cluster.stats

    host = run(None)
    STATS.reset()
    mesh = run(MeshPlacement.carve(2, tp=2))
    assert STATS.d2d_copies > 0 and STATS.host_copies == 0
    for key in ("mirror_syncs", "mirror_bytes", "stream_bytes",
                "replica_promotions", "prefills", "decode_steps"):
        assert mesh[key] == host[key], (
            f"{key}: mesh pod {mesh[key]} != host-copy pod {host[key]}")


# ---------------------------------------------------------------------------
# golden trace: heterogeneous mesh pod, live vs sim
# ---------------------------------------------------------------------------

_TRACE = [("arrive", 8, 4), ("tick",), ("arrive", 12, 6), ("arrive", 6, 5),
          ("tick",), ("arrive", 10, 3), ("tick",), ("arrive", 7, 6),
          ("arrive", 9, 4), ("tick",)]

_HETERO_SPECS = (InstanceSpec(H100, 4, intra_link_gbps=H100.link_gbps),
                 InstanceSpec(ASCEND_910B2, 2,
                              intra_link_gbps=ASCEND_910B2.link_gbps))


def _run_live_trace(cfg, params, kernel):
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=kernel,
                          mesh=MeshPlacement.carve(2, specs=_HETERO_SPECS))
    assert [sl.tp for sl in cluster.mesh.slices] == [4, 2]
    key = jax.random.PRNGKey(7)
    rids = []
    for i, op in enumerate(_TRACE):
        if op[0] == "arrive":
            plen, dlen = op[1], op[2]
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=jax.random.randint(
                              jax.random.fold_in(key, i), (1, plen), 0,
                              cfg.vocab_size))
            rids.append(req.rid)
            cluster.submit(req)
        cluster.step()
    steps = 0
    while cluster.pending() and steps < 50:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    return cluster, rids, steps


def _run_sim_trace(cfg, rids, extra_ticks):
    """Same lock-step adapter drive as tests/test_scheduling.py, but each
    SimInstance is priced on its own heterogeneous slice spec."""
    from repro.sim.cluster import SimRequest

    kernel = AcceLLMScheduler()
    kernel.trace = []
    perfs = [PerfModel(cfg, s) for s in _HETERO_SPECS]
    sim = Simulator(AcceLLMPolicy(kernel=kernel), perfs, n_instances=2)
    sim.kick = lambda inst: None          # event mechanics not under test
    pol = sim.policy
    views = list(pol.view().instances())
    assert views[0].spec() is _HETERO_SPECS[0]
    assert views[1].spec() is _HETERO_SPECS[1]

    def tick(skip_iid=None):
        finished = {}
        for inst in sim.instances:
            if inst.iid == skip_iid:
                continue
            done_here = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done_here.append(r)
            finished[inst.iid] = done_here
        for inst in sim.instances:
            if inst.iid in finished:
                pol.on_decode_done(inst, finished[inst.iid])

    arrivals = iter(rids)
    for op in _TRACE:
        skip = None
        if op[0] == "arrive":
            r = SimRequest(rid=next(arrivals), arrival=0.0,
                           prompt_len=op[1], decode_len=op[2])
            inst = pol.route(r)
            r.generated = 1               # the prefill's first token
            pol.on_prefill_done(inst, [r])
            skip = inst.iid
        tick(skip_iid=skip)
    for _ in range(extra_ticks):
        tick()
    return kernel.trace


def test_golden_trace_hetero_mesh_live_vs_sim(mesh8, setup):
    cfg, params = setup
    live_kernel = AcceLLMScheduler()
    live_kernel.trace = []
    cluster, rids, extra = _run_live_trace(cfg, params, live_kernel)
    sim_trace = _run_sim_trace(cfg, rids, extra)
    assert live_kernel.trace == sim_trace, (
        "shared kernel diverged between the hetero mesh pod and the sim:\n"
        f"live: {live_kernel.trace}\nsim:  {sim_trace}")
    kinds = {entry[0] for entry in live_kernel.trace}
    assert {"route", "place"} <= kinds
    # the live views expose the same hardware identity the sim priced
    assert cluster.engines[0].mesh.tp == 4
    assert cluster.engines[1].mesh.tp == 2
    from repro.scheduling.live import LiveInstanceView
    assert LiveInstanceView(cluster, 0).spec() is _HETERO_SPECS[0]
    assert LiveInstanceView(cluster, 1).spec() is _HETERO_SPECS[1]
    # redundancy ran across slice widths and booked real mirror traffic
    assert cluster.stats["mirror_syncs"] > 0
    assert cluster.stats["mirror_bytes"] > 0
