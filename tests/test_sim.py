"""Simulator: conservation properties + reproduction of the paper's
qualitative claims (the quantitative reproduction lives in benchmarks/ and
EXPERIMENTS.md)."""
import copy

import pytest
from _propcheck import given, settings, st

from repro.configs import get_config
from repro.sim import (AcceLLMPolicy, H100, InstanceSpec, PerfModel,
                       Simulator, SplitwisePolicy, VLLMPolicy, make_workload,
                       summarize)

CFG = get_config("llama2-70b")
INST = InstanceSpec(H100, 4)


def _run(policy, reqs, n=4, horizon=600.0):
    sim = Simulator(policy, PerfModel(CFG, INST), n_instances=n)
    done = sim.run([copy.deepcopy(r) for r in reqs], horizon=horizon)
    return sim, done


@pytest.mark.parametrize("mk", [VLLMPolicy, lambda: SplitwisePolicy(1),
                                AcceLLMPolicy])
def test_all_requests_complete(mk):
    reqs = make_workload("mixed", rate=5.0, duration=20.0, seed=0)
    sim, done = _run(mk(), reqs)
    assert len(done) == len(reqs)
    for r in done:
        assert r.generated == r.decode_len
        assert r.first_token_time >= r.arrival
        assert r.finish_time >= r.first_token_time
        assert len(r.token_times) == r.decode_len


def test_token_times_monotone():
    reqs = make_workload("light", rate=8.0, duration=15.0, seed=1)
    for mk in (VLLMPolicy, lambda: SplitwisePolicy(1), AcceLLMPolicy):
        _, done = _run(mk(), reqs)
        for r in done:
            assert all(b >= a for a, b in zip(r.token_times,
                                              r.token_times[1:]))


def test_sarathi_bounds_tbt_spikes():
    """Sarathi chunked prefill bounds the vLLM co-batch spike (its §2 role)
    but AcceLLM still beats it (no co-batching at all)."""
    from repro.sim import SarathiPolicy
    reqs = make_workload("mixed", rate=10.0, duration=20.0, seed=6)
    _, d_v = _run(VLLMPolicy(), reqs)
    _, d_s = _run(SarathiPolicy(512), reqs)
    _, d_a = _run(AcceLLMPolicy(), reqs)
    assert len(d_s) == len(reqs)
    v = summarize(d_v, 4, 600.0)
    s = summarize(d_s, 4, 600.0)
    a = summarize(d_a, 4, 600.0)
    assert s.tbt_worst < v.tbt_worst
    assert a.tbt_worst <= s.tbt_worst


def test_paper_claim_worst_tbt(paper_rate=10.0):
    """Fig. 16: vLLM co-batching spikes worst-case TBT; AcceLLM stays flat."""
    reqs = make_workload("mixed", rate=paper_rate, duration=30.0, seed=2)
    _, d_v = _run(VLLMPolicy(), reqs)
    _, d_a = _run(AcceLLMPolicy(), reqs)
    s_v = summarize(d_v, 4, 30.0)
    s_a = summarize(d_a, 4, 30.0)
    assert s_a.tbt_worst < 0.5 * s_v.tbt_worst, (
        f"AcceLLM worst TBT {s_a.tbt_worst} should be far below vLLM "
        f"{s_v.tbt_worst}")


def test_paper_claim_jct_at_saturation():
    """Figs 11-12(d): near/above Splitwise saturation AcceLLM's dynamic
    instances cut JCT (paper: up to ~30%; stronger when prefill queues)."""
    reqs = make_workload("mixed", rate=40.0, duration=40.0, seed=3)
    _, d_s = _run(SplitwisePolicy(1), reqs)
    _, d_a = _run(AcceLLMPolicy(), reqs)
    s_s = summarize(d_s, 4, 600.0)
    s_a = summarize(d_a, 4, 600.0)
    assert s_a.jct_p50 < 0.8 * s_s.jct_p50
    assert s_a.ttft_p50 < s_s.ttft_p50


def test_redundancy_memory_overhead_small():
    """Fig. 9: AcceLLM needs only a few GB extra per instance."""
    reqs = make_workload("mixed", rate=8.0, duration=30.0, seed=4)
    sim_a, _ = _run(AcceLLMPolicy(), reqs)
    sim_s, _ = _run(SplitwisePolicy(1), reqs)
    peak_a = max(i.peak_state_bytes for i in sim_a.instances)
    peak_s = max(i.peak_state_bytes for i in sim_s.instances)
    extra_gb = (peak_a - peak_s) / 1e9
    assert extra_gb < 10.0, f"redundancy overhead {extra_gb:.1f}GB too large"


@given(st.sampled_from(["light", "mixed", "heavy"]),
       st.floats(min_value=1.0, max_value=20.0),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=10, deadline=None)
def test_accellm_conservation_property(workload, rate, seed):
    reqs = make_workload(workload, rate=rate, duration=10.0, seed=seed)
    sim, done = _run(AcceLLMPolicy(), reqs, horizon=2000.0)
    assert len(done) + len(sim.dropped) == len(reqs)
    assert len(sim.dropped) == 0
    # no request is resident on two instances' decode batches
    rids = [rid for inst in sim.instances for rid in inst.decode_batch]
    assert len(rids) == len(set(rids))
