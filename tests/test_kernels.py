"""Pallas kernel validation: interpret-mode execution of the real kernel
bodies vs the pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas

FLASH_SHAPES = [
    # (B, S, H, KVH, hd)
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 8, 1, 128),     # MQA, MXU-aligned head
    (2, 128, 16, 4, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return 2e-6 if dtype == jnp.float32 else 2e-2


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("window", [None, 64])
def test_flash_kernel_sweep(shape, dtype, window, rng_key):
    B, S, H, KVH, hd = shape
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, KVH, hd), dtype)
    v = jax.random.normal(k3, (B, S, KVH, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    err = jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)).max()
    assert float(err) < _tol(dtype), f"{shape} {dtype} w={window}: {err}"


def test_flash_non_causal(rng_key):
    B, S, H, hd = 1, 128, 4, 64
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    out = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.abs(out - exp).max()) < 2e-6


DECODE_SHAPES = [
    # (B, H, KVH, hd, W)
    (1, 4, 4, 64, 256),
    (2, 8, 2, 64, 512),
    (3, 8, 1, 128, 256),
    (2, 16, 4, 128, 512),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_kernel_sweep(shape, dtype, rng_key):
    B, H, KVH, hd, W = shape
    k1, k2, k3, k4 = jax.random.split(rng_key, 4)
    q = jax.random.normal(k1, (B, 1, H, hd), dtype)
    kc = jax.random.normal(k2, (B, W, KVH, hd), dtype)
    vc = jax.random.normal(k3, (B, W, KVH, hd), dtype)
    lengths = jax.random.randint(k4, (B,), 1, W + 1)
    out = decode_attention_pallas(q, kc, vc, lengths, block_k=128,
                                  interpret=True)
    exp = ref.decode_attention_ref(q, kc, vc, lengths)
    err = jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)).max()
    assert float(err) < _tol(dtype)


def test_decode_partial_lengths_masking(rng_key):
    """Slots past `length` must not affect output even if filled with junk."""
    B, H, KVH, hd, W = 1, 4, 2, 64, 256
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    kc = jax.random.normal(k2, (B, W, KVH, hd))
    vc = jax.random.normal(k3, (B, W, KVH, hd))
    L = 100
    lengths = jnp.array([L], jnp.int32)
    out1 = decode_attention_pallas(q, kc, vc, lengths, block_k=64,
                                   interpret=True)
    kc2 = kc.at[:, L:].set(1e4)
    vc2 = vc.at[:, L:].set(-1e4)
    out2 = decode_attention_pallas(q, kc2, vc2, lengths, block_k=64,
                                   interpret=True)
    assert float(jnp.abs(out1 - out2).max()) < 1e-6


# ---------------------------------------------------------------------------
# Paged decode attention (KV gathered through block tables)
# ---------------------------------------------------------------------------

from repro.kernels.decode_attention import (paged_decode_attention_pallas,
                                            paged_decode_attention_ref)

PAGED_SHAPES = [
    # (B, H, KVH, hd, W, block_lines)
    (1, 4, 4, 64, 256, 64),
    (2, 8, 2, 64, 512, 128),
    (3, 8, 1, 128, 256, 64),
]


def _scatter_to_pool(cache, tables, block_lines, num_blocks):
    """Place each request's contiguous cache rows into the pool blocks
    its table names (inverse of the kernel's gather)."""
    B, W = cache.shape[:2]
    pool = jnp.zeros((num_blocks, block_lines) + cache.shape[2:],
                     cache.dtype)
    for b in range(B):
        for i, blk in enumerate(tables[b]):
            rows = cache[b, i * block_lines:(i + 1) * block_lines]
            pool = pool.at[int(blk)].set(rows)
    return pool


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_paged_decode_kernel_matches_dense(shape, dtype, rng_key):
    """The paged kernel over a scattered pool == the dense kernel over
    the contiguous caches the block tables describe."""
    B, H, KVH, hd, W, bl = shape
    nb = W // bl
    k1, k2, k3, k4, k5 = jax.random.split(rng_key, 5)
    q = jax.random.normal(k1, (B, 1, H, hd), dtype)
    kc = jax.random.normal(k2, (B, W, KVH, hd), dtype)
    vc = jax.random.normal(k3, (B, W, KVH, hd), dtype)
    lengths = jax.random.randint(k4, (B,), 1, W + 1)
    # a non-trivial physical placement: shuffled pool twice as large
    num_blocks = 2 * B * nb
    tables = jax.random.permutation(k5, num_blocks)[: B * nb]
    tables = tables.reshape(B, nb).astype(jnp.int32)
    k_pool = _scatter_to_pool(kc, tables, bl, num_blocks)
    v_pool = _scatter_to_pool(vc, tables, bl, num_blocks)
    out = paged_decode_attention_pallas(q, k_pool, v_pool, tables, lengths,
                                        interpret=True)
    exp = decode_attention_pallas(q, kc, vc, lengths, block_k=bl,
                                  interpret=True)
    err = jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)).max()
    assert float(err) < _tol(dtype), f"{shape} {dtype}: {err}"
    # and the jnp oracle agrees
    oracle = paged_decode_attention_ref(q, k_pool, v_pool, tables, lengths)
    err = jnp.abs(out.astype(jnp.float32)
                  - oracle.astype(jnp.float32)).max()
    assert float(err) < max(_tol(dtype), 2e-5)


def test_paged_kernel_reads_store_block_tables(rng_key):
    """End-to-end with the live store: attention over a PagedStore leaf
    through its real (slot-affine) block tables matches the dense view."""
    import numpy as np
    from repro.configs import get_config
    from repro.kvstore import PagedStore
    cfg = get_config("starcoder2-3b").reduced()
    store = PagedStore(cfg, num_slots=4, kv_capacity=64, block_lines=16)
    rids, slots = [11, 22], [1, 3]
    lengths = [20, 37]
    for rid, slot, n in zip(rids, slots, lengths):
        store.alloc(rid, slot, lines=n)
    # one attention leaf, repeat index 0: (B, W, KVH, hd)
    i, pj, key, kind = next(p for p in store._paths if p[3] == "line")
    leaf = store.state["layers"][i][pj][key][0]
    B, W, KVH, hd = leaf.shape
    k1, k2, k3 = jax.random.split(rng_key, 3)
    kc = jax.random.normal(k1, leaf.shape)
    vc = jax.random.normal(k2, leaf.shape)
    H = cfg.num_heads
    q = jax.random.normal(k3, (B, 1, H, cfg.head_dim))
    pool_k, pool_v = store.pool_view(kc), store.pool_view(vc)
    nb = store.line_blocks_per_slot
    tables = np.zeros((B, nb), np.int32)
    lens = np.zeros((B,), np.int32)
    for rid, slot, n in zip(rids, slots, lengths):
        t = store.line_block_table(rid)
        tables[slot, :len(t)] = t
        lens[slot] = n
    out = paged_decode_attention_pallas(q, pool_k, pool_v,
                                        jnp.asarray(tables),
                                        jnp.asarray(lens), interpret=True)
    exp = decode_attention_pallas(q, kc, vc, jnp.asarray(lens), block_k=16,
                                  interpret=True)
    # only rows of slots that hold requests are meaningful
    for slot in slots:
        err = jnp.abs(out[slot] - exp[slot]).max()
        assert float(err) < 2e-6
