"""Pallas kernel validation: interpret-mode execution of the real kernel
bodies vs the pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas

FLASH_SHAPES = [
    # (B, S, H, KVH, hd)
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 8, 1, 128),     # MQA, MXU-aligned head
    (2, 128, 16, 4, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return 2e-6 if dtype == jnp.float32 else 2e-2


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("window", [None, 64])
def test_flash_kernel_sweep(shape, dtype, window, rng_key):
    B, S, H, KVH, hd = shape
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, KVH, hd), dtype)
    v = jax.random.normal(k3, (B, S, KVH, hd), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    err = jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)).max()
    assert float(err) < _tol(dtype), f"{shape} {dtype} w={window}: {err}"


def test_flash_non_causal(rng_key):
    B, S, H, hd = 1, 128, 4, 64
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    out = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    assert float(jnp.abs(out - exp).max()) < 2e-6


DECODE_SHAPES = [
    # (B, H, KVH, hd, W)
    (1, 4, 4, 64, 256),
    (2, 8, 2, 64, 512),
    (3, 8, 1, 128, 256),
    (2, 16, 4, 128, 512),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_kernel_sweep(shape, dtype, rng_key):
    B, H, KVH, hd, W = shape
    k1, k2, k3, k4 = jax.random.split(rng_key, 4)
    q = jax.random.normal(k1, (B, 1, H, hd), dtype)
    kc = jax.random.normal(k2, (B, W, KVH, hd), dtype)
    vc = jax.random.normal(k3, (B, W, KVH, hd), dtype)
    lengths = jax.random.randint(k4, (B,), 1, W + 1)
    out = decode_attention_pallas(q, kc, vc, lengths, block_k=128,
                                  interpret=True)
    exp = ref.decode_attention_ref(q, kc, vc, lengths)
    err = jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)).max()
    assert float(err) < _tol(dtype)


def test_decode_partial_lengths_masking(rng_key):
    """Slots past `length` must not affect output even if filled with junk."""
    B, H, KVH, hd, W = 1, 4, 2, 64, 256
    k1, k2, k3 = jax.random.split(rng_key, 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    kc = jax.random.normal(k2, (B, W, KVH, hd))
    vc = jax.random.normal(k3, (B, W, KVH, hd))
    L = 100
    lengths = jnp.array([L], jnp.int32)
    out1 = decode_attention_pallas(q, kc, vc, lengths, block_k=64,
                                   interpret=True)
    kc2 = kc.at[:, L:].set(1e4)
    vc2 = vc.at[:, L:].set(-1e4)
    out2 = decode_attention_pallas(q, kc2, vc2, lengths, block_k=64,
                                   interpret=True)
    assert float(jnp.abs(out1 - out2).max()) < 1e-6
