"""The fleet layer (repro.fleet): fault injection, replica failover and
warm autoscaling — one deterministic event schedule, one failover
contract, two executors.

The load-bearing check mirrors test_scheduling's golden trace: the same
arrival script with a mid-serve kill and a warm rejoin must produce the
IDENTICAL kernel trace (route/place/warm/rebalance) AND the identical
fleet-controller trace (kill/promote/requeue/drop_replica/join) whether
the fleet events hit the live-engine executor or the simulator adapter.
"""
import heapq

import jax
import pytest

from repro.configs import get_config
from repro.fleet import (Drain, FixedFleet, FleetController, JoinInstance,
                         KillInstance, PoissonFailures, load_fleet_trace,
                         reset_for_reprefill, rollback_tokens,
                         save_fleet_trace)
from repro.models import init_params
from repro.scheduling import (AcceLLMScheduler, LiveCluster, MirrorSync,
                              PromoteReplica)
from repro.serving import Request
from repro.sim import (H100, InstanceSpec, PerfModel, Simulator, SimRequest,
                       make_workload, summarize)
from repro.sim.policies import AcceLLMPolicy, SplitwisePolicy, VLLMPolicy
from repro.workloads import SLO, slo_summary


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _perf(cfg=None):
    return PerfModel(cfg or get_config("llama2-70b"), InstanceSpec(H100, 4))


# ---------------------------------------------------------------------------
# schedules: deterministic streams + JSONL round-trip
# ---------------------------------------------------------------------------


def test_fixed_fleet_stream_is_time_sorted():
    sched = FixedFleet((JoinInstance(9.0, 1), KillInstance(3.0, 1),
                        Drain(3.0, 0)))
    evs = sched.stream(seed=0)
    assert [e.t for e in evs] == [3.0, 3.0, 9.0]
    # stable: same-instant events keep emission order
    assert isinstance(evs[0], KillInstance) and isinstance(evs[1], Drain)
    # the stream is independent of the seed (nothing is random)
    assert sched.stream(seed=7) == evs


def test_poisson_failures_seeded_and_bounded():
    sched = PoissonFailures(mtbf=5.0, duration=100.0, n_instances=4,
                            recovery=2.0)
    a, b = sched.stream(seed=0), sched.stream(seed=0)
    assert a == b, "same seed must replay the identical failure stream"
    assert a != sched.stream(seed=1)
    kills = [e for e in a if isinstance(e, KillInstance)]
    joins = [e for e in a if isinstance(e, JoinInstance)]
    assert kills, "mtbf=5 over 100 units must produce failures"
    assert all(0.0 < e.t < 100.0 for e in kills)
    assert all(0 <= e.instance < 4 for e in kills)
    # each kill is followed by replacement hardware at the same rank
    assert len(joins) == len(kills)
    by_t = sorted(a, key=lambda e: e.t)
    assert [e.t for e in by_t] == [e.t for e in a], "stream() sorts"
    # no recovery -> kills only
    dark = PoissonFailures(mtbf=5.0, duration=100.0, n_instances=4)
    assert all(isinstance(e, KillInstance) for e in dark.stream(seed=0))


def test_fleet_trace_jsonl_round_trip(tmp_path):
    events = [KillInstance(1.5, 2), JoinInstance(4.0, None),
              JoinInstance(5.0, 2), Drain(9.0, 0)]
    path = tmp_path / "fleet.jsonl"
    assert save_fleet_trace(path, events) == 4
    loaded = load_fleet_trace(path)
    assert isinstance(loaded, FixedFleet)
    assert loaded.stream(seed=0) == events
    # a kill without an instance is not a valid record
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"t": 1.0, "event": "kill"}\n')
    with pytest.raises(ValueError):
        load_fleet_trace(bad)


def test_controller_paces_and_drains():
    ctrl = FleetController(FixedFleet((KillInstance(2.0, 0),
                                       JoinInstance(5.0, 0),
                                       Drain(9.0, 1))))
    assert ctrl.next_time() == 2.0
    assert ctrl.due(1.0) == []
    due = ctrl.due(5.0)
    assert [e.t for e in due] == [2.0, 5.0]
    assert not ctrl.exhausted() and ctrl.next_time() == 9.0
    rest = ctrl.drain_all()          # event-heap executors take the tail
    assert [e.t for e in rest] == [9.0]
    assert ctrl.exhausted() and ctrl.due(100.0) == []


# ---------------------------------------------------------------------------
# the failover contract (shared decision, tested through the sim views)
# ---------------------------------------------------------------------------


def _resident(sim, pol, rid, primary, replica, prompt=16, decode=8, gen=3):
    r = SimRequest(rid=rid, arrival=0.0, prompt_len=prompt, decode_len=decode)
    r.generated = gen
    sim.instances[primary].decode_batch[rid] = r
    if replica is not None:
        sim.instances[replica].replicas[rid] = r
    pol.placement[rid] = (primary, replica)
    return r


def test_plan_failover_contract():
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2)
    pol = sim.policy
    _resident(sim, pol, 7, primary=1, replica=0)   # promoted
    _resident(sim, pol, 3, primary=1, replica=None)  # truly lost
    _resident(sim, pol, 5, primary=0, replica=1)   # orphaned replica
    plan = FleetController().plan_failover(pol.view(), dead=1)
    assert plan.dead == 1
    assert [p.rid for p in plan.promotions] == [7]
    assert plan.promotions[0].dst == 0
    assert plan.promotions[0].lost_lines == 0       # replica is current
    assert plan.requeues == [3]
    assert plan.dropped_replicas == [5]


def test_plan_failover_skips_unusable_replica_host():
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=4)
    pol = sim.policy
    _resident(sim, pol, 1, primary=1, replica=0)
    sim.instances[0].draining = True    # cordoned host can't take primaries
    plan = FleetController().plan_failover(pol.view(), dead=1)
    assert plan.promotions == [] and plan.requeues == [1]


def test_lifecycle_helpers_roll_back_state():
    r = SimRequest(rid=0, arrival=2.5, prompt_len=10, decode_len=6)
    r.generated = 4
    r.token_times.extend([3.0, 3.1, 3.2, 3.3])
    r.first_token_time = 3.0
    rollback_tokens(r, 2)
    assert r.generated == 2 and len(r.token_times) == 2
    assert reset_for_reprefill(r) == 10
    assert r.generated == 0 and not r.token_times
    assert r.first_token_time is None
    assert r.arrival == 2.5, "re-prefill keeps the arrival stamp (SLO damage)"


# ---------------------------------------------------------------------------
# golden fleet trace: live executor vs simulator adapter, same script
# ---------------------------------------------------------------------------

# arrival script with a mid-serve kill and a warm rejoin; decode lengths
# keep requests resident across both fleet events
_FLEET_SCRIPT = [
    ("arrive", 8, 10), ("tick",), ("arrive", 12, 12), ("arrive", 6, 12),
    ("tick",), ("kill", 1), ("tick",), ("arrive", 9, 8), ("tick",),
    ("join", 1), ("tick",), ("tick",),
]


def _run_live_fleet(cfg, params, kernel, script):
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=kernel)
    key = jax.random.PRNGKey(7)
    rids, reqs = [], []
    for i, op in enumerate(script):
        if op[0] == "arrive":
            plen, dlen = op[1], op[2]
            req = Request(prompt_len=plen, max_new_tokens=dlen,
                          prompt_tokens=jax.random.randint(
                              jax.random.fold_in(key, i), (1, plen), 0,
                              cfg.vocab_size))
            rids.append(req.rid)
            reqs.append(req)
            cluster.submit(req)
        elif op[0] == "kill":
            cluster.fleet_kill(op[1])
        elif op[0] == "join":
            cluster.fleet_join(op[1])
        cluster.step()
    steps = 0
    while cluster.pending() and steps < 120:
        cluster.step()
        steps += 1
    assert not cluster.pending()
    for r in reqs:
        assert len(r.output_tokens) == r.max_new_tokens, \
            "a fleet event must not lose or truncate a request"
    return cluster, rids, steps


def _run_sim_fleet(cfg, rids, extra_steps, script, redundancy):
    """Lock-step simulator drive of the same script (the test_scheduling
    harness plus fleet ops): kills/joins land through the adapter's
    fleet hooks, re-queued requests drain from the event heap back to
    the front of the driver's queue — exactly where the live executor
    puts them."""
    kernel = AcceLLMScheduler(redundancy=redundancy)
    kernel.trace = []
    sim = Simulator(AcceLLMPolicy(kernel=kernel), _perf(cfg), n_instances=2)
    sim.kick = lambda inst: None          # event mechanics not under test
    pol = sim.policy
    ctrl = FleetController()
    finished_rids = []

    def drain_requeues():
        out = []
        while sim._heap:
            _, _, kind, data = heapq.heappop(sim._heap)
            if kind == "arrival":
                out.append(data)
        return out

    def tick(skip_iid=None):
        finished = {}
        for inst in sim.instances:
            if not inst.alive or inst.iid == skip_iid:
                continue
            done_here = []
            for rid, r in list(inst.decode_batch.items()):
                r.generated += 1
                if r.done:
                    del inst.decode_batch[rid]
                    done_here.append(r)
                    finished_rids.append(rid)
            finished[inst.iid] = done_here
        for inst in sim.instances:
            if inst.iid in finished:
                pol.on_decode_done(inst, finished[inst.iid])

    queue = []

    def step_once():
        skip = None
        if queue:                          # admissions_per_step == 1
            r = queue[0]
            inst = pol.route(r)
            if inst is not None:
                queue.pop(0)
                r.generated = 1            # the prefill's first token
                pol.on_prefill_done(inst, [r])
                skip = inst.iid
        tick(skip_iid=skip)

    arrivals = iter(rids)
    for op in script:
        if op[0] == "arrive":
            queue.append(SimRequest(rid=next(arrivals), arrival=0.0,
                                    prompt_len=op[1], decode_len=op[2]))
        elif op[0] == "kill":
            pol._fleet_kill(op[1], ctrl)
            queue[:0] = drain_requeues()
        elif op[0] == "join":
            pol._fleet_join(op[1], ctrl)
        step_once()
    for _ in range(extra_steps):
        step_once()
    return kernel.trace, ctrl, finished_rids


@pytest.mark.parametrize("redundancy", [True, False])
def test_golden_fleet_trace_live_vs_sim(setup, redundancy):
    cfg, params = setup
    live_kernel = AcceLLMScheduler(redundancy=redundancy)
    live_kernel.trace = []
    cluster, rids, extra = _run_live_fleet(cfg, params, live_kernel,
                                           _FLEET_SCRIPT)
    sim_trace, sim_ctrl, sim_finished = _run_sim_fleet(
        cfg, rids, extra, _FLEET_SCRIPT, redundancy)

    assert live_kernel.trace == sim_trace, (
        "shared kernel diverged across backends under fleet events:\n"
        f"live: {live_kernel.trace}\nsim:  {sim_trace}")
    live_ctrl = cluster.fleet
    assert live_ctrl.trace == sim_ctrl.trace, (
        "fleet controller made different failover decisions:\n"
        f"live: {live_ctrl.trace}\nsim:  {sim_ctrl.trace}")
    assert live_ctrl.stats == sim_ctrl.stats
    assert set(sim_finished) == set(rids)

    kinds = {e[0] for e in live_ctrl.trace}
    assert {"kill", "join"} <= kinds
    if redundancy:
        # the AcceLLM payoff: the kill is absorbed by promotions, and
        # the rejoined instance is warmed with replicas before traffic
        assert live_ctrl.stats["promotions"] > 0
        assert live_ctrl.stats["requeues"] == 0
        assert live_ctrl.stats["reprefill_tokens"] == 0
        assert live_ctrl.stats["warm_streams"] > 0
        assert "warm" in {e[0] for e in live_kernel.trace}
    else:
        # no replicas: every resident of the dead instance re-prefills
        assert live_ctrl.stats["promotions"] == 0
        assert live_ctrl.stats["requeues"] > 0
        assert live_ctrl.stats["reprefill_tokens"] > 0


# ---------------------------------------------------------------------------
# partial sync: a stale replica must catch up before taking the primary
# role (regression: promotions used to assume the mirror was current)
# ---------------------------------------------------------------------------


def test_kernel_rebalance_emits_catchup_sync_first():
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2)
    pol = sim.policy
    for rid in (0, 1, 2):
        r = _resident(sim, pol, rid, primary=0, replica=1, gen=4)
        # every replica lags two lines behind its primary
        sim.instances[1].synced_marks[rid] = r.total_len - 2
    actions = pol.kernel.rebalance(pol.view(), 0)
    promotes = [a for a in actions if isinstance(a, PromoteReplica)]
    assert promotes, "3-vs-0 imbalance must promote"
    for p in promotes:
        i = actions.index(p)
        assert i > 0 and isinstance(actions[i - 1], MirrorSync), \
            "stale replica must absorb the catch-up delta before the flip"
        sync = actions[i - 1]
        assert sync.rid == p.rid
        assert sync.to_line - sync.from_line == 2
    # applying through the adapter clears the lag marks
    pol._rebalance(sim.instances[0])
    for p in promotes:
        assert p.rid not in sim.instances[1].synced_marks
        assert p.rid in sim.instances[1].decode_batch


def test_sim_handoff_refuses_stale_replica():
    sim = Simulator(AcceLLMPolicy(), _perf(), n_instances=2)
    pol = sim.policy
    r = _resident(sim, pol, 4, primary=0, replica=1, gen=3)
    sim.instances[1].synced_marks[4] = r.total_len - 1
    pol._handoff_decodes(sim.instances[0])
    assert 4 in sim.instances[0].decode_batch, \
        "a lagging replica cannot take the primary role"
    del sim.instances[1].synced_marks[4]
    pol._handoff_decodes(sim.instances[0])
    assert 4 in sim.instances[1].decode_batch


def test_live_promote_backstop_syncs_stale_replica(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=AcceLLMScheduler())
    req = Request(prompt_len=8, max_new_tokens=8,
                  prompt_tokens=jax.random.randint(
                      jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab_size))
    cluster.submit(req)
    cluster.step()
    cluster.step()
    pl = cluster.placements[req.rid]
    assert pl.replica is not None, "redundancy must mirror the request"
    p_idx, r_idx = pl.primary[0], pl.replica[0]
    src = cluster.engines[p_idx]
    dst = cluster.engines[r_idx]
    # force the replica's ledger behind the primary (a skipped sync)
    lines = src.store.lines(req.rid)
    dst.store.mark_synced(req.rid, lines - 1)
    before = cluster.stats["mirror_syncs"]
    cluster._apply_promote(PromoteReplica(req.rid, src=p_idx, dst=r_idx))
    assert cluster.stats["mirror_syncs"] == before + 1, \
        "executor backstop must emit the catch-up delta"
    assert cluster.engines[r_idx].store.synced_line(req.rid) >= lines
    assert cluster.placements[req.rid].primary[0] == r_idx
    cluster.run(max_steps=60)
    assert len(req.output_tokens) == req.max_new_tokens


# ---------------------------------------------------------------------------
# single-count accounting: a kill-requeued rid is one request, not two
# ---------------------------------------------------------------------------


def test_sim_kill_requeue_counts_each_rid_once():
    reqs = make_workload("mixed", rate=6.0, duration=6.0, seed=3)
    fleet = FleetController(FixedFleet((KillInstance(2.0, 1),)))
    sim = Simulator(VLLMPolicy(), _perf(), n_instances=2)
    sim.run(requests=reqs, horizon=600.0, fleet=fleet)
    assert fleet.stats["kills"] == 1
    assert fleet.stats["requeues"] + fleet.stats["requeue_backlog"] > 0, \
        "the kill must actually catch resident requests"
    # requeues re-enter the heap, never sim.submitted
    rids = [r.rid for r in sim.submitted]
    assert len(rids) == len(set(rids)) == len(reqs)
    done_rids = [r.rid for r in sim.finished]
    assert len(done_rids) == len(set(done_rids))
    s = summarize(sim.submitted, n_instances=2, duration=sim.now)
    assert s.n_finished + s.n_unfinished == len(reqs)
    rep = slo_summary(sim.submitted, SLO(ttft=3.0, tbt=1.0),
                      duration=sim.now, unit="s")
    assert rep.n_submitted == len(reqs)
    assert rep.n_finished + rep.n_unfinished == len(reqs)


@pytest.mark.parametrize("policy_fn", [
    lambda: AcceLLMPolicy(), lambda: VLLMPolicy(),
    lambda: SplitwisePolicy(1)], ids=["accellm", "vllm", "splitwise"])
def test_sim_survives_kill_then_rejoin(policy_fn):
    reqs = make_workload("mixed", rate=6.0, duration=6.0, seed=5)
    fleet = FleetController(FixedFleet((KillInstance(2.0, 1),
                                        JoinInstance(4.0, 1))))
    sim = Simulator(policy_fn(), _perf(), n_instances=2)
    sim.run(requests=reqs, horizon=600.0, fleet=fleet)
    assert fleet.stats["kills"] == 1 and fleet.stats["joins"] == 1
    rids = [r.rid for r in sim.submitted]
    assert len(rids) == len(set(rids)) == len(reqs)
    s = summarize(sim.submitted, n_instances=2, duration=sim.now)
    assert s.n_finished + s.n_unfinished == len(reqs)
    assert s.n_finished > 0


# ---------------------------------------------------------------------------
# live executor: drain, dead-instance routing, ServeSpec.fleet
# ---------------------------------------------------------------------------


def _live_req(cfg, i, plen, dlen, key):
    return Request(prompt_len=plen, max_new_tokens=dlen,
                   prompt_tokens=jax.random.randint(
                       jax.random.fold_in(key, i), (1, plen), 0,
                       cfg.vocab_size))


def test_live_drain_settles_after_residents_finish(setup):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=8,
                          kv_capacity=256, policy=AcceLLMScheduler())
    key = jax.random.PRNGKey(9)
    reqs = [_live_req(cfg, i, 6 + i, 4, key) for i in range(2)]
    for r in reqs:
        cluster.submit(r)
    cluster.step()
    cluster.step()
    cluster.fleet_drain(1)
    assert cluster.draining[1]
    late = [_live_req(cfg, 10 + i, 7, 3, key) for i in range(2)]
    for r in late:
        cluster.submit(r)
    cluster.run(max_steps=120)
    for r in reqs + late:
        assert len(r.output_tokens) == r.max_new_tokens
    assert not cluster.alive[1] and not cluster.draining[1], \
        "a cordoned instance retires once its residents complete"
    trace = cluster.fleet.trace
    assert ("drain", 1) in trace and ("drained", 1) in trace
    # the cordoned side held no late primaries at the end
    assert not cluster.engines[1].slot_req


@pytest.mark.parametrize("policy", ["vllm", "splitwise"])
def test_live_baselines_route_around_dead_instance(setup, policy):
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=6,
                          kv_capacity=128, policy=policy)
    # vllm: kill a peer; splitwise: kill the decode tier (requests then
    # decode on the surviving prefiller — graceful degradation)
    victim = 0 if policy == "vllm" else 1
    cluster.fleet_kill(victim)
    key = jax.random.PRNGKey(4)
    reqs = [_live_req(cfg, i, 6 + i % 3, 3 + i % 2, key) for i in range(3)]
    for r in reqs:
        cluster.submit(r)
    done = cluster.run(max_steps=200)
    assert len(done) == 3
    for r in reqs:
        assert len(r.output_tokens) == r.max_new_tokens
    assert not cluster.engines[victim].slot_req, \
        "no request may land on a dead instance"
    assert cluster.fleet.stats["kills"] == 1


def test_serve_spec_fleet_end_to_end(setup):
    from repro.api import ServeSpec, serve
    cfg, params = setup
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     num_slots=6, kv_capacity=128, n_requests=4,
                     workload="light", max_steps=200,
                     fleet=FixedFleet((KillInstance(6.0, 1),
                                       JoinInstance(12.0, 1))))
    report = serve(spec, cfg=cfg, params=params)
    assert report.all_finished
    assert report.fleet_stats is not None
    assert report.fleet_stats["kills"] == 1
    assert report.fleet_stats["joins"] == 1
    assert "fleet:" in report.describe()


# ---------------------------------------------------------------------------
# launch: the k8s-shaped orchestration dry-run mirrors the schedule
# ---------------------------------------------------------------------------


def test_launch_fleet_dry_run_plan():
    from repro.api import ServeSpec
    from repro.launch.fleet import dry_run, pod_name
    spec = ServeSpec(arch="starcoder2-3b", policy="accellm", n_instances=2,
                     fleet=FixedFleet((KillInstance(5.0, 1),
                                       JoinInstance(9.0, 1),
                                       Drain(12.0, 0))))
    plan = dry_run(spec)
    assert plan["n_instances"] == 2
    assert len(plan["manifests"]) == 2
    names = [m["metadata"]["name"] for m in plan["manifests"]]
    assert len(set(names)) == 2
    for i, m in enumerate(plan["manifests"]):
        labels = m["metadata"]["labels"]
        assert labels["repro/instance"] == str(i)
        assert labels["repro/pair"] == str(i // 2)
        assert m["spec"]["restartPolicy"] == "Never"
    ops = [s["op"] for s in plan["timeline"]]
    assert ops == ["apply", "wait-ready",          # initial rollout
                   "delete",                       # KillInstance
                   "apply", "wait-ready",          # JoinInstance
                   "cordon",                       # Drain
                   "teardown"]
    kill_step = plan["timeline"][2]
    assert kill_step["grace_period"] == 0
    assert kill_step["pod"] == pod_name(spec, 1)
