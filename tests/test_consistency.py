"""Incremental decode must equal the full parallel forward (teacher forcing)
for every architecture family — the correctness core of the serving path.

MoE archs use a large capacity factor so no tokens drop (capacity-drop
differences between batch shapes are expected semantics, not bugs)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import decode_step, forward_train, init_params, init_state, prefill

TOL = 2e-4


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_full(arch, rng_key):
    cfg = _nodrop(get_config(arch).reduced())
    params = init_params(rng_key, cfg)
    B, S, Sp = 2, 12, 8
    key = jax.random.fold_in(rng_key, 1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = {}
    prefix = 0
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        extra["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))
        prefix = cfg.frontend.num_prefix_tokens
    if cfg.is_encoder_decoder:
        extra["frames"] = jax.random.normal(key, (B, 32,
                                                  cfg.frontend.embed_dim))
    full_logits, _ = forward_train(cfg, params, {"tokens": tokens, **extra},
                                   remat=False)
    state = init_state(cfg, B, 64)
    pl, state = prefill(cfg, params, {"tokens": tokens[:, :Sp], **extra},
                        state)
    errs = [float(jnp.abs(pl - full_logits[:, Sp - 1]).max())]
    for i in range(Sp, S):
        dl, state = decode_step(cfg, params, tokens[:, i:i + 1], state,
                                jnp.int32(i + prefix))
        errs.append(float(jnp.abs(dl - full_logits[:, i]).max()))
    assert max(errs) < TOL, f"{arch}: decode/full mismatch {max(errs):.2e}"


@pytest.mark.parametrize("arch", ["starcoder2-3b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_per_request_clock_matches_scalar(arch, rng_key):
    """Vector t (continuous batching) must agree with scalar t."""
    cfg = _nodrop(get_config(arch).reduced())
    params = init_params(rng_key, cfg)
    B, Sp = 2, 8
    tokens = jax.random.randint(rng_key, (B, Sp), 0, cfg.vocab_size)
    s1 = init_state(cfg, B, 64)
    _, s1 = prefill(cfg, params, {"tokens": tokens}, s1)
    s2 = jax.tree_util.tree_map(lambda a: a.copy(), s1)
    nxt = tokens[:, :1]
    d1, _ = decode_step(cfg, params, nxt, s1, jnp.int32(Sp))
    d2, _ = decode_step(cfg, params, nxt, s2,
                        jnp.full((B,), Sp, jnp.int32))
    assert float(jnp.abs(d1 - d2).max()) < 1e-5


def test_sliding_window_decode_consistency(rng_key):
    """Ring-buffer window decode == full decode while inside the window."""
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(rng_key, cfg)
    B, Sp, n_dec = 1, 6, 4
    tokens = jax.random.randint(rng_key, (B, Sp + n_dec), 0, cfg.vocab_size)
    full_logits, _ = forward_train(cfg, params, {"tokens": tokens},
                                   remat=False)
    # capacity larger than total length: window never truncates
    state = init_state(cfg, B, 32)
    _, state = prefill(cfg, params, {"tokens": tokens[:, :Sp]}, state)
    for i in range(Sp, Sp + n_dec):
        dl, state = decode_step(cfg, params, tokens[:, i:i + 1], state,
                                jnp.int32(i))
        err = float(jnp.abs(dl - full_logits[:, i]).max())
        assert err < TOL
