"""KV-store ledger + redundancy-primitive tests.

The acceptance bar for the paged refactor:

* delta mirror-sync (``delta_since`` + apply) is BIT-IDENTICAL to a full
  ``export_slot``/``import_slot`` copy (round-trip property),
* on a golden bursty trace, live ``PagedStore`` used-bytes and sim
  ``SimStore`` used-bytes agree step-for-step with
  ``core.kvbytes.state_bytes_at``,
* executed MirrorSync traffic per decode step equals
  ``bytes_per_token(cfg)`` per mirrored request (one KV line), not full
  slot state.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.kvbytes import bytes_per_token, state_bytes_at
from repro.kvstore import (BlockLedger, KVStoreError, LineCosts, PagedStore,
                           SimStore)
from repro.models import init_params
from repro.scheduling.live import LiveCluster
from repro.serving import InstanceEngine, Request
from repro.workloads import Bursty, UniformLengths, WorkloadSpec
from tests._propcheck import given, settings, st


# ---------------------------------------------------------------------------
# LineCosts: one formula, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["starcoder2-3b", "deepseek-v3-671b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b"])
def test_line_costs_match_kvbytes(arch):
    cfg = get_config(arch)
    costs = LineCosts.from_config(cfg)
    for length in (0, 1, 37, 1000):
        assert costs.bytes_at(length) == state_bytes_at(cfg, length)
    assert costs.line_bytes == bytes_per_token(cfg)


# ---------------------------------------------------------------------------
# BlockLedger arithmetic
# ---------------------------------------------------------------------------


def _ledger(num_blocks=16, block_lines=4, line_bytes=8.0, fixed=0):
    return BlockLedger(LineCosts(line_bytes, fixed, 0), num_blocks,
                       block_lines)


def test_ledger_alloc_append_free():
    led = _ledger()
    led.alloc(1, lines=5)                   # ceil(5/4) = 2 blocks
    assert led.used_blocks() == 2 and led.free_blocks() == 14
    assert led.used_bytes() == 5 * 8.0
    led.append_line(1, 3)                   # 8 lines -> still 2 blocks
    assert led.used_blocks() == 2
    led.append_line(1)                      # 9 lines -> 3 blocks
    assert led.used_blocks() == 3
    assert led.lines(1) == 9
    led.alloc(2, lines=1)
    assert led.used_blocks() == 4
    assert led.free(1) == 3
    assert led.free_blocks() == 15
    with pytest.raises(KVStoreError):
        led.lines(1)
    with pytest.raises(KVStoreError):
        led.alloc(2, lines=1)               # double alloc


def test_ledger_fixed_block_and_exhaustion():
    led = _ledger(num_blocks=3, block_lines=4, fixed=100)
    led.alloc(7, lines=4)                   # 1 fixed + 1 line block
    assert led.used_blocks() == 2
    assert led.used_bytes() == 4 * 8.0 + 100
    assert not led.can_alloc(4)             # would need 2, only 1 free
    with pytest.raises(KVStoreError):
        led.alloc(8, lines=4)
    led.free(7)
    assert led.free_blocks() == 3


def test_ledger_delta_and_sync_marks():
    led = _ledger()
    led.alloc(3, lines=6, synced=6)
    led.append_line(3, 2)
    assert led.delta_since(3, led.synced_line(3)) == (6, 8)
    led.mark_synced(3)
    assert led.synced_line(3) == 8
    assert led.delta_since(3, 8) == (8, 8)


def test_sim_store_overcommits_instead_of_crashing():
    """Sim admission gates on BYTE headroom while block rounding (a
    2-line request pins a whole block, plus a fixed block) can exhaust
    the nominal pool first: the non-strict sim ledger must absorb the
    overcommit — free_blocks bottoms at 0, used-bytes stay exact — not
    raise from a read-only accounting query mid-run."""
    costs = LineCosts(line_bytes=100.0, recurrent_bytes=10, static_bytes=0)
    store = SimStore(costs, capacity_bytes=32_000, block_lines=16)
    assert store.ledger.num_blocks == 20
    # 30 two-line requests: 60 blocks wanted (1 line + 1 fixed each),
    # but only 6000 of 32000 bytes used
    store.reconcile({rid: 2 for rid in range(30)})
    assert store.free_blocks() == 0
    assert store.used_bytes() == 30 * (2 * 100.0 + 10)
    assert store.ledger.used_blocks() == 60
    store.reconcile({0: 2})                 # 29 freed: overflow evaporates
    assert store.ledger.used_blocks() == 2
    assert store.free_blocks() == 18
    assert len(store.ledger._free) <= store.ledger.num_blocks


def test_sim_store_reconcile_matches_state_bytes_at():
    cfg = get_config("starcoder2-3b").reduced()
    store = SimStore(LineCosts.from_config(cfg), capacity_bytes=1e9)
    store.reconcile({1: 10, 2: 25})
    expected = state_bytes_at(cfg, 10) + state_bytes_at(cfg, 25)
    assert store.used_bytes() == expected
    blocks_before = store.free_blocks()
    store.reconcile({2: 26})                # 1 gone, 2 grew
    assert store.used_bytes() == state_bytes_at(cfg, 26)
    assert store.free_blocks() > blocks_before


# ---------------------------------------------------------------------------
# PagedStore: slot-affine block tables
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, i, plen=8, new=6, seed=0):
    toks = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(seed), i),
                              (1, plen), 0, cfg.vocab_size)
    return Request(prompt_len=plen, max_new_tokens=new, prompt_tokens=toks)


def test_paged_store_slot_affinity(setup):
    cfg, _ = setup
    store = PagedStore(cfg, num_slots=4, kv_capacity=64, block_lines=16)
    assert store.block_lines == 16 and store.line_blocks_per_slot == 4
    store.alloc(rid=42, slot=2, lines=20)   # 2 line blocks
    table = store.line_block_table(42)
    assert table == [8, 9]                  # slot 2 owns pool blocks 8..11
    store.append_line(42, 45)               # 65 lines: capped at the window
    assert store.line_block_table(42) == [8, 9, 10, 11]
    assert store.free_blocks() == 12
    store.free_slot(2)
    assert store.free_blocks() == 16


def _reset(eng: InstanceEngine):
    for slot in list(eng.slot_req) + list(eng.replica_of):
        eng.release(slot)


def _states_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Round-trip property: delta mirror-sync == full state copy, bit for bit
# ---------------------------------------------------------------------------


_PROP_ENV = {}


def _prop_env():
    """cfg/params/engine pair for the property tests, built once.

    Module-level (not a fixture) because the hypothesis-fallback
    ``given`` wrapper exposes a zero-argument signature to pytest, so
    fixture injection is unavailable under it."""
    if not _PROP_ENV:
        cfg = get_config("starcoder2-3b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg)
        _PROP_ENV["cfg"] = cfg
        _PROP_ENV["engines"] = tuple(
            InstanceEngine(cfg, params, num_slots=2, kv_capacity=64,
                           instance_id=i) for i in range(2))
    return _PROP_ENV["cfg"], _PROP_ENV["engines"]


@given(st.integers(min_value=1, max_value=12),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=6, deadline=None)
def test_delta_sync_roundtrip_property(plen, steps, stride):
    """Decode ``steps`` tokens on the primary, delta-syncing the replica
    every ``stride`` steps (so syncs carry multi-line deltas): the
    replica slot must be bit-identical to a fresh full export of the
    primary slot, and the ledger marks must agree."""
    cfg, (a, b) = _prop_env()
    _reset(a), _reset(b)
    req = _mk(cfg, 0, plen=plen, new=steps + 2)
    slot_a = a.prefill_request(req)
    # replicate via the per-layer stream path (full copy, marks synced)
    chunks, length, last, lines = a.export_stream(slot_a)
    b.import_stream(0, chunks, length, last, lines, req,
                    as_replica_of=(0, slot_a))
    for step in range(1, steps + 1):
        a.decode()
        if step % stride == 0:
            moved = b.sync_replica_from(a, slot_a, 0)
            delta = min(stride, step)       # lines since last sync
            assert moved == pytest.approx(
                delta * bytes_per_token(cfg))
    if steps % stride:
        b.sync_replica_from(a, slot_a, 0)   # catch up the partial tail
    assert b.store.synced_line(req.rid) == a.store.lines(req.rid)
    assert _states_equal(b.store.extract_slot(0),
                         a.store.extract_slot(slot_a))
    assert int(b.lengths[0]) == int(a.lengths[slot_a])


def test_promote_demote_after_partial_sync(setup):
    """Role flips after partial syncs: once the replica catches up and
    is promoted, decoding on it yields exactly the tokens the primary
    would have produced (zero-cost migration stays lossless)."""
    cfg, params = setup
    _, (a, b) = _prop_env()
    _reset(a), _reset(b)
    req = _mk(cfg, 1, plen=7, new=8, seed=5)
    expected = []
    ref = InstanceEngine(cfg, params, num_slots=1, kv_capacity=64)
    ref_req = Request(prompt_len=req.prompt_len,
                      max_new_tokens=req.max_new_tokens,
                      prompt_tokens=req.prompt_tokens)
    ref.prefill_request(ref_req)
    while ref_req.generated < ref_req.max_new_tokens:
        ref.decode()
    expected = ref_req.output_tokens

    slot_a = a.prefill_request(req)
    chunks, length, last, lines = a.export_stream(slot_a)
    b.import_stream(1, chunks, length, last, lines, req,
                    as_replica_of=(0, slot_a))
    a.decode()
    a.decode()                               # replica now 2 lines behind
    assert b.store.synced_line(req.rid) < a.store.lines(req.rid)
    b.sync_replica_from(a, slot_a, 1)        # partial-sync catch-up
    # flip roles: promote the replica, demote the old primary
    b.promote_replica(1, req)
    a.demote_to_replica(slot_a, of=(1, 1))
    while req.generated < req.max_new_tokens:
        b.decode()
        if 1 in b.slot_req:                  # mirror back into old primary
            a.sync_replica_from(b, 1, slot_a)
    assert req.output_tokens == expected


# ---------------------------------------------------------------------------
# Accounting identity on a golden bursty trace (acceptance criterion)
# ---------------------------------------------------------------------------


def test_accounting_identity_golden_bursty_trace(setup):
    """Drive the live cluster open-loop through a bursty arrival trace;
    after EVERY scheduling iteration:

    * each engine's PagedStore used-bytes == Σ state_bytes_at over the
      requests resident there (primaries AND replicas),
    * a SimStore reconciled to the same residency reports the same
      used-bytes (identical ledger arithmetic),
    * MirrorSync traffic accrued this iteration == one KV line
      (bytes_per_token) per executed sync — never full slot state.
    """
    cfg, params = setup
    cluster = LiveCluster(cfg, params, n_instances=2, num_slots=4,
                          kv_capacity=64, policy="accellm")
    spec = WorkloadSpec(
        arrival=Bursty(rate_on=1.5, rate_off=0.1, duration=16.0,
                       mean_on=4.0, mean_off=4.0),
        lengths=UniformLengths(prompt=(4, 10), decode=(3, 8)),
        name="golden-bursty")
    source = iter(spec.source(seed=7, cfg=cfg))
    sim_stores = [SimStore(LineCosts.from_config(cfg),
                           capacity_bytes=eng.store.capacity_bytes)
                  for eng in cluster.engines]
    pending = next(source, None)
    prev_syncs, prev_bytes = 0, 0.0
    checked_nonzero = False
    for _ in range(200):
        while pending is not None and pending.arrival <= cluster.now:
            cluster.submit(pending, stamp_arrival=False)
            pending = next(source, None)
        if pending is None and not cluster.pending():
            break
        cluster.step()
        # residency per engine from the executor's placements (request
        # objects), independent of the ledger under test
        for eng, sim_store in zip(cluster.engines, sim_stores):
            idx = eng.instance_id
            resident = {}
            for rid, pl in cluster.placements.items():
                if pl.primary[0] == idx or (
                        pl.replica is not None and pl.replica[0] == idx):
                    resident[rid] = cluster._reqs[rid].total_len
            expected = sum(state_bytes_at(cfg, n) for n in resident.values())
            assert eng.used_bytes() == pytest.approx(expected)
            assert sim_store.reconcile(resident).used_bytes() == \
                pytest.approx(expected)
        d_syncs = cluster.stats["mirror_syncs"] - prev_syncs
        d_bytes = cluster.stats["mirror_bytes"] - prev_bytes
        assert d_bytes == pytest.approx(d_syncs * bytes_per_token(cfg)), \
            "a MirrorSync moved more than the newly generated KV line"
        if d_syncs:
            checked_nonzero = True
        prev_syncs = cluster.stats["mirror_syncs"]
        prev_bytes = cluster.stats["mirror_bytes"]
    assert not cluster.pending(), "trace did not drain"
    assert checked_nonzero, "trace exercised no mirror syncs"
    # delta mirroring must be far cheaper than full-state mirroring
    full_state_cost = state_bytes_at(cfg, 8)
    assert cluster.stats["mirror_bytes"] < \
        cluster.stats["mirror_syncs"] * full_state_cost


# ---------------------------------------------------------------------------
# Satellites: replica accounting + PerfModel capacity guard
# ---------------------------------------------------------------------------


def test_replica_tokens_counted(setup):
    cfg, params = setup
    a = InstanceEngine(cfg, params, num_slots=2, kv_capacity=64)
    b = InstanceEngine(cfg, params, num_slots=2, kv_capacity=64,
                       instance_id=1)
    req = _mk(cfg, 3, plen=9)
    slot = a.prefill_request(req)
    b.import_slot(0, a.export_slot(slot), req, as_replica_of=(0, slot))
    assert a.total_kv_tokens() == req.total_len
    assert b.primary_kv_tokens() == 0
    assert b.replica_kv_tokens() == req.total_len
    assert b.total_kv_tokens() == req.total_len, \
        "replica lines must be visible to memory accounting"
    assert b.used_bytes() == pytest.approx(
        state_bytes_at(cfg, req.total_len))


def test_perf_model_rejects_negative_kv_capacity():
    from repro.sim.devices import InstanceSpec, H100
    from repro.sim.perf import PerfModel
    cfg = get_config("llama2-70b")
    with pytest.raises(ValueError, match="HBM too small"):
        PerfModel(cfg, InstanceSpec(H100, 1))   # 140GB weights vs 80GB
    PerfModel(cfg, InstanceSpec(H100, 4))       # fits; must not raise
