"""Traffic layer: arrival processes, length models, RequestSource
determinism, trace round-trip, SLO metrics, and the open/closed-loop
lifecycles on both backends."""
import math

import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.sim import (AcceLLMPolicy, H100, InstanceSpec, PerfModel,
                       Simulator, summarize)
from repro.sim.workload import SimRequest, make_workload
from repro.workloads import (SLO, Batch, Bursty, ClosedLoop, DiurnalRamp,
                             Poisson, TableLengths, TraceReplay,
                             UniformLengths, WorkloadSpec, load_trace,
                             save_trace, slo_summary, table2_spec)


def stream(spec, seed=0):
    return [(r.rid, r.arrival, r.prompt_len, r.max_new_tokens)
            for r in spec.source(seed=seed)]


# ---------------------------------------------------------------------------
# determinism + bounds
# ---------------------------------------------------------------------------


@given(st.sampled_from(["light", "mixed", "heavy"]),
       st.floats(min_value=1.0, max_value=20.0),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=15, deadline=None)
def test_poisson_seeded_determinism_and_bounds(workload, rate, seed):
    spec = table2_spec(workload, rate=rate, duration=10.0)
    a, b = stream(spec, seed), stream(spec, seed)
    assert a == b, "same (spec, seed) must produce the identical stream"
    arrivals = [t for _, t, _, _ in a]
    assert all(0.0 < t < 10.0 for t in arrivals)
    assert arrivals == sorted(arrivals)
    assert [rid for rid, _, _, _ in a] == list(range(len(a)))


def test_different_seeds_differ():
    spec = table2_spec("mixed", rate=8.0, duration=10.0)
    assert stream(spec, 0) != stream(spec, 1)


def test_poisson_rate_is_respected():
    # mean count over seeds ~ rate * duration (law of large numbers)
    spec = WorkloadSpec(arrival=Poisson(rate=10.0, duration=20.0),
                        lengths=UniformLengths((1, 2), (1, 2)))
    counts = [len(stream(spec, s)) for s in range(20)]
    assert 150 <= np.mean(counts) <= 250


@given(st.floats(min_value=2.0, max_value=30.0),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=15, deadline=None)
def test_bursty_bounds_and_determinism(rate_on, seed):
    proc = Bursty(rate_on=rate_on, duration=12.0, rate_off=0.5,
                  mean_on=2.0, mean_off=3.0)
    a = list(proc.times(np.random.default_rng(seed)))
    b = list(proc.times(np.random.default_rng(seed)))
    assert a == b
    assert all(0.0 < t < 12.0 for t in a)
    assert a == sorted(a)


def test_bursty_duty_cycle():
    """With rate_off=0 the empirical rate must sit between the off and on
    rates, roughly rate_on * duty_cycle."""
    rate_on, mean_on, mean_off, duration = 20.0, 2.0, 2.0, 200.0
    proc = Bursty(rate_on=rate_on, duration=duration, rate_off=0.0,
                  mean_on=mean_on, mean_off=mean_off)
    counts = [len(list(proc.times(np.random.default_rng(s))))
              for s in range(10)]
    duty = mean_on / (mean_on + mean_off)
    expected = rate_on * duty * duration
    assert 0.7 * expected <= np.mean(counts) <= 1.3 * expected
    # and strictly fewer arrivals than an always-on Poisson at rate_on
    always_on = len(list(Poisson(rate=rate_on, duration=duration).times(
        np.random.default_rng(0))))
    assert np.mean(counts) < 0.8 * always_on


def test_diurnal_ramp_density_follows_rate():
    proc = DiurnalRamp(low=1.0, peak=20.0, period=100.0, duration=100.0)
    ts = np.array(list(proc.times(np.random.default_rng(0))))
    assert ts.size and 0.0 < ts.min() and ts.max() < 100.0
    # the middle half-period (peak) must be denser than the edges (trough)
    trough = np.sum(ts < 25.0) + np.sum(ts >= 75.0)
    peak = np.sum((ts >= 25.0) & (ts < 75.0))
    assert peak > 2 * trough


def test_batch_and_closed_loop_shapes():
    assert [t for _, t, _, _ in stream(
        WorkloadSpec(Batch(5), UniformLengths((2, 4), (2, 4))))] == [0.0] * 5
    spec = WorkloadSpec(ClosedLoop(k=3, n_requests=7),
                        UniformLengths((2, 4), (2, 4)))
    src = spec.source(seed=0)
    assert src.concurrency == 3
    assert len(list(src)) == 7


# ---------------------------------------------------------------------------
# trace replay round-trip
# ---------------------------------------------------------------------------


def test_trace_replay_round_trip(tmp_path):
    path = tmp_path / "trace.jsonl"
    spec = table2_spec("mixed", rate=6.0, duration=8.0)
    orig = list(spec.source(seed=3))
    assert save_trace(path, orig) == len(orig)
    replay = load_trace(path)
    assert isinstance(replay.arrival, TraceReplay)
    got = list(replay.source(seed=999))   # seed must not matter for traces
    assert ([(r.arrival, r.prompt_len, r.max_new_tokens) for r in got]
            == [(r.arrival, r.prompt_len, r.max_new_tokens) for r in orig])
    # SimRequest streams (decode_len spelling) round-trip too
    sim_reqs = make_workload("light", rate=4.0, duration=5.0, seed=1)
    save_trace(path, sim_reqs)
    got = list(load_trace(path).source())
    assert [(r.prompt_len, r.max_new_tokens) for r in got] \
        == [(r.prompt_len, r.decode_len) for r in sim_reqs]


def test_trace_replay_rejects_unsorted():
    proc = TraceReplay((2.0, 1.0))
    with pytest.raises(ValueError):
        list(proc.times(np.random.default_rng(0)))


# ---------------------------------------------------------------------------
# SLO metrics + unfinished-request guards
# ---------------------------------------------------------------------------


def _fake_req(arrival, first, times, finish):
    r = SimRequest(rid=0, arrival=arrival, prompt_len=4, decode_len=len(times))
    r.first_token_time, r.token_times, r.finish_time = first, list(times), \
        finish
    return r


def test_unfinished_request_metric_guards():
    r = SimRequest(rid=1, arrival=0.0, prompt_len=8, decode_len=4)
    assert r.ttft() is None and r.jct() is None and r.tbts() == []


def test_summarize_reports_unfinished_instead_of_raising():
    done = _fake_req(0.0, 1.0, [1.0, 2.0, 3.0], 3.0)
    pending = SimRequest(rid=2, arrival=0.5, prompt_len=8, decode_len=4)
    s = summarize([done, pending], n_instances=2, duration=10.0)
    assert s.n_finished == 1 and s.n_unfinished == 1
    s = summarize([pending], n_instances=2, duration=10.0)
    assert s.n_finished == 0 and s.n_unfinished == 1
    assert math.isnan(s.ttft_p50)


def test_slo_summary_axes():
    good = _fake_req(0.0, 1.0, [1.0, 2.0, 3.0], 3.0)       # ttft 1, tbt 1
    slow_start = _fake_req(0.0, 9.0, [9.0, 10.0], 10.0)    # ttft 9
    stalled = _fake_req(0.0, 1.0, [1.0, 8.0], 8.0)         # tbt 7
    pending = SimRequest(rid=9, arrival=0.0, prompt_len=4, decode_len=2)
    s = slo_summary([good, slow_start, stalled, pending],
                    SLO(ttft=2.0, tbt=2.0), duration=10.0, unit="s")
    assert s.n_submitted == 4 and s.n_finished == 3 and s.n_unfinished == 1
    assert s.attainment == pytest.approx(1 / 4)
    assert s.attainment_ttft == pytest.approx(2 / 3)
    assert s.attainment_tbt == pytest.approx(2 / 3)
    assert s.goodput == pytest.approx(0.1)
    assert "goodput" in s.describe()


def test_serve_report_tbts_no_sentinel():
    """Single-token requests must yield an EMPTY tbt array, not [0.0]."""
    from repro.api import ServeReport, ServeSpec
    done = _fake_req(0.0, 1.0, [1.0], 1.0)

    class _C:
        stats = {}
    report = ServeReport(spec=ServeSpec(), cluster=_C(), finished=[done],
                         n_submitted=1)
    assert report.tbts().size == 0


# ---------------------------------------------------------------------------
# both backends consume the same source
# ---------------------------------------------------------------------------

CFG = None


def _sim(policy=None, n=4):
    from repro.configs import get_config
    global CFG
    if CFG is None:
        CFG = get_config("llama2-70b")
    return Simulator(policy or AcceLLMPolicy(),
                     PerfModel(CFG, InstanceSpec(H100, 4)), n_instances=n)


def test_simulator_consumes_open_loop_source():
    spec = table2_spec("mixed", rate=5.0, duration=10.0)
    sim = _sim()
    done = sim.run(source=spec.source(seed=0), horizon=600.0)
    assert len(done) == len(list(spec.source(seed=0)))
    assert sim.timeline, "simulator must record a utilization timeline"
    assert all(p.n_prefill + p.n_decode + p.n_idle == 4
               for p in sim.timeline)


def test_simulator_overload_cannot_look_healthy():
    """Scoring sim.submitted (not just the finishers) over a truncated
    horizon must surface the stragglers as unfinished / SLO misses."""
    spec = table2_spec("heavy", rate=30.0, duration=10.0)
    sim = _sim()
    sim.run(source=spec.source(seed=0), horizon=3.0)
    assert len(sim.submitted) == len(list(spec.source(seed=0)))
    s = summarize(sim.submitted, 4, 3.0, slo=SLO(ttft=2.0))
    assert s.n_unfinished > 0
    assert s.slo_attainment < 1.0


def test_summarize_no_tbt_sentinel():
    """All-single-token runs have NO inter-token gaps: NaN, not 0.0."""
    done = _fake_req(0.0, 1.0, [1.0], 1.0)
    s = summarize([done], n_instances=1, duration=2.0)
    assert math.isnan(s.tbt_mean) and math.isnan(s.tbt_worst)


def test_simulator_closed_loop_keeps_k_in_flight():
    spec = WorkloadSpec(ClosedLoop(k=2, n_requests=8),
                        TableLengths("light"))
    sim = _sim()
    done = sim.run(source=spec.source(seed=0))
    assert len(done) == 8
    # arrivals are stamped at issue time: all but the first k strictly
    # after t=0, and never more than k requests in flight
    arrivals = sorted(r.arrival for r in done)
    assert arrivals[:2] == [0.0, 0.0] and all(t > 0 for t in arrivals[2:])
    events = [(r.arrival, 1) for r in done] + [(r.finish_time, -1)
                                              for r in done]
    in_flight = peak = 0
    # at equal timestamps the finish precedes the arrival it triggered
    for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
        in_flight += delta
        peak = max(peak, in_flight)
    assert peak <= 2
